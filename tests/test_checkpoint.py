"""Checkpoint store: atomicity, integrity fallback, keep-k, async."""

import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointStore


def _tree(x=0.0):
    return {"a": jnp.asarray([1.0 + x, 2.0]), "b": {"c": jnp.arange(6).reshape(2, 3) + int(x)}}


def test_roundtrip(tmp_path):
    store = CheckpointStore(tmp_path)
    store.save(5, _tree(1.0))
    step, restored = store.restore(_tree())
    assert step == 5
    np.testing.assert_allclose(np.asarray(restored["a"]), [2.0, 2.0])
    np.testing.assert_array_equal(np.asarray(restored["b"]["c"]), np.arange(6).reshape(2, 3) + 1)


def test_integrity_fallback(tmp_path):
    store = CheckpointStore(tmp_path)
    store.save(1, _tree(1.0))
    store.save(2, _tree(2.0))
    # corrupt the newest checkpoint's first leaf
    leaf = next((tmp_path / "step_0000000002").glob("leaf_*.npy"))
    leaf.write_bytes(b"garbage")
    step, restored = store.restore(_tree())
    assert step == 1
    np.testing.assert_allclose(np.asarray(restored["a"]), [2.0, 2.0])


def test_keep_k_gc(tmp_path):
    store = CheckpointStore(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        store.save(s, _tree(float(s)))
    assert store.all_steps() == [3, 4]


def test_async_save(tmp_path):
    store = CheckpointStore(tmp_path)
    store.save_async(7, _tree(7.0))
    store.wait()
    step, restored = store.restore(_tree())
    assert step == 7


def test_no_tmp_dir_left_behind(tmp_path):
    store = CheckpointStore(tmp_path)
    store.save(9, _tree())
    assert not list(pathlib.Path(tmp_path).glob("*.tmp"))


def test_restore_empty(tmp_path):
    store = CheckpointStore(tmp_path)
    step, restored = store.restore(_tree())
    assert step is None and restored is None


# ---------------------------------------------------------------------------
# the real serving payload: SessionState slab + host queue metadata
# (crash-recoverable serving, DESIGN.md §12)
# ---------------------------------------------------------------------------


def _serving_pair(tmp_path, *, every=2):
    from repro.core import SiliconMR
    from repro.launch.serve_dfr import DFRServer, StreamRequest
    from repro.pipeline.session import SessionConfig

    cfg = SessionConfig(model=SiliconMR(), n_nodes=16, washout=24,
                        ridge_l2=(1e-6, 1e-4), chunk_k=24, refresh_every=2,
                        state_method="fast")
    server = DFRServer(cfg, 2, checkpoint_dir=str(tmp_path),
                       checkpoint_every=every)
    server.warmup()
    rng = np.random.default_rng(17)
    for r in range(3):
        server.submit(StreamRequest(
            rid=r, j=rng.random(5 * 24).astype(np.float32),
            y=rng.random(5 * 24).astype(np.float32)))
    return cfg, server


def test_session_slab_checkpoint_roundtrip_bit_exact(tmp_path):
    """Every SessionState leaf (f32/i32/bool) survives the npy round-trip
    bit for bit, and the host queue metadata (request bytes, offsets,
    emitted predictions) comes back equal."""
    from repro.launch.serve_dfr import DFRServer

    cfg, server = _serving_pair(tmp_path, every=0)
    for _ in range(3):
        server.step()
    server.save_checkpoint()
    server.close()
    slab = jax.device_get(server.state)

    resumed = DFRServer(cfg, 2, checkpoint_dir=str(tmp_path))
    assert resumed.restore() == server.tick
    for name, a, b in zip(slab._fields, slab, resumed.state):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)
        assert np.asarray(a).dtype == np.asarray(b).dtype, name
    assert resumed.tick == server.tick
    assert resumed.counters == server.counters
    for sa, sb in zip(server.slots, resumed.slots):
        assert (sa is None) == (sb is None)
        if sa is not None:
            assert (sa.rid, sa.pos) == (sb.rid, sb.pos)
            np.testing.assert_array_equal(sa.j, sb.j)
    assert [r.rid for r in server.queue] == [r.rid for r in resumed.queue]


def test_corrupted_slab_checkpoint_falls_back_and_resumes_bit_exact(tmp_path):
    """Torn write / bit rot on the NEWEST slab checkpoint: restore walks
    back to the previous intact one, and the re-served stream outputs are
    bit-exact against an uninterrupted reference run."""
    from repro.launch.serve_dfr import DFRServer

    # uninterrupted reference
    cfg, ref = _serving_pair(tmp_path / "ref", every=0)
    ref.drain()
    expect = {r.rid: np.concatenate(r.y_hat) for r in ref.completed}

    # checkpointing run, killed mid-stream with the newest snapshot mangled
    cfg, crash = _serving_pair(tmp_path / "ck", every=2)
    for _ in range(5):
        crash.step()
    crash.close()
    store = CheckpointStore(tmp_path / "ck")
    steps = store.all_steps()
    assert steps == [2, 4]
    newest = tmp_path / "ck" / f"step_{steps[-1]:010d}"
    # bit rot on one slab leaf (hash mismatch) ...
    leaf = sorted(newest.glob("leaf_*.npy"))[0]
    leaf.write_bytes(leaf.read_bytes()[:-8] + b"deadbeef")
    # ... and a torn write of a later snapshot that never landed
    (tmp_path / "ck" / "step_0000000006.tmp").mkdir()

    resumed = DFRServer(cfg, 2, checkpoint_dir=str(tmp_path / "ck"))
    resumed.warmup()
    assert resumed.restore() == steps[0]          # walked back past the rot
    resumed.drain()
    got = {r.rid: np.concatenate(r.y_hat) for r in resumed.completed}
    assert set(got) == set(expect)
    for rid in expect:
        np.testing.assert_array_equal(expect[rid], got[rid])
