"""Checkpoint store: atomicity, integrity fallback, keep-k, async."""

import pathlib

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointStore


def _tree(x=0.0):
    return {"a": jnp.asarray([1.0 + x, 2.0]), "b": {"c": jnp.arange(6).reshape(2, 3) + int(x)}}


def test_roundtrip(tmp_path):
    store = CheckpointStore(tmp_path)
    store.save(5, _tree(1.0))
    step, restored = store.restore(_tree())
    assert step == 5
    np.testing.assert_allclose(np.asarray(restored["a"]), [2.0, 2.0])
    np.testing.assert_array_equal(np.asarray(restored["b"]["c"]), np.arange(6).reshape(2, 3) + 1)


def test_integrity_fallback(tmp_path):
    store = CheckpointStore(tmp_path)
    store.save(1, _tree(1.0))
    store.save(2, _tree(2.0))
    # corrupt the newest checkpoint's first leaf
    leaf = next((tmp_path / "step_0000000002").glob("leaf_*.npy"))
    leaf.write_bytes(b"garbage")
    step, restored = store.restore(_tree())
    assert step == 1
    np.testing.assert_allclose(np.asarray(restored["a"]), [2.0, 2.0])


def test_keep_k_gc(tmp_path):
    store = CheckpointStore(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        store.save(s, _tree(float(s)))
    assert store.all_steps() == [3, 4]


def test_async_save(tmp_path):
    store = CheckpointStore(tmp_path)
    store.save_async(7, _tree(7.0))
    store.wait()
    step, restored = store.restore(_tree())
    assert step == 7


def test_no_tmp_dir_left_behind(tmp_path):
    store = CheckpointStore(tmp_path)
    store.save(9, _tree())
    assert not list(pathlib.Path(tmp_path).glob("*.tmp"))


def test_restore_empty(tmp_path):
    store = CheckpointStore(tmp_path)
    step, restored = store.restore(_tree())
    assert step is None and restored is None
