"""Kernel-vs-reference parity through the *public* execution paths.

tests/test_kernels.py checks the Pallas kernels against their dedicated
pure-jnp oracles (dfr_scan_ref / gram_ref).  These tests close the remaining
gap to the paths users actually dispatch on:

* ``generate_states(method="kernel")`` vs ``method="ref"`` — the reservoir
  dispatch in core/reservoir.py (what DFRCAccelerator and the pipeline use),
  not the raw kernel wrapper;
* the ridge readout fitted from kernel-accumulated Gram statistics vs the
  pure-jnp solves (pipeline SVD path and core/readout.py host path).

Kernels run in Pallas interpret mode on CPU (TPU is the lowering target), so
these pass on CPU CI.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MZISine, MackeyGlass, SiliconMR, fit_readout, make_mask
from repro.core.reservoir import generate_states
from repro.kernels.ridge_gram import gram_accumulate
from repro.pipeline import (apply_readout, fit_ridge, fit_ridge_batched, gram,
                            solve_gcv, with_bias)

MODELS = [SiliconMR(), SiliconMR(beta_tpa=0.5), MackeyGlass(), MZISine()]


def _model_id(m):
    return type(m).__name__ + str(getattr(m, "beta_tpa", ""))


LAMS = (1e-6, 1e-4, 1e-2)


@pytest.mark.parametrize("model", MODELS, ids=_model_id)
@pytest.mark.parametrize("batched", [False, True], ids=["series", "batch"])
def test_generate_states_kernel_matches_ref(model, batched):
    """The public "kernel" dispatch equals the sequential oracle dispatch."""
    rng = np.random.default_rng(3)
    shape = (5, 40) if batched else (40,)
    j = jnp.asarray(rng.uniform(0, 1, shape), jnp.float32)
    mask = make_mask(23, seed=4)
    out = generate_states(model, j, mask, method="kernel")
    ref = generate_states(model, j, mask, method="ref")
    assert out.shape == ref.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_generate_states_kernel_carries_s0():
    """Initial-state carry (train -> test continuation) through the kernel."""
    rng = np.random.default_rng(5)
    j = jnp.asarray(rng.uniform(0, 1, (3, 17)), jnp.float32)
    mask = make_mask(9, seed=1)
    s0 = jnp.asarray(rng.uniform(0, 0.4, (3, 9)), jnp.float32)
    out = generate_states(SiliconMR(), j, mask, s0=s0, method="kernel")
    ref = generate_states(SiliconMR(), j, mask, s0=s0, method="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_gram_kernel_ridge_matches_pure_jnp_solve():
    """Readout weights from kernel-accumulated (G, c) match the pure-jnp
    normal-equation solve at a well-conditioned λ."""
    rng = np.random.default_rng(7)
    t, n = 400, 24
    states = jnp.asarray(rng.uniform(0, 1, (t, n)), jnp.float32)
    y = jnp.asarray(rng.standard_normal(t), jnp.float32)

    w_kernel, _ = fit_ridge(states, y, lambdas=(1e-3,), use_kernel=True)

    x = with_bias(states)
    g, c = gram(x, y[:, None])
    g_k, c_k = gram_accumulate(x, y[:, None])
    np.testing.assert_allclose(np.asarray(g_k), np.asarray(g), rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(c_k), np.asarray(c), rtol=1e-5, atol=1e-4)

    lamp = 1e-3 * np.trace(np.asarray(g, np.float64)) / g.shape[0]
    w_np = np.linalg.solve(np.asarray(g, np.float64) + lamp * np.eye(g.shape[0]),
                           np.asarray(c, np.float64))
    np.testing.assert_allclose(np.asarray(w_kernel), w_np, rtol=2e-3, atol=2e-3)


def test_gram_solve_matches_host_readout():
    """pipeline solve_gcv (Gram path) ≈ core fit_readout (float64 host path)
    on a well-conditioned problem, λ selected by the same GCV rule."""
    rng = np.random.default_rng(11)
    t, n = 600, 16
    states = jnp.asarray(rng.uniform(0, 1, (t, n)), jnp.float32)
    w_true = rng.standard_normal(n + 1)
    y = np.asarray(with_bias(states)) @ w_true + 0.01 * rng.standard_normal(t)
    y = jnp.asarray(y, jnp.float32)

    host = fit_readout(states, np.asarray(y), l2=LAMS, method="ridge")

    x = with_bias(states)
    g, c = gram(x, y[:, None])
    w_gram, idx = solve_gcv(g, c, jnp.sum(y * y), t, LAMS)
    np.testing.assert_allclose(np.asarray(w_gram)[:, 0], np.asarray(host.w)[:, 0],
                               rtol=5e-3, atol=5e-3)

    y_host = np.asarray(host(states))
    y_gram = np.asarray(apply_readout(states, w_gram))
    np.testing.assert_allclose(y_gram, y_host, rtol=5e-3, atol=5e-3)


def test_pipeline_svd_solve_matches_host_readout():
    """Default pipeline fit (SVD of X) ≈ host float64 fit on reservoir
    states — the actual claims path (ill-conditioned, N ~ T/3)."""
    rng = np.random.default_rng(13)
    j = jnp.asarray(rng.uniform(0, 1, 360), jnp.float32)
    mask = make_mask(100, seed=1)
    states = generate_states(SiliconMR(), j, mask)
    y = jnp.asarray(rng.standard_normal(360), jnp.float32)

    lams = (1e-8, 1e-6, 1e-4, 1e-2)
    w_pipe, _ = fit_ridge(states, y, lambdas=lams)
    host = fit_readout(states, np.asarray(y), l2=lams, method="ridge")

    y_pipe = np.asarray(apply_readout(states, w_pipe))
    y_host = np.asarray(host(states))
    # same λ grid + same GCV rule; f32-vs-f64 differences stay small on
    # the *predictions* even where individual weights differ
    assert np.max(np.abs(y_pipe - y_host)) < 1e-2, np.max(np.abs(y_pipe - y_host))


def _batched_fit_inputs(b=5, t=220, n=24):
    rng = np.random.default_rng(b * t + n)
    states = jnp.asarray(rng.uniform(0, 1, (b, t, n)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((b, t)), jnp.float32)
    return states, y


def test_fit_ridge_batched_matches_per_instance_kernel_fits():
    """One batch-gridded Gram launch == B sequential kernel fits."""
    states, y = _batched_fit_inputs()
    w_b, idx_b = fit_ridge_batched(states, y, lambdas=LAMS, use_kernel=True)
    for i in range(states.shape[0]):
        w_i, idx_i = fit_ridge(states[i], y[i], lambdas=LAMS, use_kernel=True)
        np.testing.assert_allclose(np.asarray(w_b[i]), np.asarray(w_i),
                                   rtol=1e-5, atol=1e-6)
        assert int(idx_b[i]) == int(idx_i)


def test_fit_ridge_batched_kernel_vs_svd_path():
    """Gram-kernel batched fit stays close to the vmapped SVD fit on a
    well-conditioned problem (the cond(X)² gap only opens when X is near
    rank-deficient)."""
    states, y = _batched_fit_inputs()
    w_k, _ = fit_ridge_batched(states, y, lambdas=(1e-3,), use_kernel=True)
    w_s, _ = fit_ridge_batched(states, y, lambdas=(1e-3,), use_kernel=False)
    np.testing.assert_allclose(np.asarray(w_k), np.asarray(w_s), rtol=5e-3, atol=5e-3)


def test_fit_ridge_batched_single_kernel_launch():
    """The batched kernel readout is ONE pallas_call — no lax.map / scan over
    instances (the regression that motivated the batch grid dimension)."""
    states, y = _batched_fit_inputs(b=3, t=64, n=8)
    jaxpr = str(jax.make_jaxpr(
        lambda s, t_: fit_ridge_batched(s, t_, lambdas=LAMS, use_kernel=True))(states, y))
    assert jaxpr.count("pallas_call") == 1, jaxpr.count("pallas_call")
    assert "scan[" not in jaxpr and "while[" not in jaxpr
