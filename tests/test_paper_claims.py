"""Integration tests against the paper's own claims (Section V).

The paper reports *relative* numbers; these tests assert the reproduced
ordering and approximate margins on reduced datasets (full-size runs live in
benchmarks/).
"""

import numpy as np
import pytest

from repro.core import (
    DFRCAccelerator,
    DFRCConfig,
    MZISine,
    MackeyGlass,
    SiliconMR,
    SiliconMRLiteral,
    power,
    tasks,
    timing,
)


LAMS = (1e-10, 1e-8, 1e-6, 1e-4, 1e-2)  # validation-selected ridge (readout.py)


@pytest.fixture(scope="module")
def narma():
    return tasks.narma10(1200, seed=0)


def _fit_eval(cfg, ds):
    acc = DFRCAccelerator(cfg).fit(ds.inputs_train, ds.targets_train)
    return acc.evaluate_nrmse(ds.inputs_test, ds.targets_test)


@pytest.fixture(scope="module")
def narma_errors(narma):
    return {
        "mr": _fit_eval(DFRCConfig(model=SiliconMR(), n_nodes=200, washout=60,
                                   ridge_l2=LAMS), narma),
        "mg": _fit_eval(
            DFRCConfig(model=MackeyGlass(), n_nodes=200, washout=60, ridge_l2=LAMS,
                       mask_levels=(-1.0, 1.0)), narma),
        "mzi": _fit_eval(DFRCConfig(model=MZISine(), n_nodes=200, washout=60,
                                    ridge_l2=LAMS), narma),
    }


def test_narma10_all_learn(narma_errors):
    """Every accelerator beats the trivial mean predictor (NRMSE < 1)."""
    for name, e in narma_errors.items():
        assert 0 < e < 1.0, (name, e)


def test_narma10_mr_on_par_with_mg(narma_errors):
    """Paper: 'Silicon MR performs on par with Electronic (MG)' (Fig. 5)."""
    assert narma_errors["mr"] < narma_errors["mg"] * 1.15, narma_errors


def test_narma10_mr_beats_mzi(narma_errors):
    """Paper: 35% lower NRMSE than All Optical (MZI) on NARMA10 (Fig. 5)."""
    assert narma_errors["mr"] < narma_errors["mzi"] * 0.80, narma_errors


def test_literal_equations_diverge(narma):
    """DESIGN.md §7: Eq. (6-7) as printed give NRMSE = inf / huge error."""
    cfg = DFRCConfig(model=SiliconMRLiteral(gamma=0.9), n_nodes=100, washout=20)
    err = _fit_eval(cfg, narma)
    assert not np.isfinite(err) or err > 10.0, err


def test_channel_eq_ser_sane():
    """SER at 28 dB: Silicon MR decodes well above chance (paper Fig. 6)."""
    ds = tasks.channel_equalization(4000, snr_db=28.0, seed=0)
    cfg = DFRCConfig(model=SiliconMR(), n_nodes=60, washout=60, ridge_l2=LAMS, quantize=True)
    acc = DFRCAccelerator(cfg).fit(ds.inputs_train, ds.targets_train)
    ser = acc.evaluate_ser(ds.inputs_test, ds.targets_test)
    assert ser < 0.10, ser  # 4-PAM chance level is 0.75


def test_santa_fe_learns():
    """Beats the mean predictor on the (hard) Haken–Lorenz surrogate; the
    full-size run in benchmarks/ also beats the linear-AR floor."""
    ds = tasks.santa_fe(3000, train_frac=2.0 / 3.0, seed=0)
    cfg = DFRCConfig(model=SiliconMR(), n_nodes=40, washout=60, ridge_l2=LAMS)
    acc = DFRCAccelerator(cfg).fit(ds.inputs_train, ds.targets_train)
    err = acc.evaluate_nrmse(ds.inputs_test, ds.targets_test)
    assert err < 0.8, err


def test_training_time_speedups():
    """Paper Fig. 7: ~98x faster than MZI-photonic, ~93x faster than MG-electronic
    (state-collection dominated; exact ratios depend on solve-time constants)."""
    n_train = 1000
    t_mr = timing.TIMING_SILICON_MR.collection_time_s(n_train, 900)
    t_mzi = timing.TIMING_MZI.collection_time_s(n_train, 400)
    t_mg = timing.TIMING_MG.collection_time_s(n_train, 900)
    assert t_mzi / t_mr > 50          # MZI fibre spool ≫ on-chip waveguide
    assert t_mg / t_mzi > 100         # electronics ≫ photonics
    assert t_mr < 1e-3                # sub-ms state collection on-chip


def test_power_model_matches_table1():
    """Eq. (15) with Table 1 numbers: Silicon MR ≈ 126.48 mW (paper V.E),
    and the MZI accelerator draws several times more power."""
    mr = power.SILICON_MR.total_mw()
    mzi = power.ALL_OPTICAL_MZI.total_mw()
    rel = abs(mr - power.PAPER_TOTALS_MW["Silicon MR"]) / power.PAPER_TOTALS_MW["Silicon MR"]
    assert rel < 0.10, mr
    assert mzi > 2.5 * mr, (mr, mzi)


def test_mr_optimal_tau_ph():
    """Paper: τ_ph = 50 ps is the operating point; check the model is sane
    there (alpha in (0,1), bounded states)."""
    m = SiliconMR(theta_ps=50.0, tau_ph_ps=50.0)
    assert 0.5 < m.alpha < 0.7
