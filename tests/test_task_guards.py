"""NARMA10 divergence guard (ISSUE 4 satellite).

The NARMA10 recursion (Eq. 10) is not globally stable: for unlucky U[0, 0.5]
input draws the 0.05·y·Σy term wins and y escapes to inf.  Seed 83 at
n_samples = 2000 is such a draw (found by sweeping the raw recursion) — the
guard must detect it and re-draw deterministically, while every historically
convergent seed keeps its exact pre-guard stream.

Separate from test_tasks.py so it runs in hypothesis-less environments (the
offline container skips the property-based modules at collection).
"""

import numpy as np

from repro.core import tasks
from repro.core.tasks import _narma10_recursion

# Verified divergent at n_samples=2000 (+50 warmup) with default_rng(seed):
# the raw recursion escapes past the divergence bound.  If numpy's generator
# stream ever changes this constant needs re-discovery (sweep the raw
# recursion) — the determinism tests below do not depend on it.
DIVERGING_SEED = 83


def test_narma10_raw_recursion_diverges_for_known_seed():
    """The guard is protecting against something real: the unguarded
    recursion on this seed's first draw escapes to inf."""
    rng = np.random.default_rng(DIVERGING_SEED)
    i = rng.uniform(0.0, 0.5, size=2050)
    y = _narma10_recursion(i)
    assert not np.isfinite(y).all()


def test_narma10_diverging_seed_redrawn_and_finite():
    """The guarded generator redraws the diverging seed (different inputs
    than the raw first draw) and returns a bounded trajectory."""
    ds = tasks.narma10(2000, seed=DIVERGING_SEED)
    y = np.concatenate([ds.targets_train, ds.targets_test])
    i = np.concatenate([ds.inputs_train, ds.inputs_test])
    assert np.isfinite(y).all() and np.isfinite(i).all()
    assert np.abs(y).max() < 2.0
    raw_first_draw = np.random.default_rng(DIVERGING_SEED).uniform(
        0.0, 0.5, size=2050)[50:]
    assert not np.array_equal(i, raw_first_draw)


def test_narma10_redraw_is_deterministic():
    a = tasks.narma10(2000, seed=DIVERGING_SEED)
    b = tasks.narma10(2000, seed=DIVERGING_SEED)
    np.testing.assert_array_equal(a.inputs_train, b.inputs_train)
    np.testing.assert_array_equal(a.targets_test, b.targets_test)


def test_narma10_convergent_seeds_keep_historical_stream():
    """Attempt 0 is byte-identical to the pre-guard generator: convergent
    seeds (the overwhelming majority) see no change at all."""
    for seed in (0, 1, 7):
        ds = tasks.narma10(800, seed=seed)
        raw = np.random.default_rng(seed).uniform(0.0, 0.5, size=850)[50:]
        np.testing.assert_array_equal(
            np.concatenate([ds.inputs_train, ds.inputs_test]), raw)


def test_narma10_seed_sweep_all_finite():
    """The satellite's acceptance check: a seed sweep wide enough to include
    known-divergent draws (83 < 120) comes back all-finite — no silent inf
    rows poisoning a vmapped batch."""
    for seed in range(120):
        ds = tasks.narma10(2000, seed=seed)
        assert np.isfinite(ds.targets_train).all(), seed
        assert np.isfinite(ds.targets_test).all(), seed
        assert np.abs(ds.targets_train).max() < 2.0, seed
