"""Dataset generators: determinism, alignment, SNR correctness."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import tasks


def test_narma10_deterministic_and_aligned():
    a = tasks.narma10(800, seed=3)
    b = tasks.narma10(800, seed=3)
    np.testing.assert_array_equal(a.inputs_train, b.inputs_train)
    np.testing.assert_array_equal(a.targets_test, b.targets_test)
    # alignment: y[k] correlates with i[k-1] and i[k-10] (Eq. 10 structure)
    i = np.concatenate([a.inputs_train, a.inputs_test])
    y = np.concatenate([a.targets_train, a.targets_test])
    c1 = np.corrcoef(y[1:], i[:-1])[0, 1]
    c10 = np.corrcoef(y[10:], i[:-10])[0, 1]
    assert c1 > 0.3 and c10 > 0.3, (c1, c10)


def test_narma10_bounded():
    ds = tasks.narma10(2000, seed=0)
    y = np.concatenate([ds.targets_train, ds.targets_test])
    assert np.isfinite(y).all() and y.max() < 2.0


def test_santa_fe_8bit_like():
    ds = tasks.santa_fe(600, seed=1)
    vals = np.concatenate([ds.inputs_train, ds.inputs_test])
    assert vals.min() >= 0 and vals.max() <= 255
    assert np.allclose(vals, np.round(vals))
    # chaotic spiking: wide dynamic range actually used
    assert vals.std() > 20


@given(snr=st.sampled_from([12.0, 20.0, 28.0]))
@settings(max_examples=3, deadline=None)
def test_channel_eq_snr(snr):
    """Empirical SNR of the generated channel matches the requested SNR."""
    rng_free = tasks.channel_equalization(6000, snr_db=snr, seed=5)
    clean = tasks.channel_equalization(6000, snr_db=200.0, seed=5)  # ~noiseless
    noise = np.concatenate([rng_free.inputs_train, rng_free.inputs_test]) - np.concatenate(
        [clean.inputs_train, clean.inputs_test]
    )
    sig = np.concatenate([clean.inputs_train, clean.inputs_test])
    snr_emp = 10 * np.log10(np.mean(sig**2) / np.mean(noise**2))
    assert abs(snr_emp - snr) < 1.0, snr_emp


def test_quantize_symbols():
    y = np.array([-3.4, -1.2, 0.2, 1.7, 2.6])
    np.testing.assert_array_equal(tasks.quantize_symbols(y), [-3, -1, 1, 1, 3])
