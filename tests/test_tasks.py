"""Dataset generators: determinism, alignment, SNR correctness."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import metrics, tasks


def test_narma10_deterministic_and_aligned():
    a = tasks.narma10(800, seed=3)
    b = tasks.narma10(800, seed=3)
    np.testing.assert_array_equal(a.inputs_train, b.inputs_train)
    np.testing.assert_array_equal(a.targets_test, b.targets_test)
    # alignment: y[k] correlates with i[k-1] and i[k-10] (Eq. 10 structure)
    i = np.concatenate([a.inputs_train, a.inputs_test])
    y = np.concatenate([a.targets_train, a.targets_test])
    c1 = np.corrcoef(y[1:], i[:-1])[0, 1]
    c10 = np.corrcoef(y[10:], i[:-10])[0, 1]
    assert c1 > 0.3 and c10 > 0.3, (c1, c10)


def test_narma10_bounded():
    ds = tasks.narma10(2000, seed=0)
    y = np.concatenate([ds.targets_train, ds.targets_test])
    assert np.isfinite(y).all() and y.max() < 2.0


def test_santa_fe_8bit_like():
    ds = tasks.santa_fe(600, seed=1)
    vals = np.concatenate([ds.inputs_train, ds.inputs_test])
    assert vals.min() >= 0 and vals.max() <= 255
    assert np.allclose(vals, np.round(vals))
    # chaotic spiking: wide dynamic range actually used
    assert vals.std() > 20


@given(snr=st.sampled_from([12.0, 20.0, 28.0]))
@settings(max_examples=3, deadline=None)
def test_channel_eq_snr(snr):
    """Empirical SNR of the generated channel matches the requested SNR."""
    rng_free = tasks.channel_equalization(6000, snr_db=snr, seed=5)
    clean = tasks.channel_equalization(6000, snr_db=200.0, seed=5)  # ~noiseless
    noise = np.concatenate([rng_free.inputs_train, rng_free.inputs_test]) - np.concatenate(
        [clean.inputs_train, clean.inputs_test]
    )
    sig = np.concatenate([clean.inputs_train, clean.inputs_test])
    snr_emp = 10 * np.log10(np.mean(sig**2) / np.mean(noise**2))
    assert abs(snr_emp - snr) < 1.0, snr_emp


def test_quantize_symbols():
    y = np.array([-3.4, -1.2, 0.2, 1.7, 2.6])
    np.testing.assert_array_equal(tasks.quantize_symbols(y), [-3, -1, 1, 1, 3])


# ---------------------------------------------------------------------------
# Memory-capacity task suite (core/tasks + metrics.memory_capacity_score)
# ---------------------------------------------------------------------------


def test_memory_capacity_delay_alignment():
    """Target channel d IS the d-step-delayed input, across the split."""
    ds = tasks.memory_capacity(400, max_delay=6, seed=2)
    assert ds.targets_train.shape == (200, 6)
    assert ds.targets_test.shape == (200, 6)
    u = np.concatenate([ds.inputs_train, ds.inputs_test])
    y = np.concatenate([ds.targets_train, ds.targets_test])
    for d in range(1, 7):
        np.testing.assert_array_equal(y[d:, d - 1], u[:-d])
    again = tasks.memory_capacity(400, max_delay=6, seed=2)
    np.testing.assert_array_equal(ds.targets_test, again.targets_test)


@given(delay=st.integers(1, 8))
@settings(max_examples=8, deadline=None)
def test_delayed_xor_alignment(delay):
    """y(k) = u(k) XOR u(k - delay) for every in-stream k, any delay."""
    ds = tasks.delayed_xor(300, delay=delay, seed=1)
    u = np.concatenate([ds.inputs_train, ds.inputs_test])
    y = np.concatenate([ds.targets_train, ds.targets_test])
    assert set(np.unique(u)) <= {0.0, 1.0}
    assert set(np.unique(y)) <= {0.0, 1.0}
    ref = np.logical_xor(u[delay:] > 0.5, u[:-delay] > 0.5).astype(np.float64)
    np.testing.assert_array_equal(y[delay:], ref)


@given(order=st.integers(1, 4), delay=st.integers(0, 3))
@settings(max_examples=12, deadline=None)
def test_parity_alignment(order, delay):
    """y(k) = Π_m b(k - delay - m) with b = 2u - 1, for every in-stream k."""
    ds = tasks.parity(300, order=order, delay=delay, seed=4)
    u = np.concatenate([ds.inputs_train, ds.inputs_test])
    y = np.concatenate([ds.targets_train, ds.targets_test])
    assert set(np.unique(u)) <= {0.0, 1.0}
    assert set(np.unique(y)) <= {-1.0, 1.0}
    b = 2.0 * u - 1.0
    ref = np.ones_like(y)
    for m in range(order):
        ref *= np.roll(b, delay + m)
    start = delay + order          # before this, roll wraps the stream end
    np.testing.assert_array_equal(y[start:], ref[start:])


def test_memory_capacity_score_properties():
    """MC = D for perfect reconstruction, ~0 for noise; constant channels
    contribute 0 (not NaN); 1-D inputs are promoted to one channel."""
    rng = np.random.default_rng(0)
    y = rng.standard_normal((500, 8))
    assert abs(metrics.memory_capacity_score(y, y) - 8.0) < 1e-12
    noise = rng.standard_normal((500, 8))
    assert metrics.memory_capacity_score(y, noise) < 0.2
    y_const = y.copy()
    y_const[:, 0] = 3.0
    s = metrics.memory_capacity_score(y_const, y_const)
    assert np.isfinite(s) and abs(s - 7.0) < 1e-12
    assert abs(metrics.memory_capacity_score(y[:, 0], y[:, 0]) - 1.0) < 1e-12
    # r² is shift/scale invariant per channel
    assert abs(metrics.memory_capacity_score(y, 2.5 * y - 1.0) - 8.0) < 1e-9


def test_mc_suite_validation():
    with pytest.raises(ValueError):
        tasks.memory_capacity(100, max_delay=0)
    with pytest.raises(ValueError):
        tasks.delayed_xor(100, delay=0)
    with pytest.raises(ValueError):
        tasks.parity(100, order=0)
    with pytest.raises(ValueError):
        tasks.parity(100, delay=-1)
