"""Per-arch smoke tests (assignment requirement) + model invariants.

Every assigned architecture instantiates a REDUCED same-family config and
runs one forward + one train step on CPU, asserting output shapes and
finiteness.  The FULL configs are exercised only via the dry-run.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, smoke_config
from repro.models import (
    decode_step,
    forward,
    init_params,
    lm_loss,
    param_logical_axes,
    prefill,
)
from repro.optim import AdamWConfig
from repro.runtime.steps import init_train_state, train_step

ARCHS = list_archs(include_extras=True)


def _ctx(cfg, b, key):
    if not cfg.n_context_tokens:
        return None
    return jax.random.normal(key, (b, cfg.n_context_tokens, cfg.d_model), jnp.float32)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = smoke_config(arch)
    key = jax.random.PRNGKey(0)
    b, s = 2, 16
    state = init_train_state(cfg, key)
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    ctx = _ctx(cfg, b, key)

    logits, aux = forward(cfg, state["params"], toks, context=ctx)
    assert logits.shape == (b, s, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), f"{arch}: non-finite logits"

    batch = {"tokens": toks, "labels": toks}
    if ctx is not None:
        batch["context"] = ctx
    new_state, metrics = jax.jit(
        lambda st, ba: train_step(cfg, AdamWConfig(lr=1e-3), st, ba)
    )(state, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    assert int(new_state["step"]) == 1
    # params actually changed
    before = jax.tree.leaves(state["params"])[0]
    after = jax.tree.leaves(new_state["params"])[0]
    assert not np.allclose(np.asarray(before), np.asarray(after))


@pytest.mark.parametrize("arch", ["granite-8b", "jamba-v0.1-52b", "xlstm-1.3b", "reservoir_lm"])
def test_smoke_decode_matches_forward(arch):
    cfg = smoke_config(arch)
    key = jax.random.PRNGKey(1)
    b, s = 2, 10
    params = init_params(cfg, key)
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    ctx = _ctx(cfg, b, key)
    full, _ = forward(cfg, params, toks, context=ctx)
    _, cache = prefill(cfg, params, toks[:, : s - 1], max_len=s, context=ctx)
    step_logits, _ = decode_step(cfg, params, cache, toks[:, s - 1 : s])
    np.testing.assert_allclose(
        np.asarray(full[:, -1]), np.asarray(step_logits[:, 0]), atol=2e-4, rtol=2e-3
    )


@pytest.mark.parametrize("arch", ARCHS)
def test_param_axes_structure_matches_params(arch):
    """Sharding-axes pytree must mirror the params pytree exactly."""
    cfg = smoke_config(arch)
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    axes = param_logical_axes(cfg)

    flat_s = jax.tree_util.tree_flatten_with_path(
        shapes, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)
    )[0]
    flat_a = jax.tree_util.tree_flatten_with_path(
        axes, is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x)
    )[0]
    assert len(flat_s) == len(flat_a), arch
    for (ps, sh), (pa, ax) in zip(flat_s, flat_a):
        assert jax.tree_util.keystr(ps) == jax.tree_util.keystr(pa)
        assert len(sh.shape) == len(ax), (jax.tree_util.keystr(ps), sh.shape, ax)


def test_full_configs_match_assignment():
    """Exact dims from the assignment table."""
    rows = {
        "granite-8b": (36, 4096, 32, 8, 14336, 49152),
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
        "qwen3-32b": (64, 5120, 64, 8, 25600, 151936),
        "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
    }
    for arch, (L, d, h, kv, ff, v) in rows.items():
        cfg = get_config(arch)
        assert cfg.n_layers == L and cfg.d_model == d, arch
        assert cfg.n_heads == h and cfg.n_kv_heads == kv, arch
        assert cfg.d_ff == ff and cfg.vocab_size == v, arch
    for arch, e, k, ff in [("qwen3-moe-30b-a3b", 128, 8, 768),
                           ("qwen3-moe-235b-a22b", 128, 8, 1536)]:
        cfg = get_config(arch)
        assert cfg.n_experts == e and cfg.top_k == k and cfg.moe_d_ff == ff, arch
    sm = get_config("seamless-m4t-medium")
    assert sm.d_model == 1024 and sm.vocab_size == 256206 and sm.n_encoder_layers == 12


def test_moe_aux_loss_positive_and_capacity_drop():
    cfg = smoke_config("qwen3-moe-30b-a3b")
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    _, aux = forward(cfg, params, toks)
    assert float(aux) > 0.0


def test_reservoir_mixer_is_causal():
    """Perturbing x_t must not change outputs before t."""
    cfg = smoke_config("reservoir_lm")
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key)
    toks = jax.random.randint(key, (1, 12), 0, cfg.vocab_size)
    base, _ = forward(cfg, params, toks)
    toks2 = toks.at[0, 8].set((toks[0, 8] + 1) % cfg.vocab_size)
    pert, _ = forward(cfg, params, toks2)
    np.testing.assert_allclose(np.asarray(base[:, :8]), np.asarray(pert[:, :8]), atol=1e-5)
    assert not np.allclose(np.asarray(base[:, 8:]), np.asarray(pert[:, 8:]))


def test_reservoir_w_in_fixed():
    """The paper trains only the readout: w_in gets zero gradient."""
    cfg = smoke_config("reservoir_lm")
    key = jax.random.PRNGKey(4)
    params = init_params(cfg, key)
    toks = jax.random.randint(key, (2, 8), 0, cfg.vocab_size)

    def loss(p):
        lo, aux = forward(cfg, p, toks)
        return lm_loss(cfg, lo, toks, moe_aux=aux)[0]

    grads = jax.grad(loss)(params)
    g_win = grads["units"][0]["mixer/w_in"]
    g_read = grads["units"][0]["mixer/readout"]
    assert float(jnp.abs(g_win).max()) == 0.0
    assert float(jnp.abs(g_read).max()) > 0.0
