"""Dry-run HLO collective parser on synthetic HLO snippets."""

from repro.launch.dryrun import _shape_bytes, collective_bytes


HLO = """
  %ar = f32[1024,256]{1,0} all-reduce(%x), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  %ag.1 = bf16[512,128]{1,0} all-gather(%y), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
  %rs = f32[64,64]{1,0} reduce-scatter(%z), replica_groups={{0,1}}, dimensions={0}
  %cp = bf16[32,32]{1,0} collective-permute(%w), source_target_pairs={{0,1},{1,0}}
  %a2a = f32[16,16]{1,0} all-to-all(%v), replica_groups={{0,1,2,3}}, dimensions={0}
  %dot = f32[128,128]{1,0} dot(%a, %b)
"""


def test_shape_bytes():
    assert _shape_bytes("f32[1024,256]") == 1024 * 256 * 4
    assert _shape_bytes("bf16[512,128]") == 512 * 128 * 2
    assert _shape_bytes("(f32[8,8], bf16[4])") == 8 * 8 * 4 + 4 * 2


def test_collective_bytes_kinds_and_groups():
    out = collective_bytes(HLO)
    assert out["counts"] == {"all-reduce": 1, "all-gather": 1, "reduce-scatter": 1,
                             "collective-permute": 1, "all-to-all": 1}
    ar = 1024 * 256 * 4
    assert abs(out["all-reduce"] - 2 * ar * 3 / 4) < 1
    ag = 512 * 128 * 2
    assert abs(out["all-gather"] - ag * 7 / 8) < 1
    rs = 64 * 64 * 4
    assert abs(out["reduce-scatter"] - rs * 1) < 1
    assert out["collective-permute"] == 32 * 32 * 2
    assert out["total"] > 0


def test_ignores_non_collectives():
    out = collective_bytes("%dot = f32[128,128]{1,0} dot(%a, %b)\n")
    assert out["total"] == 0
