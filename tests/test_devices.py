"""Device subsystem tests (DESIGN.md §14): CMT cavity physics, calibration
parity against the paper model, and the batched design-space sweep.

Fixed-seed and grid-based throughout — this module must run on minimal
images without hypothesis; the hypothesis-generalised versions of the
split/parity invariants live in tests/test_properties.py (gracefully
skipped when hypothesis is absent, conftest.py).
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MODEL_REGISTRY, SiliconMR, make_mask, register_model, tasks
from repro.core.graph import ReservoirStage, chain
from repro.core.reservoir import generate_states
from repro.devices import (CMTSweepParams, MRCavityCMT, SweepGrid, SweepResult,
                           calibrated_twin, calibration_report, node_parity,
                           pipeline_cache_size, run_device_sweep)
from repro.pipeline import Experiment, ExperimentConfig

N = 16
K = 40
B = 3
MASK = make_mask(N, seed=3)
MR = SiliconMR()
TWIN = calibrated_twin(MR)                       # zero-power limit
CMT_HOT = calibrated_twin(MR, power_mw=1.0)      # nonlinear mechanisms on


def _stream(seed: int, k: int = K, b: int | None = B):
    rng = np.random.default_rng(seed)
    shape = (k,) if b is None else (b, k)
    return jnp.asarray(rng.uniform(0, 1, shape), jnp.float32)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_contains_cmt():
    assert MODEL_REGISTRY["mr_cavity_cmt"] is MRCavityCMT
    register_model("mr_cavity_cmt", MRCavityCMT)  # idempotent re-register
    with pytest.raises(ValueError, match="already registered"):
        register_model("mr_cavity_cmt", SiliconMR)


# ---------------------------------------------------------------------------
# calibration: the CMT low-power limit IS the paper model
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m", [1, 2, 4, 8])
def test_calibrated_twin_tick_parity_any_substeps(m):
    """The exponential integrator telescopes: the calibrated zero-power tick
    map is substep-count independent and matches SiliconMR to f32 rounding."""
    twin = calibrated_twin(MR, n_substeps=m)
    assert node_parity(MR, twin) < 1e-5


def test_calibrated_twin_requires_zero_tpa():
    with pytest.raises(ValueError, match="beta_tpa"):
        calibrated_twin(SiliconMR(beta_tpa=0.3))


def test_small_signal_gains_match():
    rep = calibration_report(MR, TWIN)
    for branch in ("charge", "discharge"):
        assert rep[branch]["max_abs_delta"] < 1e-3


def test_stream_parity_low_power():
    """Whole-stream states of the twin track SiliconMR, not just one tick."""
    j = _stream(0)
    a = generate_states(MR, j, MASK, method="ref")
    b = generate_states(TWIN, j, MASK, method="ref")
    assert float(jnp.max(jnp.abs(a - b))) < 1e-4


def test_low_power_narma_parity():
    """NRMSE-level mirror of the benchmark acceptance gate (small sizing)."""
    ds = tasks.narma10(600, seed=0)
    kw = dict(n_nodes=24, washout=40, ridge_l2=(1e-8, 1e-6),
              state_method="fast", state_noise_rel=0.0)
    r_mr = Experiment(ExperimentConfig(model=MR, **kw)).run_dataset(ds)
    r_tw = Experiment(ExperimentConfig(model=TWIN, **kw)).run_dataset(ds)
    assert abs(float(r_mr.nrmse[0]) - float(r_tw.nrmse[0])) < 2e-2


# ---------------------------------------------------------------------------
# integrator: substep convergence, path parity, chunked resume
# ---------------------------------------------------------------------------


def test_substep_convergence_with_nonlinearity_on():
    """With free carriers/thermal active the tick map depends on substep
    count; it must converge toward the fine-step limit monotonically in M."""
    g = jnp.linspace(0.0, 1.0, 7, dtype=jnp.float32)
    u, st, sp = jnp.meshgrid(g, g, g, indexing="ij")

    def tick(m):
        return dataclasses.replace(CMT_HOT, n_substeps=m).node_update(u, st, sp)

    ref = tick(64)
    errs = [float(jnp.max(jnp.abs(tick(m) - ref))) for m in (1, 4, 16)]
    assert errs[0] > errs[1] > errs[2]
    assert errs[2] < 1e-2


def test_fast_matches_ref_bitwise():
    j = _stream(1)
    a = generate_states(CMT_HOT, j, MASK, method="ref")
    b = generate_states(CMT_HOT, j, MASK, method="fast")
    assert np.array_equal(np.asarray(a), np.asarray(b))


def test_kernel_matches_ref():
    j = _stream(2)
    a = generate_states(CMT_HOT, j, MASK, method="ref")
    b = generate_states(CMT_HOT, j, MASK, method="kernel")
    assert float(jnp.max(jnp.abs(a - b))) < 1e-5


@pytest.mark.parametrize("method", ["ref", "fast", "kernel"])
def test_chunk_resume_bit_exact(method):
    """Resuming from the carried final state replays the uninterrupted scan
    exactly — the CMT adiabatic closure is a function of the carried state
    alone, so chunk boundaries are invisible."""
    j = _stream(3)
    full = generate_states(CMT_HOT, j, MASK, method=method)
    s0, out = None, []
    for lo, hi in ((0, 13), (13, 14), (14, K)):
        states, s0 = generate_states(CMT_HOT, j[:, lo:hi], MASK, s0=s0,
                                     method=method, return_final=True)
        out.append(np.asarray(states))
    assert np.array_equal(np.concatenate(out, axis=1), np.asarray(full))


# ---------------------------------------------------------------------------
# swept parameters: lanes == points, finiteness, validation
# ---------------------------------------------------------------------------


def _lane_grid():
    return CMTSweepParams(detune=jnp.asarray([-0.5, 0.0, 1.0], jnp.float32),
                          loss_scale=jnp.asarray([1.0, 1.2, 1.5], jnp.float32),
                          power=jnp.asarray([0.0, 0.5, 1.0], jnp.float32))


@pytest.mark.parametrize("method", ["ref", "fast"])
def test_swept_lanes_match_unswept_points(method):
    """Each batch lane of a dev_params run equals the dedicated model built
    at that grid point (κ pinned to the base model's calibration anchor —
    sweeping detune moves the Lorentzian, not the pump calibration)."""
    j = _stream(4)
    p = _lane_grid()
    swept = generate_states(CMT_HOT, j, MASK, method=method, dev_params=p)
    for lane in range(B):
        point = dataclasses.replace(
            CMT_HOT, detune=float(p.detune[lane]),
            loss_scale=float(p.loss_scale[lane]),
            power_mw=float(p.power[lane]),
            kappa_charge=CMT_HOT.kappa_c, kappa_discharge=CMT_HOT.kappa_d)
        ref = generate_states(point, j[lane], MASK, method=method)
        assert float(jnp.max(jnp.abs(swept[lane] - ref))) < 1e-5


def test_states_finite_over_parameter_box():
    """No NaN/inf anywhere on a (detune × loss ≥ 1 × power) box (loss < 1
    raises the loop gain above unity by construction — documented unstable)."""
    grid = SweepGrid(detune=(-2.0, 0.0, 2.0), loss_scale=(1.0, 1.5, 2.0),
                     power=(0.0, 1.0, 2.0))
    lanes = grid.lanes()
    j = _stream(5, b=grid.size)
    states = generate_states(CMT_HOT, j, MASK, method="fast", dev_params=lanes)
    assert bool(jnp.all(jnp.isfinite(states)))


def test_dev_params_scalar_leaves_broadcast():
    j = _stream(6)
    p0 = CMTSweepParams(detune=0.0, loss_scale=1.0, power=1.0)
    a = generate_states(CMT_HOT, j, MASK, method="fast", dev_params=p0)
    point = dataclasses.replace(CMT_HOT, power_mw=1.0,
                                kappa_charge=CMT_HOT.kappa_c,
                                kappa_discharge=CMT_HOT.kappa_d)
    b = generate_states(point, j, MASK, method="fast")
    assert float(jnp.max(jnp.abs(a - b))) < 1e-5


def test_dev_params_rejected_on_kernel_path():
    with pytest.raises(NotImplementedError, match="kernel"):
        generate_states(CMT_HOT, _stream(7), MASK, method="kernel",
                        dev_params=_lane_grid())


def test_experiment_dev_params_validation():
    ds = tasks.narma10(200, seed=0)
    base = dict(model=CMT_HOT, n_nodes=N, washout=20, state_noise_rel=0.0)
    args = (ds.inputs_train[None, :], ds.targets_train[None, :],
            ds.inputs_test[None, :], ds.targets_test[None, :])
    p0 = CMTSweepParams(detune=0.0, loss_scale=1.0, power=0.0)
    with pytest.raises(ValueError, match="kernel"):
        Experiment(ExperimentConfig(state_method="kernel", **base)).run(
            *args, dev_params=p0)
    topo = chain(ReservoirStage(model=CMT_HOT, n_nodes=N, mask_seed=3))
    with pytest.raises(ValueError, match="topology"):
        Experiment(ExperimentConfig(topology=topo, stream_chunk_k=16,
                                    **base)).run(*args, dev_params=p0)
    bad = CMTSweepParams(detune=jnp.zeros((2,)), loss_scale=1.0, power=0.0)
    with pytest.raises(ValueError, match="batch lane"):
        Experiment(ExperimentConfig(**base)).run(*args, dev_params=bad)


# ---------------------------------------------------------------------------
# sweep driver: grid algebra, one-program execution, no-retrace
# ---------------------------------------------------------------------------


def test_sweep_grid_lanes_fold_roundtrip():
    grid = SweepGrid(detune=(-1.0, 1.0), loss_scale=(1.0, 1.5, 2.0),
                     power=(0.0, 1.0))
    assert grid.shape == (2, 3, 2) and grid.size == 12
    lanes = grid.lanes()
    folded = grid.fold(lanes.detune)
    for i, d in enumerate(grid.detune):
        assert np.all(folded[i] == d)
    idx = (1, 2, 0)
    flat = np.ravel_multi_index(idx, grid.shape)
    assert grid.point(idx) == {"detune": float(lanes.detune[flat]),
                               "loss_scale": float(lanes.loss_scale[flat]),
                               "power": float(lanes.power[flat])}


def test_stable_region_summary():
    grid = SweepGrid(detune=(0.0, 1.0), loss_scale=(1.0,), power=(0.0, 1.0))
    nrmse = np.array([[[0.2, 0.9]], [[np.inf, 0.3]]])
    res = SweepResult(grid=grid, nrmse=nrmse,
                      ser=np.zeros_like(nrmse), lam=np.zeros_like(nrmse))
    region = res.stable_region(nrmse_max=0.4)
    assert region["summary"]["n_stable"] == 2
    assert region["summary"]["best_point"]["nrmse"] == 0.2
    assert region["map"].tolist() == [[[True, False]], [[False, True]]]
    assert region["summary"]["stable_detune"] == [0.0, 1.0]
    assert region["summary"]["stable_power"] == [0.0, 1.0]


def test_run_device_sweep_one_program_no_retrace():
    """The whole map from one compiled program: a second sweep with NEW grid
    values (same shapes) must leave the pipeline's jit cache untouched."""
    ds = tasks.narma10(300, seed=0)
    grid = SweepGrid(detune=(-0.5, 0.5), loss_scale=(1.0,), power=(0.0, 1.0))
    res = run_device_sweep(TWIN, grid, ds, n_nodes=N, washout=20,
                           stream_chunk_k=32, ridge_l2=(1e-6, 1e-4))
    assert res.nrmse.shape == grid.shape
    assert np.all(np.isfinite(res.nrmse))
    c0 = pipeline_cache_size()
    shifted = SweepGrid(detune=(-0.25, 0.75), loss_scale=(1.1,),
                        power=(0.25, 1.25))
    res2 = run_device_sweep(TWIN, shifted, ds, n_nodes=N, washout=20,
                            stream_chunk_k=32, ridge_l2=(1e-6, 1e-4))
    assert pipeline_cache_size() == c0
    assert not np.array_equal(res.nrmse, res2.nrmse)


# ---------------------------------------------------------------------------
# composition: the CMT model rides the reservoir-graph stages unchanged
# ---------------------------------------------------------------------------


def test_cmt_in_composed_graph():
    topo = chain(ReservoirStage(model=CMT_HOT, n_nodes=12, mask_seed=3,
                                link="sin2", link_gain=0.28),
                 ReservoirStage(model=TWIN, n_nodes=4, mask_seed=10))
    ds = tasks.narma10(300, seed=0)
    cfg = ExperimentConfig(model=CMT_HOT, n_nodes=topo.width, washout=20,
                           ridge_l2=(1e-6,), topology=topo, stream_chunk_k=32,
                           state_method="fast", state_noise_rel=0.0)
    res = Experiment(cfg).run_dataset(ds)
    assert np.isfinite(res.nrmse).all()
