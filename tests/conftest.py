"""Shared test configuration.

Some property-based test modules need ``hypothesis`` (requirements-dev.txt).
When it is absent (minimal CI images, the offline container) those modules
fail at *collection* with ModuleNotFoundError, wedging the whole run — so we
gracefully exclude them here and surface one clear warning instead.
"""

from __future__ import annotations

import importlib.util
import warnings

_HYPOTHESIS_MODULES = [
    "test_attention.py",
    "test_masking.py",
    "test_nonlinear.py",
    "test_properties.py",
    "test_readout.py",
    "test_tasks.py",
]

collect_ignore = []
if importlib.util.find_spec("hypothesis") is None:
    collect_ignore += _HYPOTHESIS_MODULES
    warnings.warn(
        "hypothesis is not installed — skipping property-based test modules "
        f"{_HYPOTHESIS_MODULES}; `pip install -r requirements-dev.txt` to run them.",
        stacklevel=1,
    )


def stack_datasets(datasets):
    """Equal-shape core.tasks Datasets -> (tr_in, tr_tg, te_in, te_tg) stacks
    with the instance axis leading — shared by the pipeline/streaming/WDM
    test modules (same contract as benchmarks/common.stack_datasets, kept
    separate so the test suite has no import-path dependency on the
    benchmarks package)."""
    import numpy as np

    return tuple(np.stack([getattr(d, f) for d in datasets])
                 for f in ("inputs_train", "targets_train",
                           "inputs_test", "targets_test"))
