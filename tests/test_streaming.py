"""Streaming fused reservoir -> readout path (DESIGN.md §8).

Guards the tentpole property of the streaming pipeline: the full [B, T, N]
state tensor never exists in HBM — the fit is ONE ``lax.scan`` over K-chunks
whose largest live state block is the chunk itself — while the numbers stay
at parity with the materialized kernel path (noise off) and the
diagonal-noise mode stays within its own pinned thresholds.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import stack_datasets as _stack
from repro.analysis import (MaxScans, NoStateTensor, Program, check_rules,
                            state_tensor_bytes, trace_jaxpr)
from repro.core import SiliconMR, make_mask, tasks
from repro.core.reservoir import generate_states
from repro.kernels.dfr_scan import padded_lanes
from repro.pipeline import (Experiment, ExperimentConfig, channel_states,
                            fit_ridge_batched, fit_ridge_streaming)

LAMS = (1e-8, 1e-6, 1e-4)


@pytest.fixture(scope="module")
def narma_batch():
    return _stack([tasks.narma10(720, seed=s) for s in range(4)])


def _base_cfg(**kw):
    base = dict(model=SiliconMR(), n_nodes=32, washout=40, ridge_l2=LAMS,
                state_noise_rel=0.0, state_method="kernel",
                readout_use_kernel=True)
    base.update(kw)
    return ExperimentConfig(**base)


# ---------------------------------------------------------------------------
# Fit-level parity: streamed == materialized kernel fit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("use_kernel", [True, False], ids=["gram-kernel", "gram-jnp"])
def test_fit_ridge_streaming_matches_materialized(use_kernel):
    """Chunked fit ≈ materialized Gram fit (same λ choice, same s_end), with
    the end-of-stream state exact even when K % chunk_k != 0."""
    rng = np.random.default_rng(5)
    model = SiliconMR()
    b, k, n, w0 = 3, 200, 24, 30
    j = jnp.asarray(rng.uniform(0, 1, (b, k)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((b, k)), jnp.float32)
    mask = make_mask(n, seed=1)

    st = generate_states(model, j, mask, method="kernel")
    w_m, idx_m = fit_ridge_batched(st[:, w0:], y[:, w0:], lambdas=LAMS,
                                   use_kernel=True)
    for chunk in (64, 72):  # 200 % 72 != 0 exercises the padded tail
        w_s, idx_s, s_end = fit_ridge_streaming(
            model, mask, j, y, washout=w0, chunk_k=chunk, lambdas=LAMS,
            state_method="kernel", use_kernel=use_kernel)
        np.testing.assert_array_equal(np.asarray(s_end),
                                      np.asarray(st[:, -1, :]))
        assert np.array_equal(np.asarray(idx_s), np.asarray(idx_m))
        # weights agree to f32 Gram-association tolerance (the two paths sum
        # the same products in different tile orders)
        np.testing.assert_allclose(np.asarray(w_s), np.asarray(w_m),
                                   atol=0.1, rtol=0.1)


def test_fit_ridge_streaming_rejects_short_stream():
    model = SiliconMR()
    mask = make_mask(8, seed=1)
    j = jnp.zeros((2, 30), jnp.float32)
    with pytest.raises(ValueError, match="washout"):
        fit_ridge_streaming(model, mask, j, jnp.zeros((2, 30)), washout=40,
                            chunk_k=16, lambdas=(1e-6,))


# ---------------------------------------------------------------------------
# End-to-end parity through Experiment.run
# ---------------------------------------------------------------------------


def test_streaming_experiment_parity(narma_batch):
    """Streamed Experiment == materialized kernel-path Experiment: NRMSE and
    SER within 1e-3, λ selection identical (noise off, tile-aligned chunk —
    the acceptance bar of the streaming PR)."""
    res_m = Experiment(_base_cfg()).run(*narma_batch)
    res_s = Experiment(_base_cfg(stream_chunk_k=128)).run(*narma_batch)
    assert np.max(np.abs(res_s.nrmse - res_m.nrmse)) <= 1e-3, (
        res_s.nrmse, res_m.nrmse)
    assert np.max(np.abs(res_s.ser - res_m.ser)) <= 1e-3
    np.testing.assert_array_equal(res_s.lam, res_m.lam)
    assert res_s.y_pred.shape == res_m.y_pred.shape


def test_streaming_experiment_jnp_state_method(narma_batch):
    """The chunk scan also runs with the jnp reservoir ('fast') + jnp Gram —
    streaming is a pipeline property, not a kernel-only mode."""
    cfg = _base_cfg(stream_chunk_k=128)
    cfg = dataclasses.replace(cfg, state_method="fast", readout_use_kernel=False)
    res_s = Experiment(cfg).run(*narma_batch)
    res_m = Experiment(dataclasses.replace(
        _base_cfg(), state_method="fast", readout_use_kernel=True)).run(*narma_batch)
    assert np.max(np.abs(res_s.nrmse - res_m.nrmse)) <= 2e-3


def test_streaming_multichannel(narma_batch):
    """C = 2 output channels through the streamed fit + streamed eval."""
    tr_in, tr_tg, te_in, te_tg = narma_batch

    def two_ch(tg):
        return np.stack([tg, np.roll(tg, 1, axis=-1)], axis=-1)

    cfg = _base_cfg(stream_chunk_k=128, ridge_l2=(1e-4,))
    res1 = Experiment(cfg).run(*narma_batch)
    res2 = Experiment(cfg).run(tr_in, two_ch(tr_tg), te_in, two_ch(te_tg))
    b, t_test = res1.y_pred.shape
    assert res2.y_pred.shape == (b, t_test, 2)
    assert res2.readout_w.shape == (b, cfg.n_nodes + 1, 2)
    np.testing.assert_allclose(res2.y_pred[..., 0], res1.y_pred, atol=1e-5)
    assert np.all(np.isfinite(res2.nrmse))


# ---------------------------------------------------------------------------
# Diagonal noise mode (noise-as-Tikhonov)
# ---------------------------------------------------------------------------


def test_streaming_diagonal_noise_regression(narma_batch):
    """σ²·T·I-regularised streamed fit stays within its own pinned NRMSE band
    and close to the materialized sampled-noise fit (same σ rule, noise in
    expectation instead of one draw)."""
    cfg_s = dataclasses.replace(_base_cfg(stream_chunk_k=128),
                                state_noise_rel=0.003,
                                state_noise_mode="diagonal")
    cfg_m = dataclasses.replace(_base_cfg(), state_noise_rel=0.003)
    res_s = Experiment(cfg_s).run(*narma_batch)
    res_m = Experiment(cfg_m).run(*narma_batch)
    assert np.all(res_s.nrmse < 0.85), res_s.nrmse
    assert np.all(res_s.nrmse > 0.2), res_s.nrmse
    # expectation-vs-draw: same regularisation scale, so the two fits land in
    # the same band (spread dominated by the single sampled draw)
    assert np.max(np.abs(res_s.nrmse - res_m.nrmse)) < 0.1, (
        res_s.nrmse, res_m.nrmse)


def test_noise_mode_validation():
    with pytest.raises(ValueError, match="diagonal"):
        _base_cfg(stream_chunk_k=64, state_noise_rel=0.003)  # sampled + stream
    with pytest.raises(ValueError, match="streaming"):
        ExperimentConfig(state_noise_rel=0.003, state_noise_mode="diagonal")
    with pytest.raises(ValueError, match="state_noise_mode"):
        ExperimentConfig(state_noise_mode="bogus")
    # noise off: mode is irrelevant on both routes
    _base_cfg(stream_chunk_k=64)
    ExperimentConfig(state_noise_rel=0.0, state_noise_mode="diagonal")


# ---------------------------------------------------------------------------
# Jaxpr guard: the memory property itself
# ---------------------------------------------------------------------------


def test_streaming_fit_jaxpr_has_no_full_t_state_tensor():
    """Extends the PR 2 jaxpr guard: the streaming fit lowers to exactly ONE
    lax.scan over chunks, and NO intermediate in the whole program (scan body
    included) has [*, T, N]-like shape — the state tensor the tentpole kills.
    The largest live state block is the lane-padded chunk."""
    model = SiliconMR()
    b, k, n, w0, chunk = 4, 256, 24, 40, 64
    mask = make_mask(n, seed=1)
    j = jnp.zeros((b, k), jnp.float32)
    y = jnp.zeros((b, k), jnp.float32)

    prog = Program(
        lambda jj, yy: fit_ridge_streaming(model, mask, jj, yy, washout=w0,
                                           chunk_k=chunk, lambdas=(1e-6,),
                                           state_method="kernel",
                                           use_kernel=True), (j, y))
    # peak chunk block vs the lane/feature-padded chunk budget
    fp = -(-(n + 1) // 128) * 128
    chunk_budget = padded_lanes(b) * chunk * fp * 4
    viols = check_rules(prog, [
        MaxScans(1),
        NoStateTensor(k, b * k * n, what="full-stream tensor"),
        NoStateTensor(chunk, b * chunk * n, max_bytes=2 * chunk_budget,
                      what="chunk block"),
    ])
    assert not viols, [str(v) for v in viols]
    peak_chunk = state_tensor_bytes(prog.closed_jaxpr, chunk, b * chunk * n)
    assert 0 < peak_chunk <= 2 * chunk_budget, (peak_chunk, chunk_budget)

    # sanity: the materialized fit DOES carry the full-T state tensor
    def fit_mat(jj, yy):
        st = generate_states(model, jj, mask, method="kernel")
        return fit_ridge_batched(st[:, w0:], yy[:, w0:], lambdas=(1e-6,),
                                 use_kernel=True)

    cj_m = trace_jaxpr(fit_mat, j, y)
    assert state_tensor_bytes(cj_m, k, b * k * n) >= b * k * n * 4


def test_streaming_run_pipeline_jaxpr(narma_batch):
    """The whole Experiment streaming program (fit + eval) holds no full-T
    state tensor for either the train or the test stream."""
    tr_in, tr_tg, te_in, te_tg = narma_batch
    cfg = _base_cfg(stream_chunk_k=128)
    from repro.pipeline.experiment import _run_pipeline

    mask = Experiment(cfg).mask
    prog = Program(
        lambda a, b_, c, d: _run_pipeline(cfg, mask, a, b_, c, d),
        (jnp.asarray(tr_in, jnp.float32), jnp.asarray(tr_tg, jnp.float32),
         jnp.asarray(te_in, jnp.float32), jnp.asarray(te_tg, jnp.float32)))
    b = tr_in.shape[0]
    viols = check_rules(prog, [
        NoStateTensor(t_len, b * t_len * cfg.n_nodes)
        for t_len in (tr_in.shape[1], te_in.shape[1])])
    assert not viols, [str(v) for v in viols]


# ---------------------------------------------------------------------------
# Metrics-only evaluation (collect_y_pred=False)
# ---------------------------------------------------------------------------


def test_streaming_metrics_only_matches_collected(narma_batch):
    """collect_y_pred=False returns y_pred=None with identical metrics — the
    accumulators, not the stacked predictions, are the source of truth."""
    res = Experiment(_base_cfg(stream_chunk_k=128)).run(*narma_batch)
    res_nc = Experiment(_base_cfg(stream_chunk_k=128,
                                  collect_y_pred=False)).run(*narma_batch)
    assert res_nc.y_pred is None
    assert res_nc.batch == res.batch
    np.testing.assert_array_equal(res_nc.nrmse, res.nrmse)
    np.testing.assert_array_equal(res_nc.ser, res.ser)
    np.testing.assert_array_equal(res_nc.lam, res.lam)
    np.testing.assert_array_equal(res_nc.readout_w, res.readout_w)


def test_streaming_metrics_large_mean_target(narma_batch):
    """The in-scan variance accumulator is *shifted* by the stream's first
    sample: a target riding a large DC offset (mean ≫ std) must not lose
    its variance to f32 single-pass cancellation — naive E[y²]−E[y]² at
    offset 200 is wrong by O(100%) (or clamps to zero, exploding NRMSE
    through the VAR_EPS floor).  The gold value is the host float64 metric
    evaluated on the very predictions the streamed run emitted, so fit
    degradation at the offset (a separate f32-conditioning story) cancels
    out of the comparison."""
    from repro.core import metrics

    tr_in, tr_tg, te_in, te_tg = narma_batch
    off = 200.0
    res_off = Experiment(_base_cfg(stream_chunk_k=128)).run(
        tr_in, tr_tg + off, te_in, te_tg + off)
    assert np.all(np.isfinite(res_off.nrmse))
    for i in range(te_tg.shape[0]):
        host = metrics.nrmse(te_tg[i] + off, res_off.y_pred[i])
        assert abs(res_off.nrmse[i] - host) / host < 0.02, (
            i, res_off.nrmse[i], host)


def test_streaming_metrics_only_jaxpr_no_prediction_block(narma_batch):
    """Extends the memory gate to the prediction stream (ISSUE 4 satellite):
    with collect_y_pred=False the chunked evaluation stacks nothing — no
    [B, T_test, C] block exists in the program, while the default
    (collect_y_pred=True) provably carries one.  C = 2 target channels make
    the prediction block distinguishable from the O(B·T) input streams."""
    tr_in, tr_tg, te_in, te_tg = narma_batch

    def two_ch(tg):
        return np.stack([tg, np.roll(tg, 1, axis=-1)], axis=-1)

    from repro.pipeline.experiment import _run_pipeline

    b, t_test = te_in.shape
    c = 2
    args = (jnp.asarray(tr_in, jnp.float32),
            jnp.asarray(two_ch(tr_tg), jnp.float32),
            jnp.asarray(te_in, jnp.float32),
            jnp.asarray(two_ch(te_tg), jnp.float32))
    for collect, expect_block in ((False, False), (True, True)):
        cfg = _base_cfg(stream_chunk_k=128, collect_y_pred=collect)
        mask = Experiment(cfg).mask
        cj = trace_jaxpr(
            lambda a, b_, c_, d: _run_pipeline(cfg, mask, a, b_, c_, d), *args)
        pred_bytes = state_tensor_bytes(cj, t_test, b * t_test * c)
        assert (pred_bytes > 0) == expect_block, (collect, pred_bytes)
        # the state-tensor property holds in both modes
        assert state_tensor_bytes(cj, t_test, b * t_test * cfg.n_nodes) == 0


def test_streaming_metrics_zero_variance_targets(narma_batch):
    """Constant test targets after washout: the shifted in-scan moments are
    identically zero, so var clamps to 0 and NRMSE must collapse to the
    VAR_EPS-floored convention of the host metric — finite, not NaN (an
    unclamped E[y²]−E[y]² can go eps-negative and NaN through sqrt), and
    bitwise identical between metrics-only and collected modes."""
    from repro.core.metrics import VAR_EPS

    tr_in, tr_tg, te_in, te_tg = narma_batch
    const = np.full_like(te_tg, 0.6)
    res = Experiment(_base_cfg(stream_chunk_k=128)).run(
        tr_in, tr_tg, te_in, const)
    res_nc = Experiment(_base_cfg(stream_chunk_k=128,
                                  collect_y_pred=False)).run(
        tr_in, tr_tg, te_in, const)
    assert res_nc.y_pred is None
    assert np.all(np.isfinite(res.nrmse))
    np.testing.assert_array_equal(res_nc.nrmse, res.nrmse)
    np.testing.assert_array_equal(res_nc.ser, res.ser)
    # gold: var == 0 → NRMSE = sqrt(mse / VAR_EPS), from the very
    # predictions the streamed run emitted (f64 host arithmetic)
    for i in range(te_tg.shape[0]):
        mse = np.mean((res.y_pred[i].astype(np.float64) - 0.6) ** 2)
        np.testing.assert_allclose(res.nrmse[i], np.sqrt(mse / VAR_EPS),
                                   rtol=1e-3)


def test_streaming_metrics_channel_mean_nrmse_under_chunking(narma_batch):
    """C = 2 channels with ~1600× variance mismatch through a ragged chunk
    grid: the reported NRMSE must be the per-channel-normalised mean (each
    channel against its OWN in-scan variance), not a pooled T×C reduction —
    pooling would let the offset-dominated channel mask the other."""
    from repro.core.metrics import VAR_EPS

    tr_in, tr_tg, te_in, te_tg = narma_batch

    def two_ch(tg):
        return np.stack([tg, 40.0 * tg + 7.0], axis=-1)

    cfg = _base_cfg(stream_chunk_k=96, ridge_l2=(1e-4,))  # t_test % 96 != 0
    assert te_in.shape[1] % 96 != 0
    res = Experiment(cfg).run(tr_in, two_ch(tr_tg), te_in, two_ch(te_tg))
    res_nc = Experiment(dataclasses.replace(cfg, collect_y_pred=False)).run(
        tr_in, two_ch(tr_tg), te_in, two_ch(te_tg))
    np.testing.assert_array_equal(res_nc.nrmse, res.nrmse)
    np.testing.assert_array_equal(res_nc.ser, res.ser)

    y = two_ch(te_tg).astype(np.float64)
    yp = res.y_pred.astype(np.float64)
    mse = np.mean((yp - y) ** 2, axis=1)                  # [B, C]
    var = np.var(y, axis=1)                               # [B, C]
    gold = np.mean(np.sqrt(mse / (var + VAR_EPS)), axis=-1)
    np.testing.assert_allclose(res.nrmse, gold, rtol=1e-3)
    # a pooled T×C normalisation (variance dominated by the inter-channel
    # offset) would report a number several times smaller
    pooled = np.sqrt(np.mean((yp - y) ** 2, axis=(1, 2))
                     / (np.var(y, axis=(1, 2)) + VAR_EPS))
    assert np.all(res.nrmse > 2.0 * pooled), (res.nrmse, pooled)


def test_streaming_ser_ignores_padded_tail():
    """t_test = 129 with chunk_k = 128: the final eval chunk is 127/128
    padding.  Padded rows (zero targets, garbage predictions) must add ZERO
    symbol mismatches, and the SER denominator must be t_test, not the
    padded stream length.  A bias-only readout pins ŷ ≡ 2 (→ symbol 1)
    everywhere — padding rows would quantize 0 → −1 ≠ 1 and leak ~0.98 into
    the SER if the valid mask were dropped."""
    from repro.pipeline.experiment import _eval_streaming, _streaming_metrics

    b, n, t_test = 2, 8, 129
    cfg = _base_cfg(n_nodes=n, stream_chunk_k=128, collect_y_pred=False,
                    state_method="fast", readout_use_kernel=False)
    mask = make_mask(n, seed=2)
    rng = np.random.default_rng(0)
    j_te = jnp.asarray(rng.uniform(0, 1, (b, t_test)), jnp.float32)
    w_fit = jnp.zeros((b, n + 1, 1), jnp.float32).at[:, -1, 0].set(2.0)
    s0 = jnp.zeros((b, n), jnp.float32)
    for tgt, want in ((1.0, 0.0), (-3.0, 1.0)):
        te_tg3 = jnp.full((b, t_test, 1), tgt, jnp.float32)
        y_raw, acc = _eval_streaming(cfg, mask, j_te, te_tg3, w_fit, s0)
        assert y_raw is None
        nrmse, ser = _streaming_metrics(acc, t_test, channel_axis=False)
        np.testing.assert_array_equal(np.asarray(ser),
                                      np.full((b,), want, np.float32))
        assert np.all(np.isfinite(np.asarray(nrmse)))


# ---------------------------------------------------------------------------
# channel_states on the kernel path (per-lane masks)
# ---------------------------------------------------------------------------


def test_channel_states_kernel_matches_ref():
    model = SiliconMR()
    rng = np.random.default_rng(7)
    r, k, n = 4, 30, 12
    j = jnp.asarray(rng.uniform(0, 1, (r, k)), jnp.float32)
    masks = jnp.stack([make_mask(n, seed=40 + i) for i in range(r)])
    st_k = channel_states(model, j, masks, method="kernel")
    st_r = channel_states(model, j, masks, method="ref")
    assert st_k.shape == (r, k, n)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_r), atol=1e-5)


def test_channel_states_kernel_carries_s0():
    model = SiliconMR()
    rng = np.random.default_rng(9)
    r, k, n = 3, 17, 9
    j = jnp.asarray(rng.uniform(0, 1, (r, k)), jnp.float32)
    masks = jnp.stack([make_mask(n, seed=50 + i) for i in range(r)])
    full = channel_states(model, j, masks, method="kernel")
    st1 = channel_states(model, j[:, :8], masks, method="kernel")
    st2 = channel_states(model, j[:, 8:], masks, s0=st1[:, -1, :],
                         method="kernel")
    np.testing.assert_array_equal(
        np.asarray(jnp.concatenate([st1, st2], axis=1)), np.asarray(full))
