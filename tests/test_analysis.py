"""The static-analysis subsystem itself (DESIGN.md §11).

Three layers, mirroring the package: the hardened walker (descent through
wrapper primitives + provenance paths), the rule engine (each built-in rule
catches a deliberately violating synthetic mini-program, with the right
provenance), and the CLI gate (exit codes + report).  These are the tests
of the *checker* — the repo's real programs are checked by the registry in
CI and by the migrated guards in test_streaming/test_wdm_streaming/
test_serving.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (DonationHonored, MaxPallasCalls, MaxScans,
                            NoDtypeAbove, NoHostCallback, NoSilentUpcast,
                            NoStateTensor, Program, VmemBudget,
                            intermediate_records, state_tensor_bytes,
                            state_tensor_records, trace_jaxpr)
from repro.analysis.walker import _sub_jaxprs

# ---------------------------------------------------------------------------
# walker: descent + provenance
# ---------------------------------------------------------------------------


def _wrapped_programs():
    """One program per wrapper primitive, each hiding a distinctive
    [8, 8] @ [8, 8] matmul inside the wrapped sub-jaxpr."""

    @jax.custom_jvp
    def f_jvp_wrapped(x):
        return (x @ x.T).sum()

    @f_jvp_wrapped.defjvp
    def _f_jvp(primals, tangents):
        return f_jvp_wrapped(primals[0]), jnp.zeros(())

    @jax.custom_vjp
    def f_vjp_wrapped(x):
        return (x @ x.T).sum()

    f_vjp_wrapped.defvjp(lambda x: (f_vjp_wrapped(x), x),
                         lambda res, ct: (jnp.zeros_like(res),))

    return {
        "custom_jvp_call": f_jvp_wrapped,
        "custom_vjp_call": f_vjp_wrapped,
        "while": lambda x: jax.lax.while_loop(
            lambda c: c[1] < 2, lambda c: (c[0] @ c[0].T, c[1] + 1),
            (x, 0))[0].sum(),
        "cond": lambda x: jax.lax.cond(
            x[0, 0] > 0, lambda v: (v @ v.T).sum(), lambda v: v.sum(), x),
        "remat": jax.checkpoint(lambda x: (x @ x.T).sum()),
    }


@pytest.mark.parametrize("wrapper", sorted(_wrapped_programs()))
def test_walker_descends_wrapper_subjaxprs(wrapper):
    """Sub-jaxprs behind custom-derivative / control-flow wrappers are
    walked, and the matmul inside carries the wrapper in its provenance
    path — the pre-hardening walker could not express (or in deeper
    nestings, even find) this."""
    fn = _wrapped_programs()[wrapper]
    cj = trace_jaxpr(fn, jnp.ones((8, 8), jnp.float32))
    hits = [r for r in intermediate_records(cj)
            if r.prim == "dot_general" and r.shape == (8, 8)]
    assert hits, f"matmul inside {wrapper} not found"
    assert any(r.path for r in hits), [r.where() for r in hits]
    # the path names the wrapper (jax spells custom_vjp as *_jaxpr)
    assert any(wrapper.split("_")[0] in p for r in hits for p in r.path), (
        wrapper, [r.where() for r in hits])


def test_sub_jaxprs_finds_deeply_nested_containers():
    """Jaxprs nested in tuples-of-tuples and dicts inside eqn params are
    found — the old single-level flatten (the closed_call-style blind spot)
    missed everything below the first container level."""
    cj = trace_jaxpr(lambda x: x * 2.0, jnp.ones((2,), jnp.float32))
    params = {
        "deep_tuple": (((cj,),),),
        "in_dict": {"k": cj.jaxpr},
        "scalar": 3,
        "mixed": [1, {"j": (cj,)}, "s"],
    }
    found = list(_sub_jaxprs(params))
    assert len(found) == 3
    assert all(f is cj.jaxpr for f in found)


# ---------------------------------------------------------------------------
# state_tensor_bytes: false-positive disambiguation (ISSUE 7 satellite)
# ---------------------------------------------------------------------------


def test_state_tensor_benign_template_exempts_axis_collision():
    """An unrelated axis numerically equal to t_len (here: a [B, F, F] Gram
    with F == chunk length) no longer false-positives once the structurally
    known shape is declared benign — while a genuine state tensor carrying
    the same axis value is still flagged, with provenance."""
    b, t, f = 2, 64, 64                    # F == t: the collision case

    def prog(x):                           # x: [B, t, F] chunk features
        gram = jnp.einsum("btf,btg->bfg", x, x)      # [B, F, F], F == t
        state = jnp.cumsum(x[..., :8], axis=1)       # [B, t, 8]: true state
        return gram.sum() + state.sum()

    cj = trace_jaxpr(prog, jnp.ones((b, t, f), jnp.float32))
    floor = b * t * 8
    # naive check flags the Gram (axis collision)
    assert state_tensor_bytes(cj, t, floor) >= b * f * f * 4
    # template-exempted check still flags the genuine [B, t, 8] tensor ...
    recs = state_tensor_records(cj, t, floor, benign_shapes=((b, f, f),))
    assert recs and all(sorted(r.shape) != sorted((b, f, f)) for r in recs)
    assert any(r.shape == (b, t, 8) for r in recs)
    assert all(isinstance(r.where(), str) and r.where() for r in recs)
    # ... and a fully-benign program comes out clean
    cj_g = trace_jaxpr(lambda x: jnp.einsum("btf,btg->bfg", x, x).sum(),
                       jnp.ones((b, t, f), jnp.float32))
    assert state_tensor_bytes(cj_g, t, floor,
                              benign_shapes=((b, f, f),)) == 0


# ---------------------------------------------------------------------------
# rule engine: each rule catches its synthetic violation, with provenance
# ---------------------------------------------------------------------------


def test_rule_no_state_tensor_flags_materialized_scan_output():
    b, n, t = 2, 16, 50

    def prog(x):                           # stacks [t, B, N]: the tensor
        def step(s, u):                    # the streaming path must never
            s = jnp.tanh(s + u[:, None])   # materialize
            return s, s
        _, ys = jax.lax.scan(step, jnp.zeros((b, n)), x)
        return ys.sum()

    prog_ok_src = lambda x: jax.lax.scan(
        lambda s, u: (jnp.tanh(s + u[:, None]), u.sum()),
        jnp.zeros((b, n)), x)[1].sum()

    rule = NoStateTensor(t, b * t * n)
    viols = rule.check(Program(prog, (jnp.ones((t, b), jnp.float32),)))
    assert viols
    assert any(v.shape == (t, b, n) and v.path[-1] == "scan" for v in viols)
    assert not rule.check(Program(prog_ok_src,
                                  (jnp.ones((t, b), jnp.float32),)))


def test_rule_max_scans_reports_paths():
    def prog(x):
        a = jax.lax.scan(lambda c, u: (c + u, c), 0.0, x)[0]
        b = jax.lax.scan(lambda c, u: (c * u, c), 1.0, x)[0]
        return a + b

    viols = MaxScans(1).check(Program(prog, (jnp.ones((8,), jnp.float32),)))
    assert len(viols) == 1 and "2 scan eqns" in viols[0].message


def test_rule_max_pallas_calls():
    from repro.core import SiliconMR, make_mask
    from repro.kernels.dfr_scan import dfr_scan
    model, mask = SiliconMR(), make_mask(8, seed=0)
    j, s0 = jnp.zeros((2, 16), jnp.float32), jnp.zeros((2, 8), jnp.float32)
    prog = Program(lambda jj, s: dfr_scan(model, jj, mask, s,
                                          interpret=True).sum(), (j, s0))
    assert not MaxPallasCalls(1).check(prog)
    viols = MaxPallasCalls(0).check(prog)
    assert len(viols) == 1 and "pallas_call" in viols[0].message


def test_rule_no_dtype_above_catches_f64_literal():
    """An f64 leak via a float64 literal (only expressible with x64 on —
    with x64 off jax weakens the literal and the program stays clean)."""
    def prog(x):
        return x * np.float64(2.0) + jnp.asarray(1.0, jnp.float64)

    with jax.experimental.enable_x64():
        viols = NoDtypeAbove("float32").check(
            Program(prog, (jnp.ones((4,), jnp.float32),)))
    assert viols and all(v.dtype == "float64" for v in viols)

    # same program under default x64-off config: weak literal, no violation
    assert not NoDtypeAbove("float32").check(
        Program(prog, (jnp.ones((4,), jnp.float32),)))


def test_rule_no_host_callback_with_provenance():
    def leaf(x):
        return jax.pure_callback(
            lambda v: v, jax.ShapeDtypeStruct(x.shape, x.dtype), x)

    def prog(x):                           # callback *inside* a scan body
        return jax.lax.scan(lambda c, u: (c + leaf(u), c), 0.0, x)[0]

    viols = NoHostCallback().check(
        Program(prog, (jnp.ones((4,), jnp.float32),)))
    assert viols and viols[0].path[-1] == "pure_callback"
    assert "scan" in viols[0].path

    def prog_print(x):
        jax.debug.print("x={x}", x=x.sum())
        return x * 2

    viols = NoHostCallback().check(
        Program(prog_print, (jnp.ones((4,), jnp.float32),)))
    assert viols and "debug_callback" in viols[0].message


def test_rule_donation_honored_detects_dropped_alias():
    x = jnp.ones((8, 8), jnp.float32)
    # donated and shape-compatible: alias survives lowering
    donated = Program(lambda v: v + 1.0, (x,), donate_argnums=(0,))
    assert not DonationHonored().check(donated)
    # donated but no output can reuse the buffer: XLA drops the alias
    # silently — exactly the regression this rule exists to catch
    shrunk = Program(lambda v: v[:2].sum(), (x,), donate_argnums=(0,))
    viols = DonationHonored().check(shrunk)
    assert viols and "aliased buffers" in viols[0].message
    # an un-donated program fails an explicit donation expectation
    undonated = Program(lambda v: v + 1.0, (x,))
    assert DonationHonored(min_donated=1).check(undonated)
    # pallas-level: a plain program has no input_output_aliases pairs
    assert DonationHonored(min_pallas_aliases=2).check(undonated)


def test_rule_no_silent_upcast():
    b, chunk, n = 2, 32, 16

    def bad(x):                            # bf16 chunk upcast to f32 at scale
        wide = x.astype(jnp.float32) * 2.0
        return wide.sum()

    def good(x):                           # widens only a sub-floor slice
        # (note jnp.sum over the chunk axis would NOT be clean: it
        # accumulates bf16 inputs through a full-size f32 convert)
        return (x * jnp.bfloat16(2.0))[:, :, :1].astype(jnp.float32).sum()

    arr = jnp.ones((b, chunk, n), jnp.bfloat16)
    rule = NoSilentUpcast(chunk, b * chunk * n)
    viols = rule.check(Program(bad, (arr,)))
    assert viols and viols[0].dtype == "float32"
    assert not rule.check(Program(good, (arr,)))


def _copy_kernel_program(shape, dtype, block):
    """Trace-only pallas copy kernel with an explicit block shape."""
    from jax.experimental import pallas as pl

    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    grid = tuple(s // b for s, b in zip(shape, block))

    def run(x):
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[pl.BlockSpec(block, lambda i, j: (i, j))],
            out_specs=pl.BlockSpec(block, lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct(shape, dtype),
            interpret=True,
        )(x)

    return Program(run, (jnp.zeros(shape, dtype),))


def test_rule_vmem_budget_overflow():
    # one 8 MiB f32 block, double-buffered in+out = 32 MiB > 16 MiB budget
    prog = _copy_kernel_program((2048, 1024), jnp.float32, (2048, 1024))
    viols = VmemBudget().check(prog)
    assert viols and "VMEM" in viols[0].message
    assert not VmemBudget(limit_bytes=64 * 2 ** 20).check(prog)


def test_rule_vmem_alignment_sub_f32_multi_tile():
    """A multi-tile bf16 block off the (16, 128) boundary is exactly the
    class of bug interpret mode computes happily and real Mosaic rejects
    (the dfr_scan guard, generalized to every pallas_call)."""
    bad = _copy_kernel_program((32, 256), jnp.bfloat16, (4, 256))
    viols = VmemBudget().check(bad)
    assert viols and "sublane" in viols[0].message
    # aligned bf16 blocks, single-tile blocks, and f32 at the same geometry
    # (Mosaic relayouts f32) are all fine
    assert not VmemBudget().check(
        _copy_kernel_program((32, 256), jnp.bfloat16, (16, 256)))
    assert not VmemBudget().check(
        _copy_kernel_program((32, 256), jnp.float32, (4, 256)))
    assert not VmemBudget(check_alignment=False).check(bad)


# ---------------------------------------------------------------------------
# registry + CLI gate
# ---------------------------------------------------------------------------


def test_cli_entry_point_ok_and_report(tmp_path):
    from repro.analysis.cli import main
    out = tmp_path / "report.json"
    rc = main(["--entry-point", "session_step", "--out", str(out)])
    assert rc == 0
    report = json.loads(out.read_text())
    assert report["ok"] and report["n_violations"] == 0
    (entry,) = report["entry_points"]
    assert entry["name"] == "session_step" and entry["rules"]


def test_cli_seeded_violation_exits_nonzero(tmp_path):
    from repro.analysis.cli import main
    out = tmp_path / "report.json"
    rc = main(["--seed-violation", "--entry-point", "seeded_violation",
               "--out", str(out)])
    assert rc == 1
    report = json.loads(out.read_text())
    assert not report["ok"]
    (entry,) = report["entry_points"]
    viols = [v for r in entry["rules"] for v in r["violations"]]
    assert viols and all(v["rule"] == "NoStateTensor" for v in viols)
    assert any(v["path"] for v in viols)   # provenance reaches the report


def test_cli_unknown_entry_point_rejected():
    from repro.analysis.cli import main
    with pytest.raises(KeyError, match="bogus"):
        main(["--entry-point", "bogus", "--out", "/dev/null"])


def test_registry_names_cover_issue_surface():
    from repro.analysis.registry import entry_point_names
    names = set(entry_point_names())
    assert {"experiment_ref", "experiment_fast", "experiment_kernel",
            "experiment_streaming", "fit_ridge_streaming",
            "fit_ridge_streaming_wdm", "session_step",
            "session_step_refresh", "serve_dfr_step",
            "reservoir_lm_train_step"} <= names


def test_pipeline_introspect_shim_reexports():
    """Legacy import path still works and resolves to repro.analysis."""
    from repro.pipeline import introspect
    import repro.analysis.walker as walker
    for name in ("walk_eqns", "trace_jaxpr", "intermediate_shapes",
                 "max_intermediate_bytes", "state_tensor_bytes",
                 "count_scans", "count_pallas_calls"):
        assert getattr(introspect, name) is getattr(walker, name)
