"""Online-learning serving subsystem: sessions, forgetting, the serve loop.

Pins the ISSUE 6 contracts at fixed points (the hypothesis suite in
tests/test_properties.py generalises the same invariants across generated
chunk splits and decay factors — this module keeps minimal images honest):

* λ = 1.0 streaming fit is bit-identical to the historical path, and the
  chunk-aligned ``session_update`` scan + solve is bit-identical to
  ``fit_ridge_streaming`` at ANY λ — same Gram fold, same GCV solve;
* the forgetting fold follows the closed-form λ-weighted Gram algebra;
* ``session_step`` is honest online inference (predictions use the readout
  solved from *earlier* chunks only) and its compiled program holds no
  full-stream state tensor (jaxpr gates);
* the ``DFRServer`` continuous-batching loop packs/retires/resets slots
  correctly end to end.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (MaxPallasCalls, NoStateTensor, Program,
                            check_rules, count_pallas_calls,
                            state_tensor_bytes)
from repro.core import SiliconMR
from repro.core.masking import make_mask
from repro.core.reservoir import generate_states
from repro.pipeline.ridge import _fold_chunk, _plan_fold, fit_ridge_streaming
from repro.pipeline.session import (SessionConfig, _session_step, session_init,
                                    session_predict, session_reset,
                                    session_solve, session_step,
                                    session_update)

MODEL = SiliconMR()
N, B, K, WASH = 16, 3, 96, 24
LAMS = (1e-8, 1e-6, 1e-4)
MASK = make_mask(N, seed=3)


def _stream(seed: int, k: int = K, b: int = B):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(0, 1, (b, k)), jnp.float32)


def _cfg(**kw) -> SessionConfig:
    base = dict(model=MODEL, n_nodes=N, washout=WASH, ridge_l2=LAMS,
                chunk_k=24, state_method="fast", use_kernel=False)
    base.update(kw)
    return SessionConfig(**base)


# ---------------------------------------------------------------------------
# forgetting-factor streaming fit
# ---------------------------------------------------------------------------


def test_forgetting_one_is_default_and_validated():
    j, y = _stream(0), _stream(1)
    w_a, idx_a, s_a = fit_ridge_streaming(MODEL, MASK, j, y, washout=WASH,
                                          chunk_k=24, lambdas=LAMS,
                                          state_method="fast", use_kernel=False)
    w_b, idx_b, s_b = fit_ridge_streaming(MODEL, MASK, j, y, washout=WASH,
                                          chunk_k=24, lambdas=LAMS,
                                          state_method="fast", use_kernel=False,
                                          forgetting=1.0)
    np.testing.assert_array_equal(np.asarray(w_a), np.asarray(w_b))
    np.testing.assert_array_equal(np.asarray(idx_a), np.asarray(idx_b))
    np.testing.assert_array_equal(np.asarray(s_a), np.asarray(s_b))
    with pytest.raises(ValueError, match="forgetting"):
        fit_ridge_streaming(MODEL, MASK, j, y, washout=WASH, chunk_k=24,
                            forgetting=0.0)
    with pytest.raises(ValueError, match="noise_rel"):
        fit_ridge_streaming(MODEL, MASK, j, y, washout=WASH, chunk_k=24,
                            forgetting=0.9, noise_rel=0.01)


def test_forgetting_downweights_early_chunks():
    """With λ < 1 the fit tracks the LATE part of a stream whose target
    mapping flips mid-way: the decayed readout must predict the second
    mapping better than the λ = 1 readout does."""
    from repro.pipeline.ridge import with_bias

    j = _stream(3, k=2 * K)
    states = generate_states(MODEL, j, MASK, method="fast")
    x = with_bias(states)
    rng = np.random.default_rng(7)
    w_true_a = jnp.asarray(rng.standard_normal((N + 1,)), jnp.float32)
    w_true_b = jnp.asarray(rng.standard_normal((N + 1,)), jnp.float32)
    y = jnp.concatenate([x[:, :K] @ w_true_a, x[:, K:] @ w_true_b], axis=1)

    def late_err(forgetting):
        w, _, _ = fit_ridge_streaming(MODEL, MASK, j, y, washout=WASH,
                                      chunk_k=24, lambdas=(1e-6,),
                                      state_method="fast", use_kernel=False,
                                      forgetting=forgetting)
        pred = jnp.einsum("btf,bfc->btc", x[:, K:], w)[..., 0]
        return float(jnp.mean((pred - y[:, K:]) ** 2))

    # λ decays per *chunk* (4 chunks cover the late regime here), so a
    # strong λ is needed for the early regime's weight to fade within K
    assert late_err(0.5) < 0.25 * late_err(1.0)
    assert late_err(0.9) < late_err(1.0)


def test_forgetting_fold_matches_closed_form():
    """Fixed-point mirror of the hypothesis property: λ-scan over chunks ==
    float64 Σᵢ λ^(n-1-i)·XᵢᵀXᵢ; λ = 1.0 is bitwise plain accumulation."""
    f, ch, c, n_chunks, lam = 9, 6, 2, 4, 0.9
    plan = _plan_fold(f, ch, use_kernel=False, block_t=512, block_f=128,
                      state_dtype=None)
    rng = np.random.default_rng(0)
    x = rng.standard_normal((n_chunks, B, ch, f)).astype(np.float32)
    y = rng.standard_normal((n_chunks, B, ch, c)).astype(np.float32)

    def fold_all(forgetting):
        g = jnp.zeros((B, f, f), jnp.float32)
        cv = jnp.zeros((B, f, c), jnp.float32)
        y2 = jnp.zeros((B,), jnp.float32)
        for xi, yi in zip(x, y):
            g, cv, y2 = _fold_chunk(plan, g, cv, y2, jnp.asarray(xi),
                                    jnp.asarray(yi), forgetting=forgetting)
        return np.asarray(g), np.asarray(cv), np.asarray(y2)

    g, cv, y2 = fold_all(lam)
    w = lam ** np.arange(n_chunks - 1, -1, -1, dtype=np.float64)
    x64, y64 = x.astype(np.float64), y.astype(np.float64)
    np.testing.assert_allclose(g, np.einsum("n,nbtf,nbtg->bfg", w, x64, x64),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(cv, np.einsum("n,nbtf,nbtc->bfc", w, x64, y64),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(y2, np.einsum("n,nbtc->b", w, y64 * y64),
                               rtol=1e-4, atol=1e-4)
    # λ = 1.0: zero inserted ops — bitwise the plain fold
    g1, cv1, y21 = fold_all(1.0)
    g_ref = sum(np.asarray(jnp.einsum("btf,btg->bfg", jnp.asarray(xi),
                                      jnp.asarray(xi),
                                      preferred_element_type=jnp.float32))
                for xi in x)
    np.testing.assert_array_equal(g1, g_ref)


# ---------------------------------------------------------------------------
# sessions == streaming fit, resumability
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("lam", [1.0, 0.99])
@pytest.mark.parametrize("use_kernel", [False, True])
def test_session_scan_bitwise_matches_streaming_fit(lam, use_kernel):
    chunk = 24
    j, y = _stream(5), _stream(6)
    w_ref, idx_ref, s_ref = fit_ridge_streaming(
        MODEL, MASK, j, y, washout=WASH, chunk_k=chunk, lambdas=LAMS,
        state_method="fast", use_kernel=use_kernel, forgetting=lam)
    cfg = _cfg(chunk_k=chunk, forgetting=lam, use_kernel=use_kernel)
    state = session_init(cfg, B)
    for lo in range(0, K, chunk):
        state = session_update(cfg, MASK, state, j[:, lo:lo + chunk],
                               y[:, lo:lo + chunk])
    state = session_solve(cfg, state)
    np.testing.assert_array_equal(
        np.asarray(w_ref).reshape(state.w.shape), np.asarray(state.w))
    np.testing.assert_array_equal(np.asarray(idx_ref),
                                  np.asarray(state.lam_idx))
    np.testing.assert_array_equal(np.asarray(s_ref), np.asarray(state.s))


def test_session_chunked_resume_bit_exact_fixed_splits():
    """Hypothesis-free mirror: arbitrary (hand-picked, tile-UNaligned) splits
    of the reservoir scan resume bitwise from the carried state."""
    j = _stream(9, k=30)
    full, fin = generate_states(MODEL, j, MASK, method="fast",
                                return_final=True)
    for cuts in ([7], [1, 11, 12], [5, 13, 21, 29]):
        bounds = [0] + cuts + [30]
        s = jnp.zeros((B, N), jnp.float32)
        parts = []
        for lo, hi in zip(bounds[:-1], bounds[1:]):
            states, s = generate_states(MODEL, j[:, lo:hi], MASK, s0=s,
                                        method="fast", return_final=True)
            parts.append(np.asarray(states))
        np.testing.assert_array_equal(np.concatenate(parts, axis=1),
                                      np.asarray(full))
        np.testing.assert_array_equal(np.asarray(s), np.asarray(fin))


def test_session_step_predictions_ignore_current_targets():
    """Honest online inference: the tick-t prediction uses the readout from
    ticks < t only — garbage targets in the current chunk cannot leak in."""
    cfg = _cfg()
    j, y = _stream(10), _stream(11)
    st = session_init(cfg, B)
    ck = cfg.chunk_k
    for lo in range(0, K, ck):
        jc, yc = j[:, lo:lo + ck], y[:, lo:lo + ck]
        ya, st_next = session_step(cfg, MASK, st, jc, yc, refresh=True)
        yb, _ = session_step(cfg, MASK, st, jc, 1e6 * jnp.ones_like(yc),
                             refresh=True)
        np.testing.assert_array_equal(np.asarray(ya), np.asarray(yb))
        st = st_next


def test_session_step_predict_then_update_order():
    """A target outlier in chunk t moves predictions from chunk t+1 on, not
    chunk t's own (predict-then-update, the RLS serving order)."""
    cfg = _cfg(refresh_every=1)
    j, y = _stream(12), _stream(13)
    ck = cfg.chunk_k

    def run(y_used):
        st = session_init(cfg, B)
        preds = []
        for lo in range(0, K, ck):
            p, st = session_step(cfg, MASK, st, j[:, lo:lo + ck],
                                 y_used[:, lo:lo + ck], refresh=True)
            preds.append(np.asarray(p))
        return preds

    y_bad = y.at[:, ck:2 * ck].add(100.0)
    pa, pb = run(y), run(y_bad)
    np.testing.assert_array_equal(pa[0], pb[0])
    np.testing.assert_array_equal(pa[1], pb[1])   # its own chunk: untouched
    assert np.max(np.abs(pa[2] - pb[2])) > 1.0    # visible one tick later


def test_session_ragged_chunk_tail_independence():
    """Rows past n_valid must not affect statistics or readout."""
    cfg = _cfg()
    j, y = _stream(14), _stream(15)
    ck = cfg.chunk_k
    nv = jnp.asarray([ck, ck // 2, ck // 3], jnp.int32)
    st0 = session_init(cfg, B)
    a = session_update(cfg, MASK, st0, j[:, :ck], y[:, :ck], n_valid=nv)
    y_trash = y.at[:, :ck].set(1e9)

    def mask_tail(arr):
        keep = jnp.arange(ck)[None, :] < nv[:, None]
        return jnp.where(keep, arr[:, :ck], y_trash[:, :ck])

    b = session_update(cfg, MASK, st0, j[:, :ck], mask_tail(y), n_valid=nv)
    np.testing.assert_array_equal(np.asarray(a.g), np.asarray(b.g))
    np.testing.assert_array_equal(np.asarray(a.c), np.asarray(b.c))
    np.testing.assert_array_equal(np.asarray(a.y2), np.asarray(b.y2))
    np.testing.assert_array_equal(np.asarray(a.tcnt), np.asarray(b.tcnt))


def test_session_reset_clears_only_flagged_rows():
    cfg = _cfg()
    j, y = _stream(16), _stream(17)
    st = session_init(cfg, B)
    _, st = session_step(cfg, MASK, st, j[:, :24], y[:, :24], refresh=True)
    st2 = session_reset(st, jnp.asarray([True, False, False]))
    for leaf, leaf2 in zip(st, st2):
        assert not np.any(np.asarray(leaf2[0]))
        np.testing.assert_array_equal(np.asarray(leaf2[1:]),
                                      np.asarray(leaf[1:]))


def test_session_predict_advances_carry_without_learning():
    cfg = _cfg()
    j, y = _stream(18), _stream(19)
    st = session_init(cfg, B)
    _, st = session_step(cfg, MASK, st, j[:, :24], y[:, :24], refresh=True)
    y_hat, st2 = session_predict(cfg, MASK, st, j[:, 24:48])
    assert y_hat.shape == (B, 24, 1)
    np.testing.assert_array_equal(np.asarray(st2.g), np.asarray(st.g))
    np.testing.assert_array_equal(np.asarray(st2.tcnt), np.asarray(st.tcnt))
    assert int(st2.step[0]) == int(st.step[0]) + 24
    assert not np.array_equal(np.asarray(st2.s), np.asarray(st.s))


# ---------------------------------------------------------------------------
# jaxpr gates: the serve step is one program, chunk-sized live state only
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("refresh", [False, True])
def test_session_step_jaxpr_holds_no_full_stream_tensor(refresh):
    stream_len = 4096                  # what a full-stream tensor would carry
    cfg = _cfg(chunk_k=32)
    b = 8
    state = session_init(cfg, b)
    z = jnp.zeros((b, 32), jnp.float32)
    fn = jax.jit(_session_step, static_argnames=("cfg", "refresh"))
    prog = Program(lambda st, jc, yc: fn(cfg, MASK, st, jc, yc,
                                         refresh=refresh), (state, z, z))
    viols = check_rules(prog, [
        NoStateTensor(stream_len, b * stream_len * N,
                      what="full-stream tensor"),
        # largest state-like block is the chunk itself (feature-padded
        # budget)
        NoStateTensor(32, b * 32 * N, max_bytes=2 * b * 32 * 128 * 4,
                      what="chunk block"),
    ])
    assert not viols, [str(v) for v in viols]
    assert state_tensor_bytes(prog.closed_jaxpr, 32, b * 32 * N) > 0


def test_session_step_kernel_path_single_pallas_launch_pair():
    """use_kernel sessions run ONE dfr_scan + ONE accumulate-into Gram
    launch per tick — no per-row or per-chunk re-launch fan-out."""
    cfg = _cfg(chunk_k=24, state_method="kernel", use_kernel=True)
    b = 4
    state = session_init(cfg, b)
    z = jnp.zeros((b, 24), jnp.float32)
    fn = jax.jit(_session_step, static_argnames=("cfg", "refresh"))
    prog = Program(lambda st, jc, yc: fn(cfg, MASK, st, jc, yc,
                                         refresh=False), (state, z, z))
    viols = check_rules(prog, [MaxPallasCalls(2)])
    assert not viols, [str(v) for v in viols]
    assert count_pallas_calls(prog.closed_jaxpr) == 2


# ---------------------------------------------------------------------------
# the continuous-batching server loop
# ---------------------------------------------------------------------------


def test_dfr_server_continuous_batching_end_to_end():
    from repro.launch.serve_dfr import DFRServer, StreamRequest

    cfg = _cfg(chunk_k=16, forgetting=0.99, refresh_every=2)
    server = DFRServer(cfg, batch=2, mask_seed=0)
    server.warmup()
    rng = np.random.default_rng(0)
    n_req, k = 5, 48                   # 5 streams through 2 slots: 3 waves
    for r in range(n_req):
        server.submit(StreamRequest(
            rid=r, j=rng.uniform(0, 1, k).astype(np.float32),
            y=rng.choice([-1.0, 1.0], k).astype(np.float32)))
    server.drain()
    assert len(server.completed) == n_req
    assert server.active == 0 and not server.queue
    assert sorted(r.rid for r in server.completed) == list(range(n_req))
    for req in server.completed:
        yh = np.concatenate(req.y_hat)
        assert yh.shape == (k,)
        assert np.all(np.isfinite(yh))
    # ticks: ceil(5 streams * 3 ticks each / 2 slots) packed continuously
    assert server.tick <= 9


def test_dfr_server_cli_smoke(capsys):
    from repro.launch import serve_dfr

    serve_dfr.main(["--requests", "3", "--batch", "2", "--stream-len", "64",
                    "--nodes", "16", "--washout", "16", "--chunk", "16"])
    out = capsys.readouterr().out
    assert "streams/s" in out and "p99" in out


# ---------------------------------------------------------------------------
# the drifting-link online workload (examples/online_equalization.py)
# ---------------------------------------------------------------------------


def test_channel_equalization_drift_task():
    """The online workload generator: whole stream in the test split, 4-PAM
    symbols, and a real mid-stream link change — the noise floor steps AND
    the clean channel response differs across the drift point."""
    from repro.core import tasks

    ds = tasks.channel_equalization_drift(2000, snr_db=28.0, snr_db_after=16.0,
                                          drift_frac=0.5, seed=0)
    assert ds.inputs_train.shape == (0,) and ds.inputs_test.shape == (2000,)
    assert set(np.unique(ds.targets_test)) <= {-3.0, -1.0, 1.0, 3.0}
    # same symbols, different received signal across the drift point
    still = tasks.channel_equalization_drift(2000, snr_db=28.0,
                                             snr_db_after=28.0,
                                             drift_frac=0.5, drift_taps=False,
                                             seed=0)
    np.testing.assert_array_equal(ds.targets_test, still.targets_test)
    pre, post = slice(0, 1000), slice(1000, 2000)
    np.testing.assert_array_equal(ds.inputs_test[pre], still.inputs_test[pre])
    assert not np.array_equal(ds.inputs_test[post], still.inputs_test[post])
    # the post-drift segment carries more noise than the un-drifted stream
    assert np.var(ds.inputs_test[post] - still.inputs_test[post]) > 0.0
    with pytest.raises(ValueError, match="drift_frac"):
        tasks.channel_equalization_drift(100, drift_frac=1.0)
