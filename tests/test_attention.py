"""Attention invariants: chunked == dense, GQA grouping, RoPE, causality."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.models import ModelConfig
from repro.models.layers import (
    _sdpa_chunked,
    _sdpa_dense,
    apply_rope,
    rope_freqs,
)

CFG = ModelConfig(name="t", d_model=64, n_heads=4, n_kv_heads=2, vocab_size=64)


def _qkv(key, b, sq, skv, h, kv, dh):
    ks = jax.random.split(key, 3)
    return (jax.random.normal(ks[0], (b, sq, h, dh), jnp.float32),
            jax.random.normal(ks[1], (b, skv, kv, dh), jnp.float32),
            jax.random.normal(ks[2], (b, skv, kv, dh), jnp.float32))


@given(seed=st.integers(0, 10), causal=st.booleans(),
       chunk_div=st.sampled_from([2, 4, 8]))
@settings(max_examples=12, deadline=None)
def test_chunked_equals_dense(seed, causal, chunk_div):
    q, k, v = _qkv(jax.random.PRNGKey(seed), 2, 32, 32, 4, 2, 16)
    dense = _sdpa_dense(CFG, q, k, v, causal=causal)
    chunked = _sdpa_chunked(CFG, q, k, v, causal=causal, chunk=32 // chunk_div)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense), atol=2e-6)


def test_chunked_with_offset_decode_window():
    q, k, v = _qkv(jax.random.PRNGKey(3), 1, 16, 64, 4, 2, 16)
    dense = _sdpa_dense(CFG, q, k, v, causal=True, q_offset=48)
    chunked = _sdpa_chunked(CFG, q, k, v, causal=True, q_offset=48, chunk=16)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense), atol=2e-6)


def test_causality():
    """Future kv must not influence earlier queries."""
    q, k, v = _qkv(jax.random.PRNGKey(4), 1, 8, 8, 4, 2, 16)
    base = _sdpa_dense(CFG, q, k, v, causal=True)
    k2 = k.at[:, 5:].set(jax.random.normal(jax.random.PRNGKey(9), k[:, 5:].shape))
    v2 = v.at[:, 5:].set(jax.random.normal(jax.random.PRNGKey(10), v[:, 5:].shape))
    pert = _sdpa_dense(CFG, q, k2, v2, causal=True)
    np.testing.assert_allclose(np.asarray(base[:, :5]), np.asarray(pert[:, :5]), atol=1e-6)


def test_gqa_equals_repeated_kv():
    """GQA == MHA with kv heads explicitly repeated per group."""
    q, k, v = _qkv(jax.random.PRNGKey(5), 2, 8, 8, 4, 2, 16)
    out = _sdpa_dense(CFG, q, k, v, causal=True)
    krep = jnp.repeat(k, 2, axis=2)
    vrep = jnp.repeat(v, 2, axis=2)
    # with kv == h the grouping is trivial
    out_rep = _sdpa_dense(CFG, q, krep, vrep, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_rep), atol=1e-5)


def test_rope_preserves_norm_and_relative_phase():
    pos = jnp.arange(16)[None, :]
    cos, sin = rope_freqs(32, 10_000.0, pos)
    x = jax.random.normal(jax.random.PRNGKey(6), (1, 16, 2, 32), jnp.float32)
    xr = apply_rope(x, cos, sin)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(xr), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1),
        rtol=1e-5,
    )
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jax.random.normal(jax.random.PRNGKey(7), (1, 1, 1, 32), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(8), (1, 1, 1, 32), jnp.float32)
    def dot_at(i, j):
        ci, si = rope_freqs(32, 10_000.0, jnp.asarray([[i]]))
        cj, sj = rope_freqs(32, 10_000.0, jnp.asarray([[j]]))
        return float(jnp.sum(apply_rope(q, ci, si) * apply_rope(k, cj, sj)))
    assert abs(dot_at(3, 1) - dot_at(10, 8)) < 1e-4


def test_softcap_bounds_logits():
    cfg = ModelConfig(name="t", d_model=64, n_heads=4, n_kv_heads=2,
                      vocab_size=64, attn_logit_softcap=5.0)
    q, k, v = _qkv(jax.random.PRNGKey(11), 1, 8, 8, 4, 2, 16)
    big_q = q * 100
    out = _sdpa_dense(cfg, big_q, k, v, causal=False)
    assert np.isfinite(np.asarray(out)).all()
