"""Property-based invariants of the streaming/online DFR machinery.

The streaming fits and online sessions rest on two algebraic contracts that
tests/test_streaming.py and tests/test_serving.py pin only at hand-picked
chunk sizes:

* **chunk-resume bit-exactness** — running the reservoir in chunks from the
  carried final state replays the *exact* arithmetic of the uninterrupted
  scan, for ANY split of the stream (the per-period recurrence doesn't know
  where a chunk boundary fell);
* **forgetting-Gram algebra** — the per-chunk λ-scan (scale carried
  statistics by λ, accumulate the chunk) equals the closed-form λ-weighted
  one-shot Gram Σᵢ λ^(n-1-i)·XᵢᵀXᵢ, and λ = 1.0 is bitwise the plain
  accumulation path.

This module generalises those pins across hypothesis-generated splits,
chunk sizes and decay factors (≥ 200 examples across the suite).  Needs
``hypothesis`` (requirements-dev.txt) — conftest.py skips the module
gracefully when it is absent; hypothesis-free mirrors of the same
invariants live in tests/test_serving.py so minimal images still exercise
them at fixed points.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import SiliconMR
from repro.core.masking import make_mask
from repro.core.reservoir import generate_states
from repro.pipeline.ridge import _fold_chunk, _plan_fold, fit_ridge_streaming
from repro.pipeline.session import (SessionConfig, session_init, session_solve,
                                    session_update)

MODEL = SiliconMR()
N = 7
B = 3
K = 24                     # fixed stream length bounds the jit-shape universe
MASK = make_mask(N, seed=3)


def _stream(seed: int, k: int = K, b: int = B):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(0, 1, (b, k)), jnp.float32)


@st.composite
def split_points(draw, k=K, max_cuts=4):
    """1..max_cuts sorted interior cut positions of a length-k stream."""
    n_cuts = draw(st.integers(1, max_cuts))
    cuts = draw(st.lists(st.integers(1, k - 1), min_size=n_cuts,
                         max_size=n_cuts, unique=True))
    return sorted(cuts)


# ---------------------------------------------------------------------------
# chunk-resume bit-exactness, arbitrary splits
# ---------------------------------------------------------------------------


@given(cuts=split_points(), seed=st.integers(0, 20),
       method=st.sampled_from(["fast", "kernel"]))
@settings(max_examples=60, deadline=None)
def test_chunked_resume_bit_exact_for_arbitrary_splits(cuts, seed, method):
    """States and final carry of ANY chunking == the uninterrupted scan,
    bitwise — jnp scan and Pallas kernel (interpret off-TPU) alike."""
    j = _stream(seed)
    full, fin_full = generate_states(MODEL, j, MASK, method=method,
                                     return_final=True)
    bounds = [0] + cuts + [K]
    s = jnp.zeros((B, N), jnp.float32)
    parts = []
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        states, s = generate_states(MODEL, j[:, lo:hi], MASK, s0=s,
                                    method=method, return_final=True)
        parts.append(np.asarray(states))
    np.testing.assert_array_equal(np.concatenate(parts, axis=1),
                                  np.asarray(full))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(fin_full))


@given(cuts=split_points(), seed=st.integers(0, 20))
@settings(max_examples=40, deadline=None)
def test_composed_graph_resume_bit_exact_for_arbitrary_splits(cuts, seed):
    """The composed-graph carry tuple resumes bit-exactly at ANY split: the
    per-stage carries thread independently through the chain, so chunking a
    deep/multi-loop graph replays the uninterrupted arithmetic — features
    and every stage's final state (DESIGN.md §13; fixed-point mirrors in
    tests/test_composed.py)."""
    from repro.core import ReservoirStage, build_stage_masks, chain
    from repro.core.graph import graph_states
    graph = chain(
        ReservoirStage(model=MODEL, n_nodes=N, loops=2, mask_seed=3),
        ReservoirStage(model=MODEL, n_nodes=5, mask_seed=11, link="sat"))
    masks = build_stage_masks(graph)
    j = _stream(seed)
    full, fin = graph_states(graph, j, masks, method="fast",
                             return_final=True)
    bounds = [0] + cuts + [K]
    s = None
    parts = []
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        states, s = graph_states(graph, j[:, lo:hi], masks, s0=s,
                                 method="fast", return_final=True)
        parts.append(np.asarray(states))
    np.testing.assert_array_equal(np.concatenate(parts, axis=1),
                                  np.asarray(full))
    for got, ref in zip(s, fin):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@given(chunk=st.integers(5, 40), seed=st.integers(0, 10))
@settings(max_examples=25, deadline=None)
def test_composed_fit_s_end_matches_oracle_for_any_chunk(chunk, seed):
    """fit_ridge_streaming_composed's carry tuple after period K - 1 equals
    the materialized graph oracle's for ANY chunk_k, to 1-ulp slack
    (atol 1e-6): the jitted chunk scan may fuse the link-drive
    mean/nonlinearity differently from the eager oracle. Eager per-chunk
    replay of the same states_fn IS bitwise — that property lives in
    test_composed_graph_resume_bit_exact_for_arbitrary_splits above and in
    test_composed.py's fixed-split mirror."""
    from repro.core import ReservoirStage, build_stage_masks, chain
    from repro.core.graph import graph_states
    from repro.pipeline.ridge import fit_ridge_streaming_composed
    graph = chain(
        ReservoirStage(model=MODEL, n_nodes=N, loops=2, mask_seed=3),
        ReservoirStage(model=MODEL, n_nodes=5, mask_seed=11, link="sat"))
    masks = build_stage_masks(graph)
    j = _stream(seed)
    y = _stream(seed + 100)
    _, fin = graph_states(graph, j, masks, method="fast", return_final=True)
    _, _, s_end = fit_ridge_streaming_composed(
        graph, masks, j, y, washout=8, chunk_k=chunk, lambdas=(1e-6,),
        state_method="fast", use_kernel=False)
    for got, ref in zip(s_end, fin):
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=1e-6, rtol=0)


@given(chunk=st.integers(5, 40), seed=st.integers(0, 10))
@settings(max_examples=30, deadline=None)
def test_streaming_fit_s_end_bit_exact_for_any_chunk(chunk, seed):
    """fit_ridge_streaming's carry s_end is the last-period state of the
    materialized scan for ANY chunk_k — aligned, ragged, or chunk > K."""
    k, washout = 40, 12
    j = _stream(seed, k=k, b=2)
    y = _stream(seed + 100, k=k, b=2)
    states = generate_states(MODEL, j, MASK, method="fast")
    _, _, s_end = fit_ridge_streaming(MODEL, MASK, j, y, washout=washout,
                                      chunk_k=chunk, lambdas=(1e-6,),
                                      state_method="fast", use_kernel=False)
    np.testing.assert_array_equal(np.asarray(s_end),
                                  np.asarray(states[:, -1, :]))


# ---------------------------------------------------------------------------
# forgetting-Gram algebra
# ---------------------------------------------------------------------------

F, CH, C = 9, 6, 2         # features, chunk rows, target channels
_PLAN = _plan_fold(F, CH, use_kernel=False, block_t=512, block_f=128,
                   state_dtype=None)


def _chunks(seed: int, n_chunks: int):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n_chunks, B, CH, F)).astype(np.float32)
    y = rng.standard_normal((n_chunks, B, CH, C)).astype(np.float32)
    return x, y


def _fold_all(x, y, lam: float):
    g = jnp.zeros((B, F, F), jnp.float32)
    c = jnp.zeros((B, F, C), jnp.float32)
    y2 = jnp.zeros((B,), jnp.float32)
    for xi, yi in zip(x, y):
        g, c, y2 = _fold_chunk(_PLAN, g, c, y2, jnp.asarray(xi),
                               jnp.asarray(yi), forgetting=lam)
    return np.asarray(g), np.asarray(c), np.asarray(y2)


@given(seed=st.integers(0, 1000), n_chunks=st.integers(1, 5),
       lam=st.floats(0.5, 1.0, exclude_min=True))
@settings(max_examples=100, deadline=None)
def test_forgetting_scan_matches_closed_form_weighted_gram(seed, n_chunks, lam):
    """λ-scan over chunks == Σᵢ λ^(n-1-i)·(XᵢᵀXᵢ, Xᵢᵀyᵢ, ‖yᵢ‖²), evaluated
    in float64 (the scan is f32; tolerance covers association only)."""
    x, y = _chunks(seed, n_chunks)
    g, c, y2 = _fold_all(x, y, lam)
    w = lam ** np.arange(n_chunks - 1, -1, -1, dtype=np.float64)
    x64, y64 = x.astype(np.float64), y.astype(np.float64)
    g_ref = np.einsum("n,nbtf,nbtg->bfg", w, x64, x64)
    c_ref = np.einsum("n,nbtf,nbtc->bfc", w, x64, y64)
    y2_ref = np.einsum("n,nbtc->b", w, y64 * y64)
    np.testing.assert_allclose(g, g_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(c, c_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(y2, y2_ref, rtol=1e-4, atol=1e-4)


@given(seed=st.integers(0, 1000), n_chunks=st.integers(1, 4))
@settings(max_examples=30, deadline=None)
def test_forgetting_one_is_bitwise_plain_accumulation(seed, n_chunks):
    """λ = 1.0 must insert ZERO ops: bitwise the no-forgetting fold."""
    x, y = _chunks(seed, n_chunks)
    for a, b in zip(_fold_all(x, y, 1.0), _fold_all(x, y, 17.0 / 17.0)):
        np.testing.assert_array_equal(a, b)
    # and identical to a manually accumulated eager einsum
    g, c, y2 = _fold_all(x, y, 1.0)
    g_ref = sum(np.asarray(jnp.einsum("btf,btg->bfg", jnp.asarray(xi),
                                      jnp.asarray(xi),
                                      preferred_element_type=jnp.float32))
                for xi in x)
    np.testing.assert_array_equal(g, g_ref)


# ---------------------------------------------------------------------------
# online sessions == one-shot streaming fit, generated chunk sizes + decay
# ---------------------------------------------------------------------------


@given(chunk=st.sampled_from((6, 8, 12, 16, 24, 48)),
       lam=st.sampled_from((1.0, 0.97)), seed=st.integers(0, 5))
@settings(max_examples=20, deadline=None)
def test_session_scan_bitwise_matches_streaming_fit(chunk, lam, seed):
    """Chunk-aligned session_update scan + solve == fit_ridge_streaming,
    bitwise (readout, λ index, reservoir carry), for generated chunk sizes
    and forgetting factors."""
    k, washout = 48, 12
    j = _stream(seed, k=k)
    y = _stream(seed + 50, k=k)
    w_ref, idx_ref, s_ref = fit_ridge_streaming(
        MODEL, MASK, j, y, washout=washout, chunk_k=chunk,
        lambdas=(1e-6, 1e-4), state_method="fast", use_kernel=False,
        forgetting=lam)
    cfg = SessionConfig(model=MODEL, n_nodes=N, washout=washout,
                        ridge_l2=(1e-6, 1e-4), chunk_k=chunk, forgetting=lam,
                        state_method="fast", use_kernel=False)
    state = session_init(cfg, B)
    for lo in range(0, k, chunk):
        pad = max(0, lo + chunk - k)
        jc = jnp.pad(j[:, lo:lo + chunk], ((0, 0), (0, pad)))
        yc = jnp.pad(y[:, lo:lo + chunk], ((0, 0), (0, pad)))
        nv = jnp.full((B,), min(chunk, k - lo), jnp.int32)
        state = session_update(cfg, MASK, state, jc, yc, n_valid=nv)
    state = session_solve(cfg, state)
    np.testing.assert_array_equal(
        np.asarray(w_ref).reshape(state.w.shape), np.asarray(state.w))
    np.testing.assert_array_equal(np.asarray(idx_ref),
                                  np.asarray(state.lam_idx))
    np.testing.assert_array_equal(np.asarray(s_ref), np.asarray(state.s))

# ---------------------------------------------------------------------------
# CMT device models (DESIGN.md §14), generated splits and grid points
# ---------------------------------------------------------------------------

from repro.devices import CMTSweepParams, calibrated_twin  # noqa: E402

CMT = calibrated_twin(MODEL, power_mw=1.0)


@given(cuts=split_points(), seed=st.integers(0, 20),
       method=st.sampled_from(["ref", "fast", "kernel"]))
@settings(max_examples=45, deadline=None)
def test_cmt_chunked_resume_bit_exact_for_arbitrary_splits(cuts, seed, method):
    """The CMT carry (intracavity energy; the free-carrier/thermal closure
    is a function of it alone) resumes bit-exactly at ANY split — fixed-point
    mirror in tests/test_devices.py."""
    j = _stream(seed)
    full, fin_full = generate_states(CMT, j, MASK, method=method,
                                     return_final=True)
    bounds = [0] + cuts + [K]
    s = jnp.zeros((B, N), jnp.float32)
    parts = []
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        states, s = generate_states(CMT, j[:, lo:hi], MASK, s0=s,
                                    method=method, return_final=True)
        parts.append(np.asarray(states))
    np.testing.assert_array_equal(np.concatenate(parts, axis=1),
                                  np.asarray(full))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(fin_full))


@given(seed=st.integers(0, 20),
       detune=st.floats(-2.0, 2.0), loss=st.floats(1.0, 2.0),
       power=st.floats(0.0, 2.0))
@settings(max_examples=40, deadline=None)
def test_cmt_swept_lane_matches_unswept_point(seed, detune, loss, power):
    """Any generated grid point evaluated as a dev_params batch lane equals
    the dedicated model frozen at that point (κ pinned to the base anchor),
    and stays finite over the loss ≥ 1 box."""
    import dataclasses
    j = _stream(seed, b=1)
    p = CMTSweepParams(detune=jnp.float32(detune), loss_scale=jnp.float32(loss),
                       power=jnp.float32(power))
    swept = generate_states(CMT, j, MASK, method="fast", dev_params=p)
    point = dataclasses.replace(CMT, detune=detune, loss_scale=loss,
                                power_mw=power, kappa_charge=CMT.kappa_c,
                                kappa_discharge=CMT.kappa_d)
    ref = generate_states(point, j, MASK, method="fast")
    assert np.all(np.isfinite(np.asarray(swept)))
    np.testing.assert_allclose(np.asarray(swept), np.asarray(ref),
                               atol=1e-5, rtol=0)
