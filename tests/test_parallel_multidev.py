"""Multi-device behaviours (pipeline parallelism, compressed psum, sharded
train step).  These need >1 device, so each test runs in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 — keeping the main test
process single-device per the dry-run contract.

Marked ``multidev``: excluded from the tier-1 run (pytest.ini), executed by
the CI multidev job / `pytest -m multidev`."""

import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.multidev


def _run(src: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(src)],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                         timeout=420)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_pipeline_matches_sequential():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.parallel.pipeline import make_stage_mesh, pipeline_apply

        S, M, D = 4, 6, 16
        mesh = make_stage_mesh(S)
        key = jax.random.PRNGKey(0)
        params = {"w": jax.random.normal(key, (S, D, D)) / np.sqrt(D)}

        def stage_fn(p, x):
            return jnp.tanh(x @ p["w"])

        x = jax.random.normal(jax.random.fold_in(key, 1), (M, 3, D))
        out = pipeline_apply(stage_fn, params, x, mesh=mesh)

        ref = x
        for s in range(S):
            ref = jnp.tanh(ref @ params["w"][s])
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
        print("pipeline OK")
    """)


def test_compressed_psum_error_feedback():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.optim.compression import compressed_psum

        from repro.compat import make_mesh

        mesh = make_mesh((8,), ("pod",))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 64, 37))

        def sync(g_local, err):
            return compressed_psum(g_local[0], err[0], "pod")

        fn = shard_map(sync, mesh=mesh, in_specs=(P("pod"), P("pod")),
                       out_specs=(P(), P("pod")), check_rep=False)
        err0 = jnp.zeros((8, 64, 37))
        g_hat, err = fn(g, err0)
        err = err.reshape(8, 64, 37)                # out_specs stacks shards
        exact = jnp.mean(g, 0)
        rel = float(jnp.linalg.norm(g_hat - exact) / jnp.linalg.norm(exact))
        assert rel < 0.02, rel                      # int8 quantisation error
        # error feedback: same grads + fed-back residual -> two-step average
        # is closer than a single compressed step (EF compensates)
        g_hat2, _ = fn(g, err)
        avg = (np.asarray(g_hat) + np.asarray(g_hat2)) / 2
        rel_avg = float(np.linalg.norm(avg - np.asarray(exact)) / np.linalg.norm(np.asarray(exact)))
        assert rel_avg <= rel + 1e-6, (rel_avg, rel)
        print("compression OK", rel, rel_avg)
    """)


def test_sharded_train_step_runs_on_mesh():
    """The launch-time jit (in/out shardings, donation) on a real 2x4 mesh."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.models import ModelConfig
        from repro.optim import AdamWConfig
        from repro.parallel.sharding import batch_pspec, param_pspecs
        from repro.runtime.steps import init_train_state, train_step

        cfg = ModelConfig(name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab_size=128, dtype="float32", remat="none",
                          microbatches=2)
        from repro.compat import make_mesh, shardings_for, use_mesh

        mesh = make_mesh((2, 4), ("data", "model"))
        with use_mesh(mesh):
            pspecs = param_pspecs(cfg, mesh)
            sspecs = shardings_for(mesh, {
                "params": pspecs, "opt": {"m": pspecs, "v": pspecs},
                "step": jax.sharding.PartitionSpec()})
            bspecs = shardings_for(mesh, {"tokens": batch_pspec(mesh),
                                          "labels": batch_pspec(mesh)})
            fn = jax.jit(lambda s, b: train_step(cfg, AdamWConfig(lr=1e-3), s, b),
                         in_shardings=(sspecs, bspecs), out_shardings=(sspecs, None),
                         donate_argnums=(0,))
            state = jax.jit(lambda k: init_train_state(cfg, k),
                            out_shardings=sspecs)(jax.random.PRNGKey(0))
            toks = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, 128))
            batch = {"tokens": toks, "labels": toks}  # host arrays: jit places them
            losses = []
            for _ in range(4):
                state, metrics = fn(state, batch)
                losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0], losses
        print("sharded train OK", losses)
    """)


def test_pipeline_experiment_shards_over_mesh():
    """The jit-end-to-end Experiment sweep under an active mesh: the task
    instance axis shards over the data axis via parallel/sharding.maybe_shard
    (default SVD readout; the streaming Gram path has its own parity tests),
    and results match the single-device run."""
    _run("""
        import numpy as np
        from repro.compat import make_mesh, use_mesh
        from repro.core import SiliconMR, tasks
        from repro.pipeline import Experiment, ExperimentConfig

        dss = [tasks.narma10(360, seed=s) for s in range(8)]
        batch = (np.stack([d.inputs_train for d in dss]),
                 np.stack([d.targets_train for d in dss]),
                 np.stack([d.inputs_test for d in dss]),
                 np.stack([d.targets_test for d in dss]))
        cfg = ExperimentConfig(model=SiliconMR(), n_nodes=32, washout=40,
                               ridge_l2=(1e-6, 1e-4))
        res_single = Experiment(cfg).run(*batch)

        mesh = make_mesh((8,), ("data",))
        with use_mesh(mesh):
            res_mesh = Experiment(cfg).run(*batch)
        np.testing.assert_allclose(res_mesh.nrmse, res_single.nrmse, atol=1e-4)
        assert np.all(res_mesh.nrmse < 1.0)
        print("sharded experiment OK", np.round(res_mesh.nrmse, 3))
    """)


def test_session_slab_shards_over_mesh():
    """The online serving slab (pipeline/session) under a real 8-device mesh:
    SessionState leaves and the per-tick chunks shard over the batch axis via
    explicit NamedShardings, the jitted step runs distributed, and the solved
    readout / λ choice / reservoir carry match the single-device run."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.compat import make_mesh
        from repro.core import SiliconMR
        from repro.core.masking import make_mask
        from repro.pipeline.session import (SessionConfig, _session_step,
                                            session_init, session_solve)

        b, n, k, chunk = 8, 16, 96, 24
        cfg = SessionConfig(model=SiliconMR(), n_nodes=n, washout=24,
                            ridge_l2=(1e-6, 1e-4), chunk_k=chunk,
                            forgetting=0.99, state_method="fast",
                            use_kernel=False)
        mask = make_mask(n, seed=3)
        rng = np.random.default_rng(0)
        j = jnp.asarray(rng.uniform(0, 1, (b, k)), jnp.float32)
        y = jnp.asarray(rng.standard_normal((b, k)), jnp.float32)
        step = jax.jit(_session_step, static_argnames=("cfg", "refresh"))

        def drive(place):
            state = jax.tree_util.tree_map(place, session_init(cfg, b))
            preds = []
            for lo in range(0, k, chunk):
                y_hat, state = step(cfg, mask, state,
                                    place(j[:, lo:lo + chunk]),
                                    place(y[:, lo:lo + chunk]), refresh=True)
                preds.append(np.asarray(y_hat))
            return session_solve(cfg, state), np.concatenate(preds, axis=1)

        ref, preds_ref = drive(lambda x: x)
        mesh = make_mesh((8,), ("data",))
        shard = NamedSharding(mesh, P("data"))
        out, preds_mesh = drive(lambda x: jax.device_put(x, shard))
        assert len(out.g.sharding.device_set) == 8, out.g.sharding
        # the distributed vmapped eigh differs from single-device at the
        # last f32 digits -> readout within 1e-4
        np.testing.assert_allclose(np.asarray(out.w), np.asarray(ref.w),
                                   atol=1e-4)
        np.testing.assert_array_equal(np.asarray(out.lam_idx),
                                      np.asarray(ref.lam_idx))
        np.testing.assert_allclose(np.asarray(out.s), np.asarray(ref.s),
                                   atol=1e-6)
        np.testing.assert_allclose(preds_mesh, preds_ref, atol=1e-4)
        print("sharded session OK")
    """)
