"""Composable reservoir graphs (DESIGN.md §13).

Pins the contracts of the composed-topology machinery:

* **depth-1 is the legacy reservoir, bit for bit** — a depth-1/loops-1
  graph's states, streamed fit and Experiment run reproduce the single-mask
  path exactly, on every state method;
* **per-stage carries resume bit-exactly** — chunking the composed chain at
  ANY split replays the uninterrupted arithmetic (the hypothesis property in
  tests/test_properties.py generalises the fixed points here);
* **shared-readout WDM** agrees with the materialized concat-feature Gram
  fit, and reduces to the per-channel fit at R = 1;
* **no stage materialises a full-T block** on the streamed path (the jaxpr
  contract the repro.analysis entry points gate in CI).
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.analysis import NoStateTensor, Program, check_rules
from repro.core import (ReservoirGraph, ReservoirStage, SiliconMR,
                        build_stage_masks, chain, generate_channel_states,
                        generate_states, graph_states, make_mask, tasks)
from repro.core.graph import stage_link_drive, stage_states
from repro.pipeline import (Experiment, ExperimentConfig, WDMExperiment,
                            fit_ridge, fit_ridge_batched, fit_ridge_streaming,
                            fit_ridge_streaming_composed,
                            fit_ridge_streaming_shared,
                            fit_ridge_streaming_wdm)

MODEL = SiliconMR()
LAMS = (1e-6, 1e-4)
B, K, N, W0, CHUNK = 3, 90, 12, 10, 32   # K % CHUNK != 0: ragged tail


def _stream(seed, b=B, k=K):
    rng = np.random.default_rng(seed)
    j = jnp.asarray(rng.uniform(0.05, 0.95, (b, k)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((b, k)), jnp.float32)
    return j, y


def _graph2():
    """Depth-2 chain with a multi-loop first stage (width 2·12 + 7 = 31)."""
    return chain(
        ReservoirStage(model=MODEL, n_nodes=N, loops=2, mask_seed=3),
        ReservoirStage(model=MODEL, n_nodes=7, mask_seed=11, link="sin2"))


# ---------------------------------------------------------------------------
# Graph construction and validation
# ---------------------------------------------------------------------------


def test_graph_shapes_and_layout():
    g = _graph2()
    assert g.depth == 2 and g.width == 2 * N + 7
    assert g.carry_layout == ((2, N), (1, 7))
    masks = build_stage_masks(g)
    assert masks[0].shape == (2, N) and masks[1].shape == (1, 7)
    # loop masks are distinct phases of the seed ladder
    assert not np.array_equal(np.asarray(masks[0][0]), np.asarray(masks[0][1]))
    np.testing.assert_array_equal(np.asarray(masks[0][0]),
                                  np.asarray(make_mask(N, seed=3)))


def test_graph_validation():
    with pytest.raises(ValueError, match="at least one stage"):
        ReservoirGraph(stages=())
    with pytest.raises(ValueError, match="loops"):
        ReservoirStage(loops=0)
    with pytest.raises(ValueError, match="unknown link"):
        ReservoirStage(link="tanh")
    with pytest.raises(ValueError, match="stage mask stacks"):
        graph_states(_graph2(), jnp.zeros((B, K)), (jnp.zeros((2, N)),))


def test_per_channel_masks_unique():
    g = _graph2()
    masks = build_stage_masks(g, channels=3)
    assert masks[0].shape == (3, 2, N)
    flat = np.asarray(masks[0]).reshape(6, N)
    assert len({tuple(row) for row in flat}) == 6  # no (channel, loop) reuse


# ---------------------------------------------------------------------------
# Depth-1 special case == legacy reservoir, bitwise
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["ref", "fast", "kernel"])
def test_depth1_states_bitwise(method):
    j, _ = _stream(0)
    st = ReservoirStage(model=MODEL, n_nodes=N, mask_seed=5)
    g = chain(st)
    masks = build_stage_masks(g)
    ref, fin_ref = generate_states(MODEL, j, make_mask(N, seed=5),
                                   method=method, return_final=True)
    got, fin = graph_states(g, j, masks, method=method, return_final=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(fin[0][:, 0]), np.asarray(fin_ref))


@pytest.mark.parametrize("method", ["fast", "kernel"])
def test_depth1_streaming_fit_bitwise(method):
    """Composed streamed fit at depth 1 == fit_ridge_streaming, bit for bit
    (weights, λ index, and the train -> test carry)."""
    j, y = _stream(1)
    st = ReservoirStage(model=MODEL, n_nodes=N, mask_seed=5)
    g = chain(st)
    masks = build_stage_masks(g)
    w_ref, i_ref, s_ref = fit_ridge_streaming(
        MODEL, make_mask(N, seed=5), j, y, washout=W0, chunk_k=CHUNK,
        lambdas=LAMS, state_method=method, use_kernel=True)
    w_c, i_c, s_c = fit_ridge_streaming_composed(
        g, masks, j, y, washout=W0, chunk_k=CHUNK, lambdas=LAMS,
        state_method=method, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(w_c), np.asarray(w_ref))
    np.testing.assert_array_equal(np.asarray(i_c), np.asarray(i_ref))
    np.testing.assert_array_equal(np.asarray(s_c[0][:, 0]), np.asarray(s_ref))


def test_depth1_experiment_topology_bitwise():
    """ExperimentConfig.topology at depth 1 reproduces the legacy streaming
    Experiment exactly — predictions, metrics, weights."""
    ds = tasks.narma10(420, seed=2)
    base = dict(n_nodes=N, washout=W0, state_noise_rel=0.0,
                stream_chunk_k=CHUNK, state_method="fast", ridge_l2=LAMS)
    r0 = Experiment(ExperimentConfig(**base)).run_dataset(ds)
    g = chain(ReservoirStage(model=MODEL, n_nodes=N, mask_seed=1))
    r1 = Experiment(ExperimentConfig(**base, topology=g)).run_dataset(ds)
    np.testing.assert_array_equal(r0.y_pred, r1.y_pred)
    np.testing.assert_array_equal(r0.nrmse, r1.nrmse)
    np.testing.assert_array_equal(r0.readout_w, r1.readout_w)
    np.testing.assert_array_equal(r0.lam, r1.lam)


# ---------------------------------------------------------------------------
# Composed chain: oracle parity + chunk-resume bit-exactness
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["fast", "kernel"])
def test_composed_fit_matches_materialized_oracle(method):
    """Streamed composed fit ≈ Gram fit of the materialized graph_states
    features: same λ choice, per-stage carries exactly the oracle's, and
    *predictions* at parity.  The comparison is prediction-level, not raw
    weights: a multi-loop stage's shared drive makes the composed Gram
    genuinely rank-deficient (cond ≈ 1/eps), so the weight vector is only
    unique up to the null space — f32 association differences between the
    two accumulation orders move w along it while X·w stays put."""
    from repro.pipeline import with_bias
    j, y = _stream(2)
    g = _graph2()
    masks = build_stage_masks(g)
    w_s, i_s, s_s = fit_ridge_streaming_composed(
        g, masks, j, y, washout=W0, chunk_k=CHUNK, lambdas=LAMS,
        state_method=method, use_kernel=True)
    feats, carr = graph_states(g, j, masks, method=method, return_final=True)
    w_m, i_m = fit_ridge_batched(feats[:, W0:], y[:, W0:], lambdas=LAMS,
                                 use_kernel=True)
    assert np.array_equal(np.asarray(i_s), np.asarray(i_m))
    x = np.asarray(with_bias(feats[:, W0:]))
    p_s = np.einsum("btf,bfc->btc", x, np.asarray(w_s))
    p_m = np.einsum("btf,bfc->btc", x, np.asarray(w_m))
    np.testing.assert_allclose(p_s, p_m, atol=0.02)
    for got, ref in zip(s_s, carr):
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=5e-7)


@pytest.mark.parametrize("cuts", [[13], [32, 64], [7, 40, 41, 89]],
                         ids=["mid", "aligned", "ragged"])
def test_composed_resume_bit_exact(cuts):
    """Chunking the composed chain at fixed splits replays the exact
    arithmetic of the uninterrupted run — features AND every stage carry
    (the hypothesis property generalises the splits; this mirror keeps the
    invariant exercised on hypothesis-free images)."""
    j, _ = _stream(3)
    g = _graph2()
    masks = build_stage_masks(g)
    full, fin = graph_states(g, j, masks, method="fast", return_final=True)
    bounds = [0] + cuts + [K]
    s = None
    parts = []
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        states, s = graph_states(g, j[:, lo:hi], masks, s0=s, method="fast",
                                 return_final=True)
        parts.append(np.asarray(states))
    np.testing.assert_array_equal(np.concatenate(parts, axis=1),
                                  np.asarray(full))
    for got, ref in zip(s, fin):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_multi_loop_stage_is_lane_fold():
    """A loops=L stage equals L independent single-mask reservoirs sharing
    the drive — the lane fold adds no coupling between loops."""
    j, _ = _stream(4)
    st = ReservoirStage(model=MODEL, n_nodes=N, loops=2, mask_seed=3)
    masks = build_stage_masks(chain(st))[0]
    feats, carry = stage_states(st, j, masks, None, method="fast")
    for l in range(2):
        ref, fin = generate_states(MODEL, j, masks[l], method="fast",
                                   return_final=True)
        np.testing.assert_array_equal(
            np.asarray(feats[..., l * N:(l + 1) * N]), np.asarray(ref))
        np.testing.assert_array_equal(np.asarray(carry[:, l]), np.asarray(fin))


def test_link_drive_bounded():
    """The default saturable link keeps any feature scale inside (-1, 1) —
    the drive range downstream SiliconMR stages are tuned on."""
    st = ReservoirStage(model=MODEL, n_nodes=4, link="sat", link_gain=50.0)
    f = jnp.asarray(np.random.default_rng(0).uniform(0, 1, (2, 16, 4)),
                    jnp.float32)
    p = stage_link_drive(st, f)
    assert p.shape == (2, 16)
    assert float(jnp.max(jnp.abs(p))) < 1.0


# ---------------------------------------------------------------------------
# Shared-readout WDM
# ---------------------------------------------------------------------------


def test_shared_readout_matches_materialized_concat():
    """Shared-readout streamed fit ≈ one-shot Gram fit over the materialized
    [K, R·N] concat features; carry exact, λ index equal."""
    rng = np.random.default_rng(5)
    r = 4
    masks = jnp.stack([make_mask(N, seed=20 + i) for i in range(r)])
    j = jnp.asarray(rng.uniform(0.05, 0.95, (r, K)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((K,)), jnp.float32)
    w_s, i_s, s_s = fit_ridge_streaming_shared(
        MODEL, masks, j, y, washout=W0, chunk_k=CHUNK, lambdas=LAMS,
        state_method="fast", use_kernel=True)
    assert w_s.shape == (r * N + 1, 1)
    st, fin = generate_channel_states(MODEL, j, masks, method="fast",
                                      return_final=True)
    x = jnp.moveaxis(st, 0, 1).reshape(K, r * N)[W0:]
    w_m, i_m = fit_ridge(x, y[W0:], lambdas=LAMS, use_kernel=True)
    assert int(i_s) == int(i_m)
    np.testing.assert_allclose(np.asarray(w_s), np.asarray(w_m),
                               atol=0.1, rtol=0.1)
    np.testing.assert_array_equal(np.asarray(s_s), np.asarray(fin))


def test_shared_readout_r1_equals_per_channel():
    """At R = 1 the cross-channel Gram has no cross terms: the shared fit
    IS the per-channel WDM fit."""
    rng = np.random.default_rng(6)
    masks = make_mask(N, seed=9)[None]
    j = jnp.asarray(rng.uniform(0.05, 0.95, (1, K)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((K,)), jnp.float32)
    w_s, i_s, s_s = fit_ridge_streaming_shared(
        MODEL, masks, j, y, washout=W0, chunk_k=CHUNK, lambdas=LAMS,
        state_method="fast", use_kernel=True)
    w_p, i_p, s_p = fit_ridge_streaming_wdm(
        MODEL, masks, j, y[None], washout=W0, chunk_k=CHUNK, lambdas=LAMS,
        state_method="fast", use_kernel=True)
    np.testing.assert_array_equal(np.asarray(w_s), np.asarray(w_p[0]))
    assert int(i_s) == int(i_p[0])
    np.testing.assert_array_equal(np.asarray(s_s), np.asarray(s_p))


def test_wdm_shared_experiment_runs():
    """WDMExperiment(shared_readout=True): ensemble-level result shapes and
    a finite NRMSE on a real task."""
    ds = tasks.narma10(420, seed=3)
    cfg = ExperimentConfig(n_nodes=N, washout=W0, state_noise_rel=0.0,
                           stream_chunk_k=CHUNK, state_method="fast",
                           ridge_l2=LAMS)
    r = 3
    tr = np.stack([ds.inputs_train] * r)
    te = np.stack([ds.inputs_test] * r)
    res = WDMExperiment(cfg, r, shared_readout=True).run(
        tr, ds.targets_train, te, ds.targets_test)
    assert res.nrmse.shape == (1,) and np.isfinite(res.nrmse).all()
    assert res.readout_w.shape == (1, r * N + 1)
    assert res.y_pred.shape == (1, ds.targets_test.shape[0])


def test_wdm_shared_validation():
    cfg_nostream = ExperimentConfig(n_nodes=N, state_noise_rel=0.0)
    with pytest.raises(ValueError, match="streaming"):
        WDMExperiment(cfg_nostream, 2, shared_readout=True)
    g = chain(ReservoirStage(model=MODEL, n_nodes=N))
    cfg_topo = ExperimentConfig(n_nodes=N, state_noise_rel=0.0,
                                stream_chunk_k=CHUNK, topology=g)
    with pytest.raises(ValueError, match="shared_readout"):
        WDMExperiment(cfg_topo, 2, shared_readout=True)


def test_topology_requires_streaming():
    g = chain(ReservoirStage(model=MODEL, n_nodes=N))
    with pytest.raises(ValueError, match="stream_chunk_k"):
        ExperimentConfig(n_nodes=N, topology=g, state_noise_rel=0.0)


def test_wdm_per_channel_topology():
    """WDMExperiment with a composed topology: per-channel stage masks,
    per-channel readouts of width graph.width."""
    ds = tasks.narma10(420, seed=4)
    g = _graph2()
    cfg = ExperimentConfig(n_nodes=N, washout=W0, state_noise_rel=0.0,
                           stream_chunk_k=CHUNK, state_method="fast",
                           ridge_l2=LAMS, topology=g)
    r = 2
    tr = np.stack([ds.inputs_train] * r)
    te = np.stack([ds.inputs_test] * r)
    trt = np.stack([ds.targets_train] * r)
    tet = np.stack([ds.targets_test] * r)
    res = WDMExperiment(cfg, r).run(tr, trt, te, tet)
    assert res.nrmse.shape == (r,) and np.isfinite(res.nrmse).all()
    assert res.readout_w.shape == (r, g.width + 1)


# ---------------------------------------------------------------------------
# Structural contract: no stage materialises a full-T block
# ---------------------------------------------------------------------------


def test_composed_fit_jaxpr_no_stage_tensor():
    """Depth-3 streamed composed fit holds NO tensor carrying the full
    stream axis at even the smallest stage's scale — each stage lives at
    chunk granularity inside the one scan."""
    g = chain(ReservoirStage(model=MODEL, n_nodes=N, loops=2, mask_seed=1),
              ReservoirStage(model=MODEL, n_nodes=N, mask_seed=7),
              ReservoirStage(model=MODEL, n_nodes=8, mask_seed=13))
    masks = build_stage_masks(g)
    j, y = _stream(7, k=160)
    prog = Program(
        lambda jj, yy: fit_ridge_streaming_composed(
            g, masks, jj, yy, washout=W0, chunk_k=CHUNK, lambdas=LAMS,
            state_method="kernel", use_kernel=True),
        (j, y))
    w_min = min(st.n_nodes for st in g.stages)
    viols = check_rules(prog, [NoStateTensor(160, B * 160 * w_min,
                                             what="stage tensor")])
    assert not viols, [str(v) for v in viols]


# ---------------------------------------------------------------------------
# Memory-capacity suite rides the vmapped Experiment
# ---------------------------------------------------------------------------


def test_memory_capacity_suite_one_vmapped_experiment():
    """The MC probe runs as ONE vmapped Experiment: B seeds × max_delay
    target channels in a single jit call, predictions [B, T, D] scored by
    metrics.memory_capacity_score.  A 40-node DFR reconstructs several
    delays (MC measured ~3.2-3.8 here) and MC is bounded by the channel
    count."""
    from repro.core.metrics import memory_capacity_score
    d_max = 10
    batch = [tasks.memory_capacity(700, max_delay=d_max, seed=s)
             for s in range(3)]
    tr_in, tr_tg, te_in, te_tg = (
        np.stack([getattr(d, f) for d in batch])
        for f in ("inputs_train", "targets_train",
                  "inputs_test", "targets_test"))
    cfg = ExperimentConfig(model=MODEL, n_nodes=40, washout=30,
                           ridge_l2=(1e-8, 1e-6, 1e-4))
    res = Experiment(cfg).run(tr_in, tr_tg, te_in, te_tg)
    assert res.y_pred.shape == te_tg.shape
    mcs = [memory_capacity_score(te_tg[b], res.y_pred[b]) for b in range(3)]
    for mc in mcs:
        assert 1.5 < mc < d_max, mcs
    assert float(np.mean(mcs)) > 2.5, mcs
