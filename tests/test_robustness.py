"""Fault injection, in-graph quarantine, and crash-recoverable serving.

Pins the ISSUE 8 contracts (DESIGN.md §12):

* the fault harness is *traced and bitwise-neutral*: under ``no_faults`` the
  fault-injected step equals the clean ``session_step`` bit for bit, so a
  clean/faulted pair is an apples-to-apples comparison of one program;
* poisoning faults (NaN/Inf drive, carry corruption) trip the in-graph
  quarantine: the row is reset in place, flagged and counted, its neighbours
  bitwise untouched, and no non-finite prediction ever reaches the host;
* degradation faults (stuck-at node, thermal detuning, laser droop,
  digitizer saturation) perturb only their own slot and never trip the
  guard — they are physics drift, not poison;
* a quarantined slot *re-converges* once its fault window closes;
* the ``DFRServer`` layers work: ingest validation drops non-finite ticks,
  ``max_poison`` evicts dead slots, and a kill-and-restore through
  ``CheckpointStore`` resumes bit-exactly (faults replaying identically).

The program-shape contracts of the faulted step (no host callback, no
full-stream tensor, one Pallas launch pair) are registered entry points in
``repro.analysis`` — tests/test_analysis.py and CI run them.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SiliconMR
from repro.core.masking import make_mask
from repro.pipeline.ridge import guard_readout
from repro.pipeline.session import (SessionConfig, session_init,
                                    session_step)
from repro.robustness import (faulted_rows, faulty_step, inject_carry,
                              inject_inputs, no_faults, on_rows, run_soak)

N, B, WASH, CHUNK = 16, 4, 24, 24
LAMS = (1e-8, 1e-6, 1e-4)
MASK = jnp.asarray(make_mask(N, seed=3))


def _cfg(**kw) -> SessionConfig:
    base = dict(model=SiliconMR(), n_nodes=N, washout=WASH, ridge_l2=LAMS,
                chunk_k=CHUNK, refresh_every=2, state_method="fast")
    base.update(kw)
    return SessionConfig(**base)


def _chunks(seed: int, ticks: int, b: int = B, k: int = CHUNK):
    rng = np.random.default_rng(seed)
    return (jnp.asarray(rng.uniform(0, 1, (b, ticks * k)), jnp.float32),
            jnp.asarray(rng.uniform(0, 1, (b, ticks * k)), jnp.float32))


def _run_clean(cfg, j, y, ticks):
    st = session_init(cfg, B)
    outs = []
    for t in range(ticks):
        sl = slice(t * cfg.chunk_k, (t + 1) * cfg.chunk_k)
        yh, st = session_step(cfg, MASK, st, j[:, sl], y[:, sl],
                              refresh=(t % cfg.refresh_every) == 0)
        outs.append(np.asarray(yh))
    return np.concatenate(outs, axis=1), jax.device_get(st)


def _run_faulted(cfg, spec, j, y, ticks, seed=0):
    st = session_init(cfg, B)
    outs = []
    for t in range(ticks):
        sl = slice(t * cfg.chunk_k, (t + 1) * cfg.chunk_k)
        yh, st = faulty_step(cfg, MASK, spec, st, j[:, sl], y[:, sl], t,
                             seed=seed, refresh=(t % cfg.refresh_every) == 0)
        outs.append(np.asarray(yh))
    return np.concatenate(outs, axis=1), jax.device_get(st)


# ---------------------------------------------------------------------------
# fault harness: neutrality + targeting
# ---------------------------------------------------------------------------


def test_neutral_spec_is_bitwise_identity():
    """no_faults wraps session_step with zero numerical footprint."""
    cfg = _cfg()
    j, y = _chunks(0, 4)
    yh_a, st_a = _run_clean(cfg, j, y, 4)
    yh_b, st_b = _run_faulted(cfg, no_faults(B), j, y, 4)
    np.testing.assert_array_equal(yh_a, yh_b)
    for la, lb in zip(st_a, st_b):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_injectors_neutral_and_targeted():
    spec = no_faults(B)
    rng = np.random.default_rng(1)
    jc = jnp.asarray(rng.uniform(0, 1, (B, CHUNK)), jnp.float32)
    s = jnp.asarray(rng.standard_normal((B, N)), jnp.float32)
    np.testing.assert_array_equal(np.asarray(inject_inputs(spec, jc, 3)),
                                  np.asarray(jc))
    np.testing.assert_array_equal(np.asarray(inject_carry(spec, s, 3)),
                                  np.asarray(s))
    armed = on_rows(spec, [1], stuck_node=2, stuck_value=0.5)
    out = np.array(inject_carry(armed, s, 3))
    assert out[1, 2] == 0.5
    out[1, 2] = np.asarray(s)[1, 2]
    np.testing.assert_array_equal(out, np.asarray(s))
    assert np.asarray(faulted_rows(armed)).tolist() == [False, True,
                                                        False, False]


def test_fault_window_gates_injection():
    """Outside [from_tick, until_tick) the armed spec is still an identity."""
    spec = on_rows(no_faults(B), [0], nan_prob=1.0, from_tick=2, until_tick=3)
    rng = np.random.default_rng(2)
    jc = jnp.asarray(rng.uniform(0, 1, (B, CHUNK)), jnp.float32)
    for tick, fires in ((0, False), (1, False), (2, True), (3, False)):
        out = np.asarray(inject_inputs(spec, jc, tick))
        assert np.isnan(out[0]).any() == fires, tick
        np.testing.assert_array_equal(out[1:], np.asarray(jc)[1:])


# ---------------------------------------------------------------------------
# in-graph quarantine: containment + isolation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fault", [dict(nan_prob=1.0), dict(inf_prob=1.0),
                                   dict(corrupt_prob=1.0)])
def test_poison_quarantines_row_and_isolates_neighbours(fault):
    cfg = _cfg()
    j, y = _chunks(3, 3)
    yh_clean, st_clean = _run_clean(cfg, j, y, 3)
    spec = on_rows(no_faults(B), [1], **fault)
    yh, st = _run_faulted(cfg, spec, j, y, 3)
    # containment: flagged, counted, reset, and never a NaN to the host
    assert np.asarray(st.quarantined)[1]
    assert np.asarray(st.poison)[1] == 3
    assert np.isfinite(yh).all()
    np.testing.assert_array_equal(yh[1], np.zeros_like(yh[1]))
    # the in-graph reset rewound the row to the dark state this tick
    assert np.asarray(st.s)[1].sum() == 0 and np.asarray(st.step)[1] == 0
    # isolation: every other slot is bitwise the clean run
    ok = np.asarray([True, False, True, True])
    np.testing.assert_array_equal(yh[ok], yh_clean[ok])
    for la, lb in zip(st_clean, st):
        np.testing.assert_array_equal(np.asarray(la)[ok], np.asarray(lb)[ok])


def test_degradation_faults_perturb_without_quarantine():
    """Stuck node / detuning / droop / saturation are drift, not poison.

    Five ticks so the comparison covers predictions made with a *solved*
    readout — with washout = 1 chunk and refresh_every = 2 the first
    non-zero readout applies from tick 3 on.
    """
    cfg = _cfg()
    j, y = _chunks(4, 5)
    yh_clean, _ = _run_clean(cfg, j, y, 5)
    spec = on_rows(no_faults(B), [0], stuck_node=3, stuck_value=0.5)
    spec = on_rows(spec, [1], detune_amp=0.5, detune_period=64.0)
    spec = on_rows(spec, [2], droop_rate=0.02)
    spec = on_rows(spec, [3], sat_level=0.3)
    yh, st = _run_faulted(cfg, spec, j, y, 5)
    assert np.isfinite(yh).all()
    assert not np.asarray(st.quarantined).any()
    assert np.asarray(st.poison).sum() == 0
    for i in range(B):  # each fault measurably moves its own slot
        assert not np.array_equal(yh[i], yh_clean[i]), i


def test_quarantined_slot_reconverges_after_window():
    """The acceptance gate: poison for 4 ticks, clean tail -> learns again."""
    cfg = _cfg(n_nodes=24, washout=32, chunk_k=32)
    spec = on_rows(no_faults(B), [2], corrupt_prob=1.0, until_tick=4)
    rep = run_soak(cfg, spec, n_ticks=24)
    assert rep["healthy_bitwise_identical"]
    assert rep["output_all_finite"]
    assert rep["quarantine_events"] == [0, 0, 4, 0]
    assert rep["quarantine_ticks"][2] == [0, 1, 2, 3]
    # post-window the slot's tail SER is real signal, not chance (0.75 for
    # 4-level symbols), and comparable to the never-faulted reference
    assert rep["tail_ser_faulty"] < 0.5
    assert rep["tail_ser_faulty"] <= rep["tail_ser_clean"] + 0.15


def test_guard_off_documents_the_failure_mode():
    """Without the guard one NaN tick poisons the slot permanently — the
    exact behaviour DESIGN.md §12 exists to kill."""
    cfg = _cfg(guard=False)
    j, y = _chunks(5, 3)
    spec = on_rows(no_faults(B), [1], nan_prob=1.0, until_tick=1)
    yh, st = _run_faulted(cfg, spec, j, y, 3)
    assert np.isnan(yh[1]).any()            # NaN reached the host
    assert np.isnan(np.asarray(st.g)[1]).any()   # ... and stuck in the Gram
    assert np.isfinite(yh[[0, 2, 3]]).all()  # rows stay independent either way


def test_guard_readout_falls_back_per_row():
    rng = np.random.default_rng(6)
    w_new = jnp.asarray(rng.standard_normal((3, 5, 1)), jnp.float32)
    w_new = w_new.at[1, 0, 0].set(jnp.nan)
    idx_new = jnp.asarray([2, 2, 0], jnp.int32)
    w_last = jnp.asarray(rng.standard_normal((3, 5, 1)), jnp.float32)
    idx_last = jnp.asarray([1, 1, 1], jnp.int32)
    w, idx = guard_readout(w_new, idx_new, w_last, idx_last)
    np.testing.assert_array_equal(np.asarray(w[0]), np.asarray(w_new[0]))
    np.testing.assert_array_equal(np.asarray(w[1]), np.asarray(w_last[1]))
    np.testing.assert_array_equal(np.asarray(w[2]), np.asarray(w_new[2]))
    assert np.asarray(idx).tolist() == [2, 1, 0]


def test_guard_bitwise_invisible_on_kernel_path():
    """Guarded vs unguarded step on clean data: bit-identical (Pallas path
    included), so enabling the default guard costs no numerics anywhere."""
    cfg_on = _cfg(state_method="kernel", use_kernel=True)
    cfg_off = _cfg(state_method="kernel", use_kernel=True, guard=False)
    j, y = _chunks(7, 3)
    yh_a, st_a = _run_clean(cfg_on, j, y, 3)
    yh_b, st_b = _run_clean(cfg_off, j, y, 3)
    np.testing.assert_array_equal(yh_a, yh_b)
    for name, la, lb in zip(st_a._fields, st_a, st_b):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=name)


# ---------------------------------------------------------------------------
# DFRServer: ingest validation, eviction, crash recovery
# ---------------------------------------------------------------------------


def _mk_requests(n, length, seed=0):
    from repro.launch.serve_dfr import StreamRequest
    rng = np.random.default_rng(seed)
    return [StreamRequest(rid=r, j=rng.random(length).astype(np.float32),
                          y=rng.random(length).astype(np.float32))
            for r in range(n)]


def test_server_ingest_drops_nonfinite_and_clamps(tmp_path):
    from repro.launch.serve_dfr import DFRServer
    cfg = _cfg()
    server = DFRServer(cfg, 2, ingest_range=(0.0, 1.0))
    server.warmup()
    reqs = _mk_requests(2, 3 * CHUNK, seed=8)
    reqs[0].j[CHUNK + 3] = np.nan          # one bad sample -> tick dropped
    reqs[1].j[5] = 7.0                     # out of range -> clamped
    for r in reqs:
        server.submit(r)
    server.drain()
    stats = server.stats()
    assert stats["dropped_ticks"] == 1 and stats["dropped_values"] == 1
    assert stats["clamped_values"] == 1
    assert stats["completed"] == 2
    # the sanitized run never tripped the in-graph guard, and every emitted
    # prediction (including the dropped tick's zero-drive chunk) is finite
    assert stats["quarantine_events"] == 0
    for r in server.completed:
        assert np.isfinite(np.concatenate(r.y_hat)).all()


def test_server_evicts_dead_slot():
    from repro.launch.serve_dfr import DFRServer
    cfg = _cfg()
    spec = on_rows(no_faults(2), [0], corrupt_prob=1.0)  # slot 0 always dies
    server = DFRServer(cfg, 2, fault_spec=spec, max_poison=2)
    server.warmup()
    for r in _mk_requests(2, 8 * CHUNK, seed=9):
        server.submit(r)
    server.drain()
    stats = server.stats()
    assert stats["evictions"] == 1 and len(server.evicted) == 1
    assert server.evicted[0].rid == 0
    assert stats["completed"] == 1
    assert stats["quarantine_events"] >= 2


def test_server_kill_and_restore_is_bit_exact(tmp_path):
    from repro.launch.serve_dfr import DFRServer
    cfg = _cfg()
    spec = on_rows(no_faults(2), [1], nan_prob=0.02, until_tick=5)

    def fresh(ckpt=None, every=0):
        s = DFRServer(cfg, 2, fault_spec=spec, fault_seed=11,
                      checkpoint_dir=ckpt, checkpoint_every=every)
        s.warmup()
        return s

    ref = fresh()
    for r in _mk_requests(3, 5 * CHUNK, seed=10):
        ref.submit(r)
    ref.drain()
    expect = {r.rid: np.concatenate(r.y_hat) for r in ref.completed}

    crash = fresh(ckpt=str(tmp_path), every=2)
    for r in _mk_requests(3, 5 * CHUNK, seed=10):
        crash.submit(r)
    for _ in range(5):
        crash.step()
    crash.close()                          # "kill" mid-stream

    resumed = fresh(ckpt=str(tmp_path))
    assert resumed.restore() == 4
    assert resumed.stats()["restored_from"] == 4
    resumed.drain()
    got = {r.rid: np.concatenate(r.y_hat) for r in resumed.completed}
    assert set(got) == set(expect)
    for rid in expect:
        np.testing.assert_array_equal(expect[rid], got[rid])
