"""Sharding rules on the (abstract) production meshes: every param of every
arch gets a valid PartitionSpec (divisible, no axis reuse), and the cache
specs shard what must be sharded."""

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import abstract_mesh
from repro.configs import get_config, input_specs, list_archs
from repro.models import init_params
from repro.parallel.sharding import batch_pspec, cache_pspecs, param_pspecs, spec_for

POD = abstract_mesh((16, 16), ("data", "model"))
MULTIPOD = abstract_mesh((2, 16, 16), ("pod", "data", "model"))

ARCHS = list_archs(include_extras=True)


def _check_tree(cfg, mesh):
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    specs = param_pspecs(cfg, mesh)
    flat_sh = jax.tree_util.tree_leaves(
        shapes, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    flat_sp = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_sh) == len(flat_sp)
    n_sharded = 0
    for sh, sp in zip(flat_sh, flat_sp):
        used = set()
        for dim, entry in zip(sh.shape, tuple(sp) + (None,) * len(sh.shape)):
            axes = entry if isinstance(entry, tuple) else ((entry,) if entry else ())
            size = 1
            for a in axes:
                assert a in mesh.shape, (sp, a)
                assert a not in used, f"axis {a} reused in {sp}"
                used.add(a)
                size *= mesh.shape[a]
            assert dim % size == 0, (sh.shape, sp)
        if used:
            n_sharded += 1
    return n_sharded, len(flat_sh)


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("mesh", [POD, MULTIPOD], ids=["pod", "multipod"])
def test_param_specs_valid(arch, mesh):
    cfg = get_config(arch)
    n_sharded, n_total = _check_tree(cfg, mesh)
    # the bulk of parameters must actually shard
    assert n_sharded > 0.5 * n_total, (arch, n_sharded, n_total)


def test_fsdp_fallback_shards_big_dims():
    """starcoder2 (24 heads) must still shard its big matrices over 'model'."""
    spec = spec_for(("embed", "heads", "hd"), (3072, 24, 128), POD, "fsdp")
    # heads (24) can't take model=16; embed dim picks up ("data","model")
    assert spec[0] in (("data", "model"), "data")
    flat = [a for e in spec if e for a in (e if isinstance(e, tuple) else (e,))]
    assert "model" in flat, spec


@pytest.mark.parametrize("arch,shape", [
    ("granite-8b", "decode_32k"),       # kv=8 not divisible -> seq over model
    ("gemma-7b", "decode_32k"),         # kv=16 divisible -> kv over model
    ("jamba-v0.1-52b", "long_500k"),    # batch 1 -> seq over data+model
    ("xlstm-1.3b", "long_500k"),        # recurrent states shard inner dims
])
def test_cache_specs_shard_the_big_buffers(arch, shape):
    cfg = get_config(arch)
    specs = input_specs(cfg, shape)
    cspecs = cache_pspecs(cfg, POD, specs["cache"])
    # every multi-GiB leaf must be sharded over >= 16 devices
    flat_shapes = jax.tree_util.tree_leaves(
        specs["cache"]["units"], is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    flat_specs = jax.tree_util.tree_leaves(
        cspecs["units"], is_leaf=lambda x: isinstance(x, P))
    for sh, sp in zip(flat_shapes, flat_specs):
        nbytes = int(np.prod(sh.shape)) * sh.dtype.itemsize
        shard = 1
        for entry in sp:
            for a in (entry if isinstance(entry, tuple) else ((entry,) if entry else ())):
                shard *= POD.shape[a]
        assert nbytes / shard < 6 * 2**30, (arch, shape, sh.shape, sp, nbytes / shard)


def test_batch_pspec():
    assert batch_pspec(POD) == P(("data",), None)
    assert batch_pspec(MULTIPOD) == P(("pod", "data"), None)
