"""NL-node model properties: scan equivalence, stability, fading memory."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    MZISine,
    MackeyGlass,
    SiliconMR,
    SiliconMRLiteral,
    generate_states,
    make_mask,
)

MODELS = {
    "mr": SiliconMR(),
    "mr_tpa": SiliconMR(beta_tpa=0.5),
    "mr_literal": SiliconMRLiteral(gamma=0.05),
    "mg": MackeyGlass(),
    "mzi": MZISine(),
}


@pytest.mark.parametrize("name", list(MODELS))
@given(b=st.integers(1, 3), k=st.integers(1, 12), n=st.integers(1, 40),
       seed=st.integers(0, 10))
@settings(max_examples=10, deadline=None)
def test_fast_equals_sequential_oracle(name, b, k, n, seed):
    """period_update (assoc-scan / batched) == node-by-node physical evolution."""
    model = MODELS[name]
    rng = np.random.default_rng(seed)
    j = jnp.asarray(rng.uniform(0, 1, (b, k)), jnp.float32)
    mask = make_mask(n, seed=seed)
    ref = generate_states(model, j, mask, method="ref")
    fast = generate_states(model, j, mask, method="fast")
    np.testing.assert_allclose(np.asarray(fast), np.asarray(ref), atol=5e-6)


@given(seed=st.integers(0, 20), gamma=st.floats(0.1, 0.95))
@settings(max_examples=15, deadline=None)
def test_corrected_mr_bounded(seed, gamma):
    """θ-corrected dynamics are bounded for any γ<1 (DESIGN.md §7)."""
    model = SiliconMR(gamma=gamma)
    rng = np.random.default_rng(seed)
    j = jnp.asarray(rng.uniform(0, 1, (200,)), jnp.float32)
    states = np.asarray(generate_states(model, j, make_mask(50, seed=seed)))
    bound = 1.0 / (1.0 - gamma) + 2.0
    assert np.all(np.isfinite(states))
    assert states.max() < bound, states.max()


def test_literal_mr_diverges():
    """Paper Eq. (6-7) as printed explode for useful γ (DESIGN.md §7)."""
    model = SiliconMRLiteral(gamma=0.9)
    rng = np.random.default_rng(0)
    j = jnp.asarray(rng.uniform(0, 1, (300,)), jnp.float32)
    states = np.asarray(generate_states(model, j, make_mask(100, seed=1)))
    assert states.max() > 1e6


def test_fading_memory():
    """Echo-state property: two different initial states converge under the
    same input drive (necessary for reservoir computing; paper Section II)."""
    model = SiliconMR()
    rng = np.random.default_rng(3)
    j = jnp.asarray(rng.uniform(0, 1, (1, 400)), jnp.float32)
    mask = make_mask(40, seed=1)
    s0a = jnp.zeros((1, 40))
    s0b = jnp.asarray(rng.uniform(0, 1, (1, 40)), jnp.float32)
    sa = np.asarray(generate_states(model, j, mask, s0=s0a))
    sb = np.asarray(generate_states(model, j, mask, s0=s0b))
    d0 = np.abs(sa[:, 0] - sb[:, 0]).max()
    d_end = np.abs(sa[:, -1] - sb[:, -1]).max()
    assert d_end < 1e-3 * max(d0, 1e-9), (d0, d_end)


def test_kernel_method_matches_fast():
    model = SiliconMR()
    rng = np.random.default_rng(5)
    j = jnp.asarray(rng.uniform(0, 1, (2, 9)), jnp.float32)
    mask = make_mask(17, seed=4)
    fast = generate_states(model, j, mask, method="fast")
    kern = generate_states(model, j, mask, method="kernel")
    np.testing.assert_allclose(np.asarray(kern), np.asarray(fast), atol=1e-6)
