"""Readout training: pinv vs ridge vs kernel-path agreement; exact recovery."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import fit_readout


def test_exact_recovery_noiseless():
    """With T >> N and no noise, both methods recover the generating weights."""
    rng = np.random.default_rng(0)
    x = rng.standard_normal((400, 20))
    w_true = rng.standard_normal(21)
    y = x @ w_true[:-1] + w_true[-1]
    for method in ("pinv", "ridge"):
        ro = fit_readout(jnp.asarray(x, jnp.float32), y, method=method, l2=1e-12)
        pred = np.asarray(ro(jnp.asarray(x, jnp.float32)))
        assert np.abs(pred - y).max() < 1e-3, method


@given(t=st.integers(30, 120), n=st.integers(2, 25), seed=st.integers(0, 20))
@settings(max_examples=15, deadline=None)
def test_pinv_and_ridge_agree(t, n, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((t, n))
    y = rng.standard_normal(t)
    a = fit_readout(jnp.asarray(x, jnp.float32), y, method="pinv")
    b = fit_readout(jnp.asarray(x, jnp.float32), y, method="ridge", l2=1e-12)
    pa = np.asarray(a(jnp.asarray(x, jnp.float32)))
    pb = np.asarray(b(jnp.asarray(x, jnp.float32)))
    np.testing.assert_allclose(pa, pb, atol=1e-2, rtol=1e-2)


def test_kernel_path_matches_host_path():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.uniform(0, 1, (300, 50)), jnp.float32)
    y = rng.standard_normal(300)
    a = fit_readout(x, y, l2=1e-8)
    b = fit_readout(x, y, l2=1e-8, use_kernel=True)
    np.testing.assert_allclose(np.asarray(a.w), np.asarray(b.w), atol=1e-3)


def test_multi_output():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((100, 10)), jnp.float32)
    y = rng.standard_normal((100, 3))
    ro = fit_readout(x, y, l2=1e-10)
    assert ro(x).shape == (100, 3)
