"""Pallas kernel validation: shape/dtype sweeps against pure-jnp oracles.

Kernels run in interpret mode on CPU (TPU is the lowering target)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MZISine, MackeyGlass, SiliconMR, make_mask
from repro.kernels.dfr_scan import dfr_scan, dfr_scan_ref
from repro.kernels.ridge_gram import gram_accumulate, gram_ref

MODELS = [SiliconMR(), SiliconMR(beta_tpa=0.7), MackeyGlass(), MZISine()]


@pytest.mark.parametrize("model", MODELS, ids=lambda m: type(m).__name__ + str(getattr(m, "beta_tpa", "")))
@pytest.mark.parametrize("b,k,n", [(1, 5, 7), (3, 11, 17), (5, 7, 64), (2, 3, 129)])
def test_dfr_scan_matches_oracle(model, b, k, n):
    rng = np.random.default_rng(b * 100 + k * 10 + n)
    j = jnp.asarray(rng.uniform(0, 1, (b, k)), jnp.float32)
    mask = make_mask(n, seed=2)
    s0 = jnp.asarray(rng.uniform(0, 0.3, (b, n)), jnp.float32)
    out = dfr_scan(model, j, mask, s0, block_s=1)
    ref = dfr_scan_ref(model, j, mask, s0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dfr_scan_dtypes(dtype):
    """bf16 I/O compares against the *f32* oracle: the kernel carries the
    recurrence in f32 internally (kernels/dfr_scan docstring), so it is more
    accurate than a bf16-carried reference; tolerance covers the bf16
    input/output quantisation only (plus rare branch flips near u == s)."""
    model = SiliconMR()
    rng = np.random.default_rng(0)
    j32 = jnp.asarray(rng.uniform(0, 1, (2, 6)), jnp.float32)
    mask = make_mask(9, seed=1)
    out = dfr_scan(model, j32.astype(dtype), mask, jnp.zeros((2, 9), dtype), block_s=1)
    ref = dfr_scan_ref(model, j32.astype(dtype).astype(jnp.float32), mask, jnp.zeros((2, 9)))
    assert out.dtype == dtype
    tol = 1e-6 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol
    )


def test_dfr_scan_multi_tile_batch():
    """Batch larger than one (S, 128) tile exercises the grid's batch dim."""
    model = SiliconMR()
    rng = np.random.default_rng(1)
    b = 2 * 128 + 17  # forces padding + 2+ tiles at block_s=1
    j = jnp.asarray(rng.uniform(0, 1, (b, 4)), jnp.float32)
    mask = make_mask(5, seed=1)
    s0 = jnp.zeros((b, 5), jnp.float32)
    out = dfr_scan(model, j, mask, s0, block_s=1)
    ref = dfr_scan_ref(model, j, mask, s0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)


@pytest.mark.parametrize("t,f,c", [(100, 37, 1), (600, 128, 2), (257, 150, 1), (64, 129, 3)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gram_matches_oracle(t, f, c, dtype):
    rng = np.random.default_rng(t + f + c)
    x = jnp.asarray(rng.standard_normal((t, f)), dtype)
    y = jnp.asarray(rng.standard_normal((t, c)), dtype)
    g, mom = gram_accumulate(x, y)
    gr, mr = gram_ref(x, y)
    scale_g = max(1e-9, float(jnp.max(jnp.abs(gr))))
    scale_c = max(1e-9, float(jnp.max(jnp.abs(mr))))
    assert float(jnp.max(jnp.abs(g - gr))) / scale_g < 1e-5
    assert float(jnp.max(jnp.abs(mom - mr))) / scale_c < 1e-5


def test_gram_1d_targets():
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.standard_normal((50, 20)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((50,)), jnp.float32)
    g, mom = gram_accumulate(x, y)
    assert g.shape == (20, 20) and mom.shape == (20, 1)
