"""Pallas kernel validation: shape/dtype sweeps against pure-jnp oracles.

Kernels run in interpret mode on CPU (TPU is the lowering target)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MZISine, MackeyGlass, SiliconMR, make_mask
from repro.kernels.dfr_scan import (auto_block_s, dfr_scan, dfr_scan_ref,
                                    min_sublanes, padded_lanes)
from repro.kernels.ridge_gram import (effective_block_t, gram_accumulate,
                                      gram_accumulate_batched,
                                      gram_accumulate_batched_into, gram_ref,
                                      gram_ref_batched)

MODELS = [SiliconMR(), SiliconMR(beta_tpa=0.7), MackeyGlass(), MZISine()]


def _model_id(m):
    return type(m).__name__ + str(getattr(m, "beta_tpa", ""))


@pytest.mark.parametrize("model", MODELS, ids=_model_id)
@pytest.mark.parametrize("b,k,n", [(1, 5, 7), (3, 11, 17), (5, 7, 64), (2, 3, 129)])
def test_dfr_scan_matches_oracle(model, b, k, n):
    rng = np.random.default_rng(b * 100 + k * 10 + n)
    j = jnp.asarray(rng.uniform(0, 1, (b, k)), jnp.float32)
    mask = make_mask(n, seed=2)
    s0 = jnp.asarray(rng.uniform(0, 0.3, (b, n)), jnp.float32)
    out = dfr_scan(model, j, mask, s0, block_s=1)
    ref = dfr_scan_ref(model, j, mask, s0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dfr_scan_dtypes(dtype):
    """bf16 I/O compares against the *f32* oracle: the kernel carries the
    recurrence in f32 internally (kernels/dfr_scan docstring), so it is more
    accurate than a bf16-carried reference; tolerance covers the bf16
    input/output quantisation only (plus rare branch flips near u == s)."""
    model = SiliconMR()
    rng = np.random.default_rng(0)
    j32 = jnp.asarray(rng.uniform(0, 1, (2, 6)), jnp.float32)
    mask = make_mask(9, seed=1)
    out = dfr_scan(model, j32.astype(dtype), mask, jnp.zeros((2, 9), dtype), block_s=1)
    ref = dfr_scan_ref(model, j32.astype(dtype).astype(jnp.float32), mask, jnp.zeros((2, 9)))
    assert out.dtype == dtype
    tol = 1e-6 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol
    )


def test_dfr_scan_multi_tile_batch():
    """Batch larger than one (S, 128) tile exercises the grid's batch dim."""
    model = SiliconMR()
    rng = np.random.default_rng(1)
    b = 2 * 128 + 17  # forces padding + 2+ tiles at block_s=1
    j = jnp.asarray(rng.uniform(0, 1, (b, 4)), jnp.float32)
    mask = make_mask(5, seed=1)
    s0 = jnp.zeros((b, 5), jnp.float32)
    out = dfr_scan(model, j, mask, s0, block_s=1)
    ref = dfr_scan_ref(model, j, mask, s0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)


@pytest.mark.parametrize("t,f,c", [(100, 37, 1), (600, 128, 2), (257, 150, 1), (64, 129, 3)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gram_matches_oracle(t, f, c, dtype):
    rng = np.random.default_rng(t + f + c)
    x = jnp.asarray(rng.standard_normal((t, f)), dtype)
    y = jnp.asarray(rng.standard_normal((t, c)), dtype)
    g, mom = gram_accumulate(x, y)
    gr, mr = gram_ref(x, y)
    scale_g = max(1e-9, float(jnp.max(jnp.abs(gr))))
    scale_c = max(1e-9, float(jnp.max(jnp.abs(mr))))
    assert float(jnp.max(jnp.abs(g - gr))) / scale_g < 1e-5
    assert float(jnp.max(jnp.abs(mom - mr))) / scale_c < 1e-5


def test_gram_1d_targets():
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.standard_normal((50, 20)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((50,)), jnp.float32)
    g, mom = gram_accumulate(x, y)
    assert g.shape == (20, 20) and mom.shape == (20, 1)


@pytest.mark.parametrize("t", [1, 7, 100, 257, 512, 513])
def test_effective_block_t_sublane_aligned(t):
    """Regression: min(block_t, max(8, t)) used to yield non-multiple-of-8
    tiles (T=100 -> a (100, 128) block), which fails TPU f32 tiling.  The
    effective tile must be a multiple of 8 and at most one tile of padding."""
    eff = effective_block_t(t)
    assert eff % 8 == 0
    assert 8 <= eff <= 512
    assert eff <= max(8, -(-t // 8) * 8)  # never bigger than the aligned stream


@pytest.mark.parametrize("t", [100, 257])
def test_gram_odd_t_matches_oracle(t):
    """Odd (non-multiple-of-8) T through the aligned-tile padding path."""
    rng = np.random.default_rng(t)
    x = jnp.asarray(rng.standard_normal((t, 37)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((t, 1)), jnp.float32)
    g, mom = gram_accumulate(x, y, interpret=True)
    gr, mr = gram_ref(x, y)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gr), rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(mom), np.asarray(mr), rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("b,t,f,c", [(1, 100, 37, 1), (4, 257, 150, 2), (8, 64, 129, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gram_batched_matches_oracle(b, t, f, c, dtype):
    """The batch-gridded kernel: B instances in ONE launch, no lane mixing."""
    rng = np.random.default_rng(b * 1000 + t + f + c)
    x = jnp.asarray(rng.standard_normal((b, t, f)), dtype)
    y = jnp.asarray(rng.standard_normal((b, t, c)), dtype)
    g, mom = gram_accumulate_batched(x, y)
    gr, mr = gram_ref_batched(x, y)
    assert g.shape == (b, f, f) and mom.shape == (b, f, c)
    scale_g = max(1e-9, float(jnp.max(jnp.abs(gr))))
    scale_c = max(1e-9, float(jnp.max(jnp.abs(mr))))
    assert float(jnp.max(jnp.abs(g - gr))) / scale_g < 1e-5
    assert float(jnp.max(jnp.abs(mom - mr))) / scale_c < 1e-5


def test_gram_batched_matches_per_instance_calls():
    """Batched launch == stack of single-instance launches, bit-for-bit."""
    rng = np.random.default_rng(21)
    x = jnp.asarray(rng.standard_normal((3, 90, 33)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((3, 90)), jnp.float32)
    g_b, c_b = gram_accumulate_batched(x, y)
    for i in range(3):
        g_i, c_i = gram_accumulate(x[i], y[i])
        np.testing.assert_array_equal(np.asarray(g_b[i]), np.asarray(g_i))
        np.testing.assert_array_equal(np.asarray(c_b[i]), np.asarray(c_i))


def test_auto_block_s_heuristic():
    """Smallest sublane tile in {1, 2, 4, 8} covering the batch."""
    assert auto_block_s(1) == 1
    assert auto_block_s(8) == 1
    assert auto_block_s(128) == 1
    assert auto_block_s(129) == 2
    assert auto_block_s(256) == 2
    assert auto_block_s(257) == 4
    assert auto_block_s(512) == 4
    assert auto_block_s(513) == 8
    assert auto_block_s(5000) == 8
    # the B = 8 sweep from the issue: 128 lanes, not 1024
    assert padded_lanes(8) == 128
    assert padded_lanes(8, 8) == 1024


@pytest.mark.parametrize("b", [3, 130, 300])
def test_dfr_scan_auto_tile_matches_oracle(b):
    """block_s=None picks the auto tile; results match the oracle exactly."""
    model = SiliconMR()
    rng = np.random.default_rng(b)
    j = jnp.asarray(rng.uniform(0, 1, (b, 5)), jnp.float32)
    mask = make_mask(7, seed=2)
    s0 = jnp.asarray(rng.uniform(0, 0.3, (b, 7)), jnp.float32)
    out = dfr_scan(model, j, mask, s0)
    ref = dfr_scan_ref(model, j, mask, s0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)


def test_dfr_scan_rejects_bad_block_s():
    model = SiliconMR()
    j = jnp.zeros((4, 3), jnp.float32)
    mask = make_mask(5, seed=1)
    with pytest.raises(ValueError, match="block_s"):
        dfr_scan(model, j, mask, jnp.zeros((4, 5), jnp.float32), block_s=3)


# ---------------------------------------------------------------------------
# Sub-f32 out-tile sublane alignment (ROADMAP fix): a multi-tile bf16/int8
# emitted block must sit on that dtype's (16/32, 128) min-tile boundary —
# the f32 path's sub-minimal (block_s, 128) tile is illegal for narrower
# dtypes on real Mosaic, and interpret mode silently computes it anyway, so
# the compiled-shape contract is enforced at trace time (backend-independent)
# ---------------------------------------------------------------------------


def test_min_sublanes_follows_tpu_packing():
    """sublanes × itemsize = 32 bytes: f32 (8,128), bf16 (16,128), int8 (32,128)."""
    assert min_sublanes(jnp.float32) == 8
    assert min_sublanes(jnp.bfloat16) == 16
    assert min_sublanes(jnp.float16) == 16
    assert min_sublanes(jnp.int8) == 32


def test_auto_block_s_is_out_dtype_aware():
    """Single-tile batches keep the small f32 ladder (whole-axis blocks are
    alignment-exempt); multi-tile sub-f32 batches get the dtype's min tile."""
    assert auto_block_s(64, jnp.bfloat16) == 1      # one tile: exempt
    assert auto_block_s(2 * 128 + 17, jnp.bfloat16) == 4  # pads to ONE 4-row tile
    assert auto_block_s(9 * 128, jnp.bfloat16) == 16      # multi-tile: bf16 min
    assert auto_block_s(9 * 128, jnp.int8) == 32          # multi-tile: int8 min
    assert auto_block_s(9 * 128) == 8                     # f32 path unchanged
    assert padded_lanes(9 * 128, out_dtype=jnp.bfloat16) == 16 * 128


def test_dfr_scan_rejects_misaligned_bf16_out_tile():
    """The compiled-shape regression gate: a sub-minimal multi-tile bf16 out
    block raises at trace time even in interpret mode (which would otherwise
    hide the Mosaic tiling violation until a real TPU run)."""
    model = SiliconMR()
    b = 9 * 128 + 17          # 10 sublanes: multi-tile at every f32 ladder tile
    j = jnp.zeros((b, 3), jnp.float32)
    mask = make_mask(5, seed=1)
    s0 = jnp.zeros((b, 5), jnp.float32)
    for bad in (1, 8):
        with pytest.raises(ValueError, match="multiple of 16"):
            dfr_scan(model, j, mask, s0, block_s=bad, out_dtype=jnp.bfloat16)
    # f32 multi-tile sub-minimal blocks remain supported
    dfr_scan(model, j, mask, s0, block_s=1)


def test_dfr_scan_bf16_multi_tile_auto_matches_f32():
    """Auto-tiled bf16 emission over a genuinely multi-tile batch: the fixed
    (16, 128) out tile produces the f32 states rounded to bf16, and the
    final-state carry stays f32 (bit-exact resume contract)."""
    model = SiliconMR()
    rng = np.random.default_rng(7)
    b = 9 * 128 + 17
    j = jnp.asarray(rng.uniform(0, 1, (b, 4)), jnp.float32)
    mask = make_mask(5, seed=1)
    s0 = jnp.zeros((b, 5), jnp.float32)
    ref, fin_ref = dfr_scan(model, j, mask, s0, return_final=True)
    out, fin = dfr_scan(model, j, mask, s0, out_dtype=jnp.bfloat16,
                        return_final=True)
    assert out.dtype == jnp.bfloat16 and fin.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               atol=1 / 64)
    np.testing.assert_array_equal(np.asarray(fin), np.asarray(fin_ref))


# ---------------------------------------------------------------------------
# Chunked emission: final-state output + bit-exact K-chunk resume
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("model", MODELS, ids=_model_id)
@pytest.mark.parametrize("block_s", [1, 8])
def test_dfr_scan_chunked_resume_bit_exact(model, block_s):
    """K split into chunks with the carried final state must BIT-match one
    full-K call, for every NL model and both sublane tiles: the final-state
    output is the kernel's f32 VMEM carry, so resuming from it replays the
    exact arithmetic of the uninterrupted scan (the streaming fit's
    correctness contract)."""
    rng = np.random.default_rng(17)
    b, k, n = 3, 13, 9
    j = jnp.asarray(rng.uniform(0, 1, (b, k)), jnp.float32)
    mask = make_mask(n, seed=3)
    s0 = jnp.asarray(rng.uniform(0, 0.3, (b, n)), jnp.float32)

    full, fin_full = dfr_scan(model, j, mask, s0, block_s=block_s,
                              return_final=True)
    np.testing.assert_array_equal(np.asarray(fin_full),
                                  np.asarray(full[:, -1, :]))

    chunks, s = [], s0
    for lo in (0, 5, 9):  # uneven chunk lengths 5 / 4 / 4
        hi = min(lo + 5 if lo == 0 else lo + 4, k)
        st, s = dfr_scan(model, j[:, lo:hi], mask, s, block_s=block_s,
                         return_final=True)
        chunks.append(st)
    np.testing.assert_array_equal(
        np.asarray(jnp.concatenate(chunks, axis=1)), np.asarray(full))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(fin_full))


# ---------------------------------------------------------------------------
# Per-lane masks (WDM ensembles: one mask per batch lane)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,k,n", [(3, 11, 17), (5, 7, 64)])
def test_dfr_scan_per_lane_mask_matches_oracle(b, k, n):
    """A [B, N] mask stack gives each batch lane its own mask — equal to B
    independent single-mask oracle runs."""
    model = SiliconMR()
    rng = np.random.default_rng(b + k + n)
    j = jnp.asarray(rng.uniform(0, 1, (b, k)), jnp.float32)
    masks = jnp.stack([make_mask(n, seed=20 + i) for i in range(b)])
    s0 = jnp.asarray(rng.uniform(0, 0.3, (b, n)), jnp.float32)
    out = dfr_scan(model, j, masks, s0, block_s=1)
    ref = jnp.stack([dfr_scan_ref(model, j[i:i + 1], masks[i], s0[i:i + 1])[0]
                     for i in range(b)])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)


def test_dfr_scan_per_lane_mask_batch_mismatch():
    model = SiliconMR()
    j = jnp.zeros((4, 3), jnp.float32)
    masks = jnp.zeros((3, 5), jnp.float32)  # 3 masks for 4 lanes
    with pytest.raises(ValueError, match="per-lane mask"):
        dfr_scan(model, j, masks, jnp.zeros((4, 5), jnp.float32))


# ---------------------------------------------------------------------------
# Accumulate-into Gram: chunked folding == one-shot
# ---------------------------------------------------------------------------


def test_gram_accumulate_into_bit_matches_one_shot():
    """Folding T-chunks into a running (G, c) is bit-identical to one pass
    over the concatenated stream when chunks align with the T tile: the
    kernel seeds its VMEM accumulator from the running value, so the f32
    additions happen in the same order."""
    rng = np.random.default_rng(31)
    b, t, f, bt = 2, 96, 20, 16
    x = jnp.asarray(rng.standard_normal((b, t, f)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((b, t, 1)), jnp.float32)
    g_full, c_full = gram_accumulate_batched(x, y, block_t=bt)
    g = jnp.zeros((b, f, f), jnp.float32)
    c = jnp.zeros((b, f, 1), jnp.float32)
    for lo in range(0, t, 32):  # 32 is a multiple of the 16-row tile
        g, c = gram_accumulate_batched_into(g, c, x[:, lo:lo + 32],
                                            y[:, lo:lo + 32], block_t=bt)
    np.testing.assert_array_equal(np.asarray(g), np.asarray(g_full))
    np.testing.assert_array_equal(np.asarray(c), np.asarray(c_full))


@pytest.mark.parametrize("t,f,c", [(100, 37, 2), (64, 129, 1)])
def test_gram_accumulate_into_padding_path(t, f, c):
    """Odd T (tile padding) and F > block_f (init-stack padding) through the
    ops wrapper; result matches the pure-jnp oracle plus the init."""
    rng = np.random.default_rng(t + f)
    b = 3
    x = jnp.asarray(rng.standard_normal((b, t, f)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((b, t, c)), jnp.float32)
    g0 = jnp.asarray(rng.standard_normal((b, f, f)), jnp.float32)
    c0 = jnp.asarray(rng.standard_normal((b, f, c)), jnp.float32)
    g, mom = gram_accumulate_batched_into(g0, c0, x, y)
    gr, mr = gram_ref_batched(x, y)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g0 + gr),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(mom), np.asarray(c0 + mr),
                               rtol=1e-5, atol=1e-4)


def test_gram_accumulate_into_rejects_shape_mismatch():
    x = jnp.zeros((2, 16, 5), jnp.float32)
    y = jnp.zeros((2, 16, 1), jnp.float32)
    with pytest.raises(ValueError, match="init stacks"):
        gram_accumulate_batched_into(jnp.zeros((2, 4, 4)), jnp.zeros((2, 4, 1)),
                                     x, y)
