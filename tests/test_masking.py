"""Masking / MLS properties (paper Section III.A, ref [25])."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.masking import make_mask, masked_input, mls_sequence


@pytest.mark.parametrize("m", [3, 5, 8, 10])
def test_mls_period_and_balance(m):
    seq = mls_sequence(m)
    assert seq.shape[0] == 2**m - 1
    # MLS balance: exactly one more +1 run than -1 (sum == +1 or -1 depending
    # on convention; Fibonacci LFSR emits 2^(m-1) ones).
    assert abs(int(seq.sum())) == 1


@pytest.mark.parametrize("m", [5, 8])
def test_mls_autocorrelation(m):
    """Ideal MLS property: cyclic autocorrelation is -1 off-peak."""
    seq = mls_sequence(m).astype(np.int64)
    n = seq.shape[0]
    for lag in [1, 2, n // 2, n - 1]:
        r = int(np.sum(seq * np.roll(seq, lag)))
        assert r == -1, (lag, r)


@given(n=st.integers(1, 300), seed=st.integers(0, 50))
@settings(max_examples=25, deadline=None)
def test_make_mask_levels_and_determinism(n, seed):
    mask = np.asarray(make_mask(n, seed=seed))
    assert mask.shape == (n,)
    assert set(np.unique(mask)) <= {0.0, 1.0}
    again = np.asarray(make_mask(n, seed=seed))
    np.testing.assert_array_equal(mask, again)


def test_masked_input_shape_and_periodicity():
    """m(t) holds the same per-node value in every tau period (paper III.A.1)."""
    import jax.numpy as jnp

    j = jnp.asarray(np.random.default_rng(0).uniform(size=(7,)), jnp.float32)
    mask = make_mask(13, seed=2)
    u = np.asarray(masked_input(j, mask))
    assert u.shape == (7, 13)
    for k in range(7):
        np.testing.assert_allclose(u[k], float(j[k]) * np.asarray(mask), rtol=1e-6)
