"""End-to-end regression tests for the jit-compiled Experiment pipeline.

These guard the paper-claims path: a single ``Experiment.run`` call (one jit
program) must reproduce NARMA10 NRMSE and channel-equalization SER under
fixed thresholds, vmapped over 8 task instances, with the three reservoir
execution paths (ref / fast / kernel) agreeing.

Thresholds have head-room over the measured values (NARMA10 NRMSE ~0.58–0.63
per seed, chan-eq SER ~0.09–0.12 at 28 dB) but sit far below failure modes:
a broken readout/λ-selection shows up as NRMSE > 0.8 (the f32 Gram-path
regression caught during development) or SER > 0.16, and a broken reservoir
as NRMSE ≈ 1 / SER ≈ 0.75 (chance).
"""

import numpy as np
import pytest

from repro.core import MZISine, MackeyGlass, SiliconMR, tasks
from repro.pipeline import Experiment, ExperimentConfig

LAMS = (1e-10, 1e-8, 1e-6, 1e-4, 1e-2)
N_INSTANCES = 8


def _stack(datasets):
    return (np.stack([d.inputs_train for d in datasets]),
            np.stack([d.targets_train for d in datasets]),
            np.stack([d.inputs_test for d in datasets]),
            np.stack([d.targets_test for d in datasets]))


@pytest.fixture(scope="module")
def narma_batch():
    return _stack([tasks.narma10(1200, seed=s) for s in range(N_INSTANCES)])


@pytest.fixture(scope="module")
def narma_small_batch():
    return _stack([tasks.narma10(360, seed=s) for s in range(N_INSTANCES)])


@pytest.fixture(scope="module")
def santa_fe_batch():
    return _stack([tasks.santa_fe(1800, train_frac=2.0 / 3.0, seed=s)
                   for s in range(6)])


def test_narma10_nrmse_regression(narma_batch):
    """8 NARMA10 seeds in ONE compiled run; every instance beats the mean
    predictor with margin (host float64 reference: 0.57–0.63)."""
    cfg = ExperimentConfig(model=SiliconMR(), n_nodes=200, washout=60, ridge_l2=LAMS)
    res = Experiment(cfg).run(*narma_batch)
    assert res.batch == N_INSTANCES
    assert np.all(res.nrmse < 0.72), res.nrmse
    assert float(res.nrmse.mean()) < 0.65, res.nrmse
    assert np.all(res.nrmse > 0.2), res.nrmse  # too-good = leakage/NaN bug


def test_channel_eq_ser_regression():
    """8 chan-eq seeds at 28 dB in ONE compiled run (host reference SER
    0.09–0.12; 4-PAM chance level is 0.75)."""
    batch = _stack([tasks.channel_equalization(3000, snr_db=28.0, seed=s)
                    for s in range(N_INSTANCES)])
    cfg = ExperimentConfig(model=SiliconMR(), n_nodes=60, washout=60,
                           ridge_l2=LAMS, quantize=True)
    res = Experiment(cfg).run(*batch)
    assert np.all(res.ser < 0.16), res.ser
    assert float(res.ser.mean()) < 0.13, res.ser
    # quantized predictions must be actual 4-PAM symbols
    assert set(np.unique(res.y_pred)) <= {-3.0, -1.0, 1.0, 3.0}


def test_reservoir_methods_agree(narma_small_batch):
    """ref / fast / kernel dispatch agree end-to-end (≤ 1e-3): identical
    states up to f32 round-off, identical predictions through a
    well-conditioned readout."""
    results = {}
    for method in ("ref", "fast", "kernel"):
        cfg = ExperimentConfig(model=SiliconMR(), n_nodes=32, washout=40,
                               ridge_l2=(1e-4,), state_method=method)
        results[method] = Experiment(cfg).run(*narma_small_batch)
    for method in ("fast", "kernel"):
        d_y = np.max(np.abs(results[method].y_pred - results["ref"].y_pred))
        d_err = np.max(np.abs(results[method].nrmse - results["ref"].nrmse))
        assert d_y <= 1e-3, (method, d_y)
        assert d_err <= 1e-3, (method, d_err)


def test_readout_kernel_path_agrees(narma_small_batch):
    """The streaming Gram-kernel readout stays close to the SVD solve."""
    base = ExperimentConfig(model=SiliconMR(), n_nodes=32, washout=40, ridge_l2=(1e-4,))
    res_svd = Experiment(base).run(*narma_small_batch)
    import dataclasses

    res_gram = Experiment(dataclasses.replace(base, readout_use_kernel=True)).run(
        *narma_small_batch)
    assert np.max(np.abs(res_gram.nrmse - res_svd.nrmse)) < 5e-3


def test_santa_fe_nrmse_regression(santa_fe_batch):
    """6 Santa Fe (Haken–Lorenz surrogate) seeds in ONE compiled run.  The
    surrogate is hard (measured 0.58–0.83 per seed at N=40, matching the
    host-path pin in test_paper_claims); thresholds catch a broken readout
    (> 1) without flaking on seed spread."""
    cfg = ExperimentConfig(model=SiliconMR(), n_nodes=40, washout=60, ridge_l2=LAMS)
    res = Experiment(cfg).run(*santa_fe_batch)
    assert np.all(res.nrmse < 0.95), res.nrmse
    assert float(res.nrmse.mean()) < 0.75, res.nrmse
    assert np.all(res.nrmse > 0.2), res.nrmse  # too-good = leakage/NaN bug


def test_santa_fe_methods_agree(santa_fe_batch):
    """ref / fast / kernel dispatch agree on the Santa Fe task end-to-end
    (predictions are O(500) in 8-bit-count units -> compare relative)."""
    results = {}
    for method in ("ref", "fast", "kernel"):
        cfg = ExperimentConfig(model=SiliconMR(), n_nodes=40, washout=60,
                               ridge_l2=(1e-4,), state_method=method)
        results[method] = Experiment(cfg).run(*santa_fe_batch)
    y_scale = np.max(np.abs(results["ref"].y_pred))
    for method in ("fast", "kernel"):
        d_y = np.max(np.abs(results[method].y_pred - results["ref"].y_pred))
        d_err = np.max(np.abs(results[method].nrmse - results["ref"].nrmse))
        assert d_y / y_scale <= 1e-3, (method, d_y)
        assert d_err <= 1e-3, (method, d_err)


def test_multichannel_targets(narma_small_batch):
    """C = 2 target channels: full [B, T, C] predictions and [B, N+1, C]
    weights (channels used to be silently truncated to channel 0), with
    channel 0 equal to the single-channel fit at a fixed λ."""
    tr_in, tr_tg, te_in, te_tg = narma_small_batch

    def two_ch(tg):
        return np.stack([tg, np.roll(tg, 1, axis=-1)], axis=-1)

    cfg = ExperimentConfig(model=SiliconMR(), n_nodes=32, washout=40, ridge_l2=(1e-4,))
    res1 = Experiment(cfg).run(*narma_small_batch)
    res2 = Experiment(cfg).run(tr_in, two_ch(tr_tg), te_in, two_ch(te_tg))
    b, t_test = res1.y_pred.shape
    assert res2.y_pred.shape == (b, t_test, 2)
    assert res2.readout_w.shape == (b, cfg.n_nodes + 1, 2)
    np.testing.assert_allclose(res2.y_pred[..., 0], res1.y_pred, atol=1e-5)
    np.testing.assert_allclose(res2.readout_w[..., 0], res1.readout_w, atol=1e-5)
    assert np.all(np.isfinite(res2.nrmse))


def test_ser_robust_to_dtype_roundtrip():
    """SER compares quantized-vs-quantized symbols: targets that sit eps off
    the nominal 4-PAM levels (f64 task gen -> f32 canon round-trips) must not
    inflate SER to 1.0 via raw float equality."""
    ds = tasks.channel_equalization(1500, snr_db=28.0, seed=0)
    cfg = ExperimentConfig(model=SiliconMR(), n_nodes=60, washout=60,
                           ridge_l2=LAMS, quantize=True)
    res = Experiment(cfg).run_dataset(ds)
    res_pert = Experiment(cfg).run(ds.inputs_train, ds.targets_train,
                                   ds.inputs_test, ds.targets_test + 1e-4)
    np.testing.assert_array_equal(res_pert.ser, res.ser)
    assert np.all(res.ser < 0.75)  # far from the "all symbols wrong" failure


def test_single_instance_and_dataset_api():
    """[T] inputs (B = 1) and the Dataset convenience wrapper."""
    ds = tasks.narma10(600, seed=0)
    cfg = ExperimentConfig(model=SiliconMR(), n_nodes=64, washout=50, ridge_l2=LAMS)
    res = Experiment(cfg).run(ds.inputs_train, ds.targets_train,
                              ds.inputs_test, ds.targets_test)
    res2 = Experiment(cfg).run_dataset(ds)
    assert res.batch == res2.batch == 1
    np.testing.assert_allclose(res.nrmse, res2.nrmse)
    assert res.nrmse[0] < 0.9


def test_matches_host_accelerator():
    """Pipeline ≈ host DFRCAccelerator on the same task (different noise
    RNG + f32 vs f64 solve -> compare loosely)."""
    from repro.core import DFRCAccelerator, DFRCConfig

    ds = tasks.narma10(1200, seed=0)
    host_cfg = DFRCConfig(model=SiliconMR(), n_nodes=200, washout=60, ridge_l2=LAMS)
    host = DFRCAccelerator(host_cfg).fit(ds.inputs_train, ds.targets_train)
    err_host = host.evaluate_nrmse(ds.inputs_test, ds.targets_test)

    res = Experiment(ExperimentConfig.from_dfrc(host_cfg)).run_dataset(ds)
    assert abs(float(res.nrmse[0]) - err_host) < 0.05, (res.nrmse, err_host)


def test_constant_target_nrmse_host_device_agree():
    """Zero-variance targets (ISSUE 4 satellite): the NRMSE variance floor is
    ONE shared constant (core.metrics.VAR_EPS) on the host metric and both
    jit paths — a constant-target channel yields the same finite value
    everywhere, instead of host 1e-300 vs device 1e-30 disagreeing by 135
    orders of magnitude."""
    import dataclasses

    from repro.core import metrics

    # T_test = 512: XLA lowers the /T_test of the running means to a
    # multiply-by-reciprocal, which is only exact for power-of-two T — with
    # T=512 and a const of 1.5 the f32 variance is exactly 0.0 on every
    # path, so the comparison isolates the eps floor itself.
    ds = tasks.narma10(1024, seed=1)
    const = 1.5                       # exactly representable in f32
    tr_tg = np.full_like(ds.targets_train, const)
    te_tg = np.full_like(ds.targets_test, const)
    base = ExperimentConfig(model=SiliconMR(), n_nodes=32, washout=40,
                            ridge_l2=(1e-4,))
    for cfg in (base,
                dataclasses.replace(base, state_noise_rel=0.0,
                                    state_method="kernel",
                                    readout_use_kernel=True,
                                    stream_chunk_k=64)):
        res = Experiment(cfg).run(ds.inputs_train, tr_tg,
                                  ds.inputs_test, te_tg)
        assert np.isfinite(res.nrmse).all(), res.nrmse
        host = metrics.nrmse(te_tg, res.y_pred[0])
        assert np.isfinite(host)
        # same eps, same (f32-rounded) predictions -> same value up to the
        # f32-vs-f64 accumulation of the residual itself
        np.testing.assert_allclose(res.nrmse[0], host, rtol=1e-3)


def test_mzi_and_mg_models_run_batched(narma_small_batch):
    """The baseline device models run through the same compiled pipeline."""
    for model, levels in [(MZISine(), (0.0, 1.0)), (MackeyGlass(), (-1.0, 1.0))]:
        cfg = ExperimentConfig(model=model, n_nodes=48, washout=40,
                               ridge_l2=LAMS, mask_levels=levels)
        res = Experiment(cfg).run(*narma_small_batch)
        assert np.all(np.isfinite(res.nrmse))
        assert np.all(res.nrmse < 1.1), res.nrmse
