"""Trainer behaviour: convergence, microbatch equivalence, fault tolerance."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import DataConfig, host_batch
from repro.models import ModelConfig
from repro.optim import AdamWConfig, apply_updates, init_opt_state, schedule_lr
from repro.runtime.steps import init_train_state, train_step
from repro.runtime.trainer import StragglerWatchdog, TrainLoopConfig, run_training

CFG = ModelConfig(name="tiny", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                  d_ff=128, vocab_size=128, dtype="float32", remat="none")


def _batch(key, b=4, s=32):
    toks = jax.random.randint(key, (b, s), 0, CFG.vocab_size)
    return {"tokens": toks, "labels": toks}


def test_loss_decreases():
    key = jax.random.PRNGKey(0)
    state = init_train_state(CFG, key)
    opt = AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=50)
    fn = jax.jit(lambda s, b: train_step(CFG, opt, s, b))
    batch = _batch(key)  # overfit one batch
    losses = []
    for _ in range(25):
        state, metrics = fn(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.7, (losses[0], losses[-1])


def test_microbatch_equivalence():
    """M=1 and M=4 produce (nearly) the same update for the same global batch."""
    import dataclasses

    key = jax.random.PRNGKey(1)
    batch = _batch(key, b=8)
    opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    outs = {}
    for m in (1, 4):
        cfg = dataclasses.replace(CFG, microbatches=m)
        state = init_train_state(cfg, jax.random.PRNGKey(2))
        new_state, _ = jax.jit(lambda s, b, c=cfg: train_step(c, opt, s, b))(state, batch)
        outs[m] = new_state["params"]
    a = np.concatenate([np.asarray(x).ravel() for x in jax.tree.leaves(outs[1])])
    b = np.concatenate([np.asarray(x).ravel() for x in jax.tree.leaves(outs[4])])
    np.testing.assert_allclose(a, b, atol=1e-5)


def test_adamw_decay_mask_and_schedule():
    params = {"w": jnp.ones((4, 4)), "norm_scale": jnp.ones((4,))}
    opt_state = init_opt_state(params)
    grads = jax.tree.map(jnp.zeros_like, params)
    cfg = AdamWConfig(lr=1.0, weight_decay=0.5, warmup_steps=0, total_steps=100,
                      schedule="constant")
    new_p, _, _ = apply_updates(cfg, params, opt_state, grads, jnp.asarray(5))
    # zero grads: only weight decay moves 'w'; 'norm_scale' must not move
    assert float(jnp.abs(new_p["norm_scale"] - 1.0).max()) == 0.0
    assert float(jnp.abs(new_p["w"] - 1.0).max()) > 0.0
    lr0 = float(schedule_lr(AdamWConfig(warmup_steps=10), jnp.asarray(0)))
    lr9 = float(schedule_lr(AdamWConfig(warmup_steps=10), jnp.asarray(9)))
    assert lr0 < lr9


def test_data_pipeline_determinism_and_sharding():
    cfg2 = DataConfig(vocab_size=64, seq_len=16, global_batch=8, n_hosts=2, host_id=0)
    a = host_batch(cfg2, 7)
    b = host_batch(cfg2, 7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].shape == (4, 16)  # half the global batch
    # labels are next-token shift of the same stream
    other = host_batch(DataConfig(vocab_size=64, seq_len=16, global_batch=8,
                                  n_hosts=2, host_id=1), 7)
    assert not np.array_equal(a["tokens"], other["tokens"])


def test_run_training_restart_and_retry(tmp_path):
    """Driver restores from checkpoint and retries transient step failures."""
    calls = {"n": 0, "fail_at": 3}

    def step_fn(state, batch):
        calls["n"] += 1
        if calls["n"] == calls["fail_at"]:
            raise RuntimeError("simulated preemption")
        return {"step": state["step"] + 1, "w": state["w"] + 1.0}, {"loss": jnp.asarray(1.0)}

    def init_fn():
        return {"step": jnp.asarray(0), "w": jnp.asarray(0.0)}

    data_cfg = DataConfig(vocab_size=8, seq_len=4, global_batch=2)
    loop = TrainLoopConfig(total_steps=6, checkpoint_every=2,
                           checkpoint_dir=str(tmp_path), max_step_retries=2, log_every=0)
    state, history, _ = run_training(step_fn=step_fn, init_state_fn=init_fn,
                                     data_cfg=data_cfg, loop_cfg=loop)
    assert int(state["step"]) == 6
    assert len(history) == 6

    # restart: resumes from the last checkpoint, not from zero
    calls["fail_at"] = -1
    loop2 = TrainLoopConfig(total_steps=8, checkpoint_every=2,
                            checkpoint_dir=str(tmp_path), log_every=0)
    state2, history2, _ = run_training(step_fn=step_fn, init_state_fn=init_fn,
                                       data_cfg=data_cfg, loop_cfg=loop2)
    assert int(state2["step"]) == 8
    assert len(history2) == 2  # only steps 6, 7 re-run


def test_straggler_watchdog():
    w = StragglerWatchdog(factor=3.0)
    for i in range(10):
        w.observe(i, 0.1)
    w.observe(10, 1.0)
    assert w.flagged and w.flagged[-1][0] == 10
