"""Streaming WDM subsystem (DESIGN.md §9).

Guards the tentpole of ISSUE 4: long WDM streams (R wavelength channels,
per-channel masks, one delay loop) run on the PR 3 streaming architecture —
chunked ``channel_states`` with a bit-exact carry on all three methods, a
per-channel streaming Gram fit (``fit_ridge_streaming_wdm``) inside ONE
chunk scan, bf16 state chunks within documented parity bounds, and the
memory property (no [R, K, N] tensor) checkable from the jaxpr.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import stack_datasets as _stack
from repro.analysis import (MaxPallasCalls, MaxScans, NoStateTensor, Program,
                            check_rules, state_tensor_bytes, trace_jaxpr)
from repro.core import SiliconMR, make_mask, tasks
from repro.kernels.dfr_scan import padded_lanes
from repro.pipeline import (ExperimentConfig, WDMExperiment, channel_states,
                            fit_ridge_batched, fit_ridge_streaming_wdm)

LAMS = (1e-8, 1e-6, 1e-4)
# bf16 state chunks round every state entry to 8 mantissa bits; measured
# drift vs f32 chunks on the chan-eq task is ~0.025 NRMSE / ~0.025 SER
# (DESIGN.md §9) — the pinned bounds keep 2x head-room without letting a
# broken bf16 path (NRMSE ~1, SER ~0.75) slip through.
BF16_NRMSE_TOL = 0.06
BF16_SER_TOL = 0.05


@pytest.fixture(scope="module")
def narma_channels():
    """4 wavelength channels = 4 independent NARMA10 draws."""
    return _stack([tasks.narma10(720, seed=s) for s in range(4)])


@pytest.fixture(scope="module")
def chan_eq_channels():
    return _stack([tasks.channel_equalization(1800, snr_db=24.0, seed=s)
                   for s in range(4)])


def _base_cfg(**kw):
    base = dict(model=SiliconMR(), n_nodes=32, washout=40, ridge_l2=LAMS,
                state_noise_rel=0.0, state_method="kernel",
                readout_use_kernel=True)
    base.update(kw)
    return ExperimentConfig(**base)


# ---------------------------------------------------------------------------
# channel_states: return_final / s0 carry parity with generate_states
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method,block_s", [("ref", None), ("fast", None),
                                            ("kernel", 1), ("kernel", 8)],
                         ids=["ref", "fast", "kernel-bs1", "kernel-bs8"])
def test_channel_states_chunk_resume_bit_parity(method, block_s):
    """Chunked channel_states(return_final=True) resumes bit-exactly: the
    carry equals the one-shot run's state row and the re-assembled chunks
    equal the one-shot state tensor, on every method x sublane tile."""
    model = SiliconMR()
    rng = np.random.default_rng(11)
    r, k, n = 3, 50, 12
    j = jnp.asarray(rng.uniform(0, 1, (r, k)), jnp.float32)
    masks = jnp.stack([make_mask(n, seed=60 + i) for i in range(r)])

    full, fin_full = channel_states(model, j, masks, method=method,
                                    block_s=block_s, return_final=True)
    np.testing.assert_array_equal(np.asarray(fin_full),
                                  np.asarray(full[:, -1, :]))

    chunks, s, fin = [], None, None
    for lo in range(0, k, 17):              # 17 ∤ 50: exercises a ragged tail
        st, fin = channel_states(model, j[:, lo:lo + 17], masks, s0=s,
                                 method=method, block_s=block_s,
                                 return_final=True)
        chunks.append(np.asarray(st))
        s = fin
    np.testing.assert_array_equal(np.concatenate(chunks, axis=1),
                                  np.asarray(full))
    np.testing.assert_array_equal(np.asarray(fin), np.asarray(fin_full))


def test_channel_states_bf16_chunks_track_f32():
    """state_dtype='bfloat16' rounds only the emitted tensor: the f32 carry
    stays bit-exact vs the f32 run, and the tensor matches to bf16 eps."""
    model = SiliconMR()
    rng = np.random.default_rng(12)
    r, k, n = 3, 40, 10
    j = jnp.asarray(rng.uniform(0, 1, (r, k)), jnp.float32)
    masks = jnp.stack([make_mask(n, seed=70 + i) for i in range(r)])
    for method in ("fast", "kernel"):
        st32, fin32 = channel_states(model, j, masks, method=method,
                                     return_final=True)
        st16, fin16 = channel_states(model, j, masks, method=method,
                                     return_final=True, state_dtype="bfloat16")
        assert st16.dtype == jnp.bfloat16
        assert fin16.dtype == jnp.float32
        np.testing.assert_array_equal(np.asarray(fin16), np.asarray(fin32))
        np.testing.assert_allclose(np.asarray(st16, dtype=np.float32),
                                   np.asarray(st32), atol=1e-2, rtol=1e-2)


# ---------------------------------------------------------------------------
# fit_ridge_streaming_wdm: streamed per-channel Grams == materialized fit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("use_kernel", [True, False], ids=["gram-kernel", "gram-jnp"])
def test_fit_wdm_streaming_matches_materialized(use_kernel):
    """Chunked WDM fit ≈ materialized per-channel Gram fit (same λ choice,
    same s_end), with the end-of-stream carry exact for K % chunk_k != 0."""
    rng = np.random.default_rng(5)
    model = SiliconMR()
    r, k, n, w0 = 3, 200, 24, 30
    j = jnp.asarray(rng.uniform(0, 1, (r, k)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((r, k)), jnp.float32)
    masks = jnp.stack([make_mask(n, seed=80 + i) for i in range(r)])

    st = channel_states(model, j, masks, method="kernel")
    w_m, idx_m = fit_ridge_batched(st[:, w0:], y[:, w0:], lambdas=LAMS,
                                   use_kernel=True)
    for chunk in (64, 72):  # 200 % 72 != 0 exercises the padded tail
        w_s, idx_s, s_end = fit_ridge_streaming_wdm(
            model, masks, j, y, washout=w0, chunk_k=chunk, lambdas=LAMS,
            state_method="kernel", use_kernel=use_kernel)
        np.testing.assert_array_equal(np.asarray(s_end),
                                      np.asarray(st[:, -1, :]))
        assert np.array_equal(np.asarray(idx_s), np.asarray(idx_m))
        np.testing.assert_allclose(np.asarray(w_s), np.asarray(w_m),
                                   atol=0.1, rtol=0.1)


def test_wdm_streaming_jnp_state_method(narma_channels):
    """The WDM chunk scan also runs with the vmapped jnp reservoir ('fast')
    + jnp Gram — streaming WDM is a pipeline property, not kernel-only."""
    cfg_j = _base_cfg(stream_chunk_k=128, state_method="fast",
                      readout_use_kernel=False)
    cfg_k = _base_cfg(stream_chunk_k=128)
    res_j = WDMExperiment(cfg_j, 4).run(*narma_channels)
    res_k = WDMExperiment(cfg_k, 4).run(*narma_channels)
    assert np.max(np.abs(res_j.nrmse - res_k.nrmse)) <= 2e-3, (
        res_j.nrmse, res_k.nrmse)


def test_fit_wdm_streaming_rejects_mismatched_channels():
    masks = jnp.stack([make_mask(8, seed=1), make_mask(8, seed=2)])
    j = jnp.zeros((3, 60), jnp.float32)
    with pytest.raises(ValueError, match="channels mismatch"):
        fit_ridge_streaming_wdm(SiliconMR(), masks, j, jnp.zeros((3, 60)),
                                washout=10, chunk_k=16, lambdas=(1e-6,))


# ---------------------------------------------------------------------------
# WDMExperiment end-to-end
# ---------------------------------------------------------------------------


def test_wdm_experiment_streaming_parity(narma_channels):
    """Streamed WDMExperiment == materialized channel_states path: NRMSE and
    SER within 1e-3, λ selection identical (noise off, tile-aligned chunk —
    the acceptance bar of ISSUE 4)."""
    res_m = WDMExperiment(_base_cfg(), 4).run(*narma_channels)
    res_s = WDMExperiment(_base_cfg(stream_chunk_k=128), 4).run(*narma_channels)
    assert np.max(np.abs(res_s.nrmse - res_m.nrmse)) <= 1e-3, (
        res_s.nrmse, res_m.nrmse)
    assert np.max(np.abs(res_s.ser - res_m.ser)) <= 1e-3
    np.testing.assert_array_equal(res_s.lam, res_m.lam)
    assert res_s.y_pred.shape == res_m.y_pred.shape
    # a per-channel fit must beat the mean predictor on every wavelength
    assert np.all(res_s.nrmse < 0.9), res_s.nrmse


def test_wdm_experiment_bf16_chunk_parity(chan_eq_channels):
    """bf16 state chunks stay within the documented (looser) parity band of
    the f32 streamed run on the chan-eq task — satellite 4's bound."""
    cfg32 = _base_cfg(stream_chunk_k=128)
    cfg16 = _base_cfg(stream_chunk_k=128, stream_state_dtype="bfloat16")
    res32 = WDMExperiment(cfg32, 4).run(*chan_eq_channels)
    res16 = WDMExperiment(cfg16, 4).run(*chan_eq_channels)
    assert np.max(np.abs(res16.nrmse - res32.nrmse)) <= BF16_NRMSE_TOL, (
        res16.nrmse, res32.nrmse)
    assert np.max(np.abs(res16.ser - res32.ser)) <= BF16_SER_TOL, (
        res16.ser, res32.ser)


def test_wdm_experiment_default_masks_differ():
    """Default per-channel masks are distinct per wavelength (mask_seed + r),
    and an explicit mask stack overrides them."""
    cfg = _base_cfg()
    exp = WDMExperiment(cfg, 3)
    m = np.asarray(exp.masks)
    assert m.shape == (3, cfg.n_nodes)
    assert not np.array_equal(m[0], m[1])
    custom = jnp.stack([make_mask(cfg.n_nodes, seed=7)] * 3)
    assert np.array_equal(np.asarray(WDMExperiment(cfg, 3, masks=custom).masks),
                          np.asarray(custom))
    with pytest.raises(ValueError, match="masks"):
        WDMExperiment(cfg, 4, masks=custom)
    with pytest.raises(ValueError, match="channel rows"):
        exp.run(np.zeros((2, 100)), np.zeros((2, 100)),
                np.zeros((2, 50)), np.zeros((2, 50)))


def test_wdm_experiment_metrics_only(narma_channels):
    """collect_y_pred=False on the WDM path: metrics identical, y_pred None."""
    res = WDMExperiment(_base_cfg(stream_chunk_k=128), 4).run(*narma_channels)
    res_nc = WDMExperiment(_base_cfg(stream_chunk_k=128, collect_y_pred=False),
                           4).run(*narma_channels)
    assert res_nc.y_pred is None
    assert res_nc.batch == 4
    np.testing.assert_array_equal(res_nc.nrmse, res.nrmse)
    np.testing.assert_array_equal(res_nc.ser, res.ser)


# ---------------------------------------------------------------------------
# Jaxpr guards: the WDM memory property itself
# ---------------------------------------------------------------------------


def test_wdm_streaming_fit_jaxpr_no_full_k_tensor():
    """The WDM streamed fit lowers to ONE chunk scan whose body runs ONE
    dfr_scan launch + ONE Gram launch for all R channels (per-lane masks),
    and no [R, K, N]-scale intermediate exists anywhere in the program."""
    model = SiliconMR()
    r, k, n, w0, chunk = 4, 256, 24, 40, 64
    masks = jnp.stack([make_mask(n, seed=30 + i) for i in range(r)])
    j = jnp.zeros((r, k), jnp.float32)
    y = jnp.zeros((r, k), jnp.float32)

    prog = Program(
        lambda jj, yy: fit_ridge_streaming_wdm(model, masks, jj, yy,
                                               washout=w0, chunk_k=chunk,
                                               lambdas=(1e-6,),
                                               state_method="kernel",
                                               use_kernel=True), (j, y))
    fp = -(-(n + 1) // 128) * 128
    chunk_budget = padded_lanes(r) * chunk * fp * 4
    viols = check_rules(prog, [
        MaxScans(1),
        MaxPallasCalls(2),                  # dfr_scan + gram, once each
        NoStateTensor(k, r * k * n, what="full-stream tensor"),
        NoStateTensor(chunk, r * chunk * n, max_bytes=2 * chunk_budget,
                      what="chunk block"),
    ])
    assert not viols, [str(v) for v in viols]
    peak_chunk = state_tensor_bytes(prog.closed_jaxpr, chunk, r * chunk * n)
    assert 0 < peak_chunk <= 2 * chunk_budget, (peak_chunk, chunk_budget)


def test_wdm_bf16_chunks_halve_peak_state_bytes():
    """bf16 chunks halve the peak live state block in the traced program —
    the HBM-traffic claim of DESIGN.md §9, measured not asserted by fiat."""
    model = SiliconMR()
    r, k, n, w0, chunk = 4, 256, 24, 40, 64
    masks = jnp.stack([make_mask(n, seed=30 + i) for i in range(r)])
    j = jnp.zeros((r, k), jnp.float32)
    y = jnp.zeros((r, k), jnp.float32)

    def fit(state_dtype):
        return trace_jaxpr(
            lambda jj, yy: fit_ridge_streaming_wdm(model, masks, jj, yy,
                                                   washout=w0, chunk_k=chunk,
                                                   lambdas=(1e-6,),
                                                   state_method="kernel",
                                                   use_kernel=True,
                                                   state_dtype=state_dtype),
            j, y)

    peak32 = state_tensor_bytes(fit(None), chunk, r * chunk * n)
    peak16 = state_tensor_bytes(fit("bfloat16"), chunk, r * chunk * n)
    assert 0 < peak16 <= -(-peak32 // 2), (peak16, peak32)


def test_wdm_run_pipeline_jaxpr(narma_channels):
    """The whole WDMExperiment streaming program (fit + eval) holds no
    full-K channel-state tensor for either the train or the test stream."""
    tr_in, tr_tg, te_in, te_tg = narma_channels
    cfg = _base_cfg(stream_chunk_k=128)
    from repro.pipeline.experiment import _run_pipeline

    exp = WDMExperiment(cfg, 4)
    prog = Program(
        lambda a, b_, c, d: _run_pipeline(cfg, exp.masks, a, b_, c, d,
                                          wdm=True),
        (jnp.asarray(tr_in, jnp.float32), jnp.asarray(tr_tg, jnp.float32),
         jnp.asarray(te_in, jnp.float32), jnp.asarray(te_tg, jnp.float32)))
    r = tr_in.shape[0]
    viols = check_rules(prog, [
        NoStateTensor(t_len, r * t_len * cfg.n_nodes)
        for t_len in (tr_in.shape[1], te_in.shape[1])])
    assert not viols, [str(v) for v in viols]
