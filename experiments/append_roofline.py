"""Append the §Roofline section (full table + hillclimbed variants) to EXPERIMENTS.md."""
import sys
sys.path.insert(0, "src"); sys.path.insert(0, ".")
from benchmarks.roofline import analyze_cell, markdown_table

lines = ["\n## §Roofline — full baseline table (single-pod 16×16, per assignment)\n"]
lines.append("Terms per device per step; `dominant` judged on the analytic memory")
lines.append("model (the HLO byte count is the CPU-granularity upper bound, shown in")
lines.append("parens).  `MODEL/HLO` = 6·N(active)·D / calibrated HLO FLOPs — the")
lines.append("useful-compute ratio; `roofline frac` = (MODEL_FLOPS/peak) / dominant")
lines.append("term, i.e. the fraction of ideal step time achieved under perfect")
lines.append("overlap.  One-line bottleneck notes follow the table.\n")
lines.append(markdown_table())
lines.append("""
Bottleneck notes (what moves the dominant term down):
- dense train/prefill cells: collective-bound on Megatron-TP activation
  all-reduces -> the zero3 recipe removes them (hillclimb it-3; variants below).
- MoE cells: ZeRO expert-weight gathers + token ARs after the it-4 fixes;
  next lever is caching gathered expert weights across microbatches.
- decode cells: collective/memory-bound on cache reads + small ARs; fractions
  are intrinsically low because MODEL_FLOPS for 1 token is tiny vs the cache
  sweep -- batching (gb=128) is what the serving layer already does.
- jamba/xlstm: recurrent-state updates are elementwise (low MXU use); their
  useful ratios reflect scan overhead counted by HLO, not waste.
- seamless: encoder+cross-attn counted per microbatch; compute-bound at
  prefill.

### Hillclimbed variants (beyond-paper; §Perf log)

| cell | variant | compute s | memory s | collective s | dominant | frac |
|---|---|---|---|---|---|---|""")
for arch, shape, tag in [("granite-8b", "train_4k", "zero3"),
                         ("qwen3-moe-235b-a22b", "train_4k", "m8"),
                         ("reservoir_lm", "train_4k", "zero3")]:
    r = analyze_cell(arch, shape, tag=tag)
    if r is None:
        lines.append(f"| {arch} {shape} | {tag} | (missing) | | | | |")
        continue
    lines.append(
        f"| {arch} {shape} | {tag} | {r['compute_s']:.2e} | {r['memory_s']:.2e} "
        f"| {r['collective_s']:.2e} | {r['dominant']} | **{r['roofline_fraction']:.3f}** |")
lines.append("""
(The variant rows use the same calibrated extraction; the MoE row's tagged
baseline reflects the it-4 framework fixes with M=8 — its collective term
is an f32-counted upper bound, ≈2× lower in bf16 on TPU.)

Multi-pod (2×16×16) dry-run compiles for every cell prove the "pod" axis
shards (gradient all-reduce over pod; batch over pod×data); per the
assignment the roofline table itself is single-pod.
""")
open("EXPERIMENTS.md", "a").write("\n".join(lines))
print("appended", len(lines), "lines")
