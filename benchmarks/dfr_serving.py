"""Online-learning DFR serving benchmark: sustained throughput + tick latency.

Quantifies what ISSUE 6 builds.  The serving loop (``launch/serve_dfr``)
ticks a continuously-batched ``SessionState`` slab through ``session_step``:
one reservoir pass per ``chunk_k`` periods shared by prediction and the RLS
Gram fold, readout re-solved in-graph every ``refresh_every``-th tick.  This
benchmark drives the real ``DFRServer`` (slot packing, resets, donation)
with synthetic streams and reports, per (B, λ) cell:

* ``streams_per_s`` / ``periods_per_s`` — sustained completion throughput
  over the drain of ``requests`` streams through ``B`` slots;
* ``tick_p50_us`` / ``tick_p99_us`` — per-tick step latency quantiles
  (post-warmup; both step variants are compiled before timing).

Plus jaxpr-derived memory gates (backend-exact, like streaming_fusion): the
serve step is ONE compiled program whose largest live state block is the
chunk — a server holding B live sessions must never materialise a
full-stream [B, T, N] tensor, or slot residency would scale with stream
length instead of chunk size.

Emits ``BENCH_dfr_serving.json``; ``--smoke`` is the tier-1 CI gate:

* the traced step holds no state tensor with a full-stream axis,
* step peak state bytes stay within 2× the chunk budget,
* λ only rescales carried statistics: both λ cells must compile to the same
  program count and identical peak-bytes numbers.

  PYTHONPATH=src python -m benchmarks.dfr_serving [--smoke] [--out PATH]
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import (NoStateTensor, Program, check_rules,
                            max_intermediate_bytes, state_tensor_bytes)
from repro.core.masking import make_mask
from repro.launch.serve_dfr import DFRServer, StreamRequest
from repro.pipeline.session import SessionConfig, _session_step, session_init

from .common import csv_row

GRID_B = (64, 512, 4096)
GRID_LAM = (1.0, 0.99)
N_NODES = 64
CHUNK = 32
WASHOUT = 32
STREAM_LEN = 256          # periods per request (8 ticks)
REFRESH_EVERY = 4
LAMS = (1e-8, 1e-6, 1e-4)
# CPU drains the big-B cells at reduced request multiplicity; TPU runs full
CPU_REQ_CAP = 1024


def _cfg(forgetting: float, chunk: int = CHUNK) -> SessionConfig:
    return SessionConfig(n_nodes=N_NODES, washout=WASHOUT, chunk_k=chunk,
                         forgetting=forgetting, refresh_every=REFRESH_EVERY,
                         ridge_l2=LAMS, state_method="fast")


def _step_program(cfg: SessionConfig, b: int, *, refresh: bool) -> Program:
    mask = make_mask(cfg.n_nodes, seed=0)
    state = session_init(cfg, b)
    ck = cfg.chunk_k
    z = jnp.zeros((b, ck), jnp.float32)
    nv = jnp.zeros((b,), jnp.int32)
    rs = jnp.zeros((b,), bool)
    return Program(
        lambda st, jc, yc: _session_step(cfg, mask, st, jc, yc,
                                         refresh=refresh, n_valid=nv,
                                         reset=rs),
        (state, z, z),
        name=f"serve_step_{'fold_solve' if refresh else 'fold'}_B{b}")


def measure_cell(b: int, forgetting: float, *, requests: int,
                 stream_len: int = STREAM_LEN, timed: bool = True) -> dict:
    cfg = _cfg(forgetting)
    n, ck = cfg.n_nodes, cfg.chunk_k

    # jaxpr gates: both step variants, measured against the chunk budget and
    # the would-be full-stream tensor — the shared repro.analysis rules
    fp = -(-(n + 1) // 128) * 128
    budget = b * ck * fp * 4
    gates = {}
    for refresh, tag in ((False, "fold"), (True, "fold_solve")):
        prog = _step_program(cfg, b, refresh=refresh)
        cj = prog.closed_jaxpr
        violations = check_rules(prog, [
            NoStateTensor(stream_len, b * stream_len * n,
                          what="full-stream state tensor"),
            NoStateTensor(ck, b * ck * n, max_bytes=2 * budget,
                          what="chunk state block"),
        ])
        gates[tag] = {
            "peak_state_bytes": state_tensor_bytes(cj, ck, b * ck * n),
            "full_stream_state_bytes": state_tensor_bytes(
                cj, stream_len, b * stream_len * n),
            "peak_any_bytes": max_intermediate_bytes(cj),
            "contract_violations": [str(v) for v in violations],
        }
    entry = {
        "b": b, "forgetting": forgetting, "nodes": n, "chunk": ck,
        "stream_len": stream_len, "requests": requests,
        "refresh_every": cfg.refresh_every,
        "chunk_budget_bytes": budget,
        "step": gates,
        "timed": bool(timed),
    }
    if not timed:
        return entry

    server = DFRServer(cfg, b, mask_seed=0)
    server.warmup()
    rng = np.random.default_rng(b + int(forgetting * 100))
    for r in range(requests):
        server.submit(StreamRequest(
            rid=r,
            j=rng.uniform(0.0, 1.0, stream_len).astype(np.float32),
            y=rng.choice([-3.0, -1.0, 1.0, 3.0], stream_len).astype(np.float32)))
    import time
    t0 = time.perf_counter()
    server.drain()
    wall = time.perf_counter() - t0
    ticks_us = np.asarray(server.tick_seconds) * 1e6
    entry.update({
        "ticks": server.tick,
        "completed": len(server.completed),
        "wall_s": round(wall, 4),
        "streams_per_s": round(len(server.completed) / max(wall, 1e-9), 2),
        "periods_per_s": round(
            len(server.completed) * stream_len / max(wall, 1e-9), 1),
        "tick_p50_us": round(float(np.percentile(ticks_us, 50)), 1),
        "tick_p99_us": round(float(np.percentile(ticks_us, 99)), 1),
    })
    return entry


def check(report: dict) -> list[str]:
    """Regression gates (jaxpr bytes everywhere; λ-invariance of the program)."""
    failures = []
    by_b: dict[int, list[dict]] = {}
    for e in report["cells"]:
        by_b.setdefault(e["b"], []).append(e)
        for tag, g in e["step"].items():
            # memory-shape gates are the shared repro.analysis rules,
            # evaluated at measure time and serialized with the cell
            for v in g["contract_violations"]:
                failures.append(
                    f"serve step ({tag}) contract at B={e['b']} "
                    f"lam={e['forgetting']}: {v}")
    for b, cells in by_b.items():
        peaks = {json.dumps({t: {k: g[k] for k in
                                 ("peak_state_bytes", "full_stream_state_bytes")}
                             for t, g in e["step"].items()}, sort_keys=True)
                 for e in cells}
        if len(peaks) > 1:
            failures.append(
                f"λ changed the compiled step's memory profile at B={b} — "
                f"forgetting must only rescale carried statistics")
    return failures


def build_report(*, smoke: bool) -> dict:
    backend = jax.default_backend()
    if smoke:
        cells = [measure_cell(64, lam, requests=96, stream_len=128)
                 for lam in GRID_LAM]
    else:
        cells = []
        for b in GRID_B:
            for lam in GRID_LAM:
                req = 2 * b
                if backend != "tpu":
                    req = min(req, CPU_REQ_CAP)
                cells.append(measure_cell(b, lam, requests=req))
    return {
        "config": {"backend": backend, "smoke": smoke, "nodes": N_NODES,
                   "chunk": CHUNK, "washout": WASHOUT,
                   "refresh_every": REFRESH_EVERY,
                   "wall_note": "off-TPU walls are functional numbers; the "
                                "jaxpr byte gates are backend-exact"},
        "cells": cells,
    }


def run() -> list[str]:
    """benchmarks.run section: CSV rows + the JSON artifact."""
    report = build_report(smoke=False)
    with open("BENCH_dfr_serving.json", "w") as fh:
        json.dump(report, fh, indent=2)
    failures = check(report)
    if failures:
        raise AssertionError("dfr_serving check FAILED: " + "; ".join(failures))
    rows = []
    for e in report["cells"]:
        name = f"dfr_serving/B{e['b']}_lam{e['forgetting']}"
        if e.get("timed"):
            rows.append(csv_row(f"{name}/streams_per_s",
                                f"{e['streams_per_s']:.1f}",
                                f"periods_per_s={e['periods_per_s']:.0f}"))
            rows.append(csv_row(f"{name}/tick_p99_us",
                                f"{e['tick_p99_us']:.0f}",
                                f"p50={e['tick_p50_us']:.0f}"))
        rows.append(csv_row(
            f"{name}/step_peak_state_bytes",
            str(e["step"]["fold_solve"]["peak_state_bytes"]),
            f"budget={e['chunk_budget_bytes']}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="B=64-only cells / short streams (CI gate on the "
                         "jaxpr memory profile of the serve step)")
    ap.add_argument("--out", default="BENCH_dfr_serving.json")
    args = ap.parse_args()
    report = build_report(smoke=args.smoke)
    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2)
    print(json.dumps(report, indent=2))
    failures = check(report)
    if failures:
        raise SystemExit("dfr_serving check FAILED: " + "; ".join(failures))


if __name__ == "__main__":
    main()
