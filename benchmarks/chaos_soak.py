"""Chaos soak benchmark: isolation, re-convergence, crash recovery gates.

The ISSUE 8 acceptance harness, runnable as a CI smoke gate.  Three cells,
each a *bitwise* or *learnability* claim about the robust serving stack
(``repro.robustness`` + the DESIGN.md §12 in-graph guards + the
crash-recoverable ``DFRServer``):

* **soak** — a slab where a subset of slots is attacked (NaN drive ticks,
  a windowed carry-corruption burst, a stuck-at node) while the rest serve
  clean traffic.  Gates: healthy slots' predictions and final state are
  BITWISE identical to a fault-free run of the same compiled program;
  poisoned slots are quarantined in-graph (poison counts match the fault
  windows) and no non-finite value ever reaches the host; the quarantined
  slot *re-converges* on post-fault data (tail SER < 0.5, i.e. real signal
  on 4-level symbols, and within a band of the clean reference).
* **kill_restore** — a checkpointing server killed mid-stream (faults
  armed) and restored into a fresh process image: every completed stream's
  predictions must be bitwise identical to an uninterrupted reference run.
* **contracts** — the registered program contracts of the fault-injected
  step variants (``repro.analysis``: no host callback, no full-stream
  tensor, one Pallas launch pair, donation honored) re-evaluated and
  serialized with the artifact.

Emits ``BENCH_chaos_soak.json``; ``--smoke`` shrinks shapes but keeps every
gate armed (bitwise claims are size-independent).

  PYTHONPATH=src python -m benchmarks.chaos_soak [--smoke] [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import tempfile

import numpy as np

from repro.analysis import check_rules
from repro.analysis.registry import ENTRY_POINTS
from repro.pipeline.session import SessionConfig
from repro.robustness import no_faults, on_rows, run_soak

from .common import csv_row

RECONVERGE_SER = 0.5          # tail SER a re-converged slot must beat
RECONVERGE_BAND = 0.15        # ... and its gap to the clean reference


def _cfg(n: int, chunk: int) -> SessionConfig:
    return SessionConfig(n_nodes=n, washout=chunk, chunk_k=chunk,
                         refresh_every=2, ridge_l2=(1e-6, 1e-4),
                         state_method="fast")


def soak_cell(*, batch: int, n_ticks: int, n: int, chunk: int,
              seed: int = 0) -> dict:
    """Mixed-fault soak: NaN ticks, a corrupt burst, a stuck node."""
    cfg = _cfg(n, chunk)
    burst = max(2, n_ticks // 6)
    spec = on_rows(no_faults(batch), [1], nan_prob=1.0, until_tick=burst)
    spec = on_rows(spec, [2], corrupt_prob=1.0, until_tick=burst)
    spec = on_rows(spec, [3], stuck_node=min(3, n - 1), stuck_value=0.5)
    rep = run_soak(cfg, spec, n_ticks=n_ticks, seed=seed, data_seed=seed)
    rep.update({"n_nodes": n, "fault_burst_ticks": burst,
                "poisoned_rows": [1, 2], "stuck_rows": [3]})
    return rep


def kill_restore_cell(*, batch: int, n_streams: int, n_ticks_per_stream: int,
                      n: int, chunk: int, kill_after: int,
                      checkpoint_every: int, seed: int = 0) -> dict:
    """Server killed mid-stream and restored; outputs vs an unbroken run."""
    from repro.launch.serve_dfr import DFRServer, StreamRequest

    cfg = _cfg(n, chunk)
    spec = on_rows(no_faults(batch), [0], nan_prob=0.05,
                   until_tick=kill_after)
    length = n_ticks_per_stream * chunk

    def requests():
        rng = np.random.default_rng(seed + 1)
        return [StreamRequest(rid=r, j=rng.random(length).astype(np.float32),
                              y=rng.random(length).astype(np.float32))
                for r in range(n_streams)]

    def outputs(server):
        return {r.rid: np.concatenate(r.y_hat) for r in server.completed}

    ref = DFRServer(cfg, batch, fault_spec=spec, fault_seed=seed)
    ref.warmup()
    for r in requests():
        ref.submit(r)
    ref.drain()
    expect = outputs(ref)

    with tempfile.TemporaryDirectory() as ckpt:
        crash = DFRServer(cfg, batch, fault_spec=spec, fault_seed=seed,
                          checkpoint_dir=ckpt,
                          checkpoint_every=checkpoint_every)
        crash.warmup()
        for r in requests():
            crash.submit(r)
        for _ in range(kill_after):
            crash.step()
        crash.close()

        resumed = DFRServer(cfg, batch, fault_spec=spec, fault_seed=seed,
                            checkpoint_dir=ckpt)
        resumed.warmup()
        restored_tick = resumed.restore()
        resumed.drain()
        got = outputs(resumed)
        stats = resumed.stats()

    bit_exact = (set(got) == set(expect) and all(
        np.array_equal(expect[rid], got[rid]) for rid in expect))
    return {
        "n_streams": n_streams, "batch": batch, "chunk": chunk,
        "stream_len": length, "killed_at_tick": kill_after,
        "checkpoint_every": checkpoint_every,
        "restored_from_tick": restored_tick,
        "resume_bit_exact": bool(bit_exact),
        "completed": len(got),
        "server_stats": stats,
    }


def contract_cell() -> dict:
    """Re-evaluate the registered fault-step contracts for the artifact."""
    out = {}
    for name in ("session_step_faulted", "session_step_faulted_kernel"):
        prog, rules = ENTRY_POINTS[name].build()
        out[name] = {
            "rules": [r.describe() for r in rules],
            "contract_violations": [str(v) for v in check_rules(prog, rules)],
        }
    return out


def check(report: dict) -> list[str]:
    """The ISSUE 8 acceptance gates."""
    failures = []
    s = report["soak"]
    if not s["healthy_bitwise_identical"]:
        failures.append("healthy slots are NOT bitwise identical to the "
                        "fault-free run")
    if not s["output_all_finite"]:
        failures.append("a non-finite prediction reached the host")
    for row in s["poisoned_rows"]:
        if s["quarantine_events"][row] < 1:
            failures.append(f"poisoned slot {row} was never quarantined")
        ser = s["tail_ser_rows"][row]
        if ser >= RECONVERGE_SER:
            failures.append(f"slot {row} did not re-converge after "
                            f"quarantine: tail SER {ser:.3f}")
        if ser > s["tail_ser_clean"] + RECONVERGE_BAND:
            failures.append(f"slot {row} re-converged badly: tail SER "
                            f"{ser:.3f} vs clean {s['tail_ser_clean']:.3f}")
    for row in s["stuck_rows"]:
        if s["quarantine_events"][row] != 0:
            failures.append(f"degradation fault on slot {row} tripped the "
                            "quarantine (drift must not count as poison)")
    kr = report["kill_restore"]
    if not kr["resume_bit_exact"]:
        failures.append("kill-and-restore resume is NOT bit-exact")
    if kr["restored_from_tick"] is None:
        failures.append("no restorable checkpoint was written")
    for name, c in report["contracts"].items():
        for v in c["contract_violations"]:
            failures.append(f"fault-step contract at {name}: {v}")
    return failures


def build_report(*, smoke: bool) -> dict:
    import jax
    if smoke:
        soak = soak_cell(batch=6, n_ticks=24, n=24, chunk=32)
        kr = kill_restore_cell(batch=4, n_streams=6, n_ticks_per_stream=5,
                               n=24, chunk=32, kill_after=5,
                               checkpoint_every=2)
    else:
        soak = soak_cell(batch=16, n_ticks=64, n=64, chunk=32)
        kr = kill_restore_cell(batch=8, n_streams=24, n_ticks_per_stream=8,
                               n=64, chunk=32, kill_after=12,
                               checkpoint_every=4)
    return {
        "config": {"backend": jax.default_backend(), "smoke": smoke,
                   "reconverge_ser_gate": RECONVERGE_SER,
                   "reconverge_band": RECONVERGE_BAND},
        "soak": soak,
        "kill_restore": kr,
        "contracts": contract_cell(),
    }


def run() -> list[str]:
    """benchmarks.run section: CSV rows + the JSON artifact."""
    report = build_report(smoke=False)
    with open("BENCH_chaos_soak.json", "w") as fh:
        json.dump(report, fh, indent=2)
    failures = check(report)
    if failures:
        raise AssertionError("chaos_soak check FAILED: " + "; ".join(failures))
    s, kr = report["soak"], report["kill_restore"]
    return [
        csv_row("chaos_soak/healthy_bitwise_identical",
                int(s["healthy_bitwise_identical"]),
                f"batch={s['batch']};faulty={s['faulty_rows']}"),
        csv_row("chaos_soak/quarantine_events",
                sum(s["quarantine_events"]),
                f"burst={s['fault_burst_ticks']}ticks"),
        csv_row("chaos_soak/tail_ser_reconverged",
                f"{max(s['tail_ser_rows'][r] for r in s['poisoned_rows']):.4f}",
                f"clean={s['tail_ser_clean']:.4f}"),
        csv_row("chaos_soak/resume_bit_exact", int(kr["resume_bit_exact"]),
                f"restored_from={kr['restored_from_tick']};"
                f"killed_at={kr['killed_at_tick']}"),
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes, every gate armed (CI tier-1 step)")
    ap.add_argument("--out", default="BENCH_chaos_soak.json")
    args = ap.parse_args()
    report = build_report(smoke=args.smoke)
    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2)
    print(json.dumps(report, indent=2))
    failures = check(report)
    if failures:
        raise SystemExit("chaos_soak check FAILED: " + "; ".join(failures))


if __name__ == "__main__":
    main()
