"""Paper Table 1 / Eq. (15): power consumption of the photonic accelerators.

The paper quotes 126.48 mW (Silicon MR) vs 549.54 mW (All Optical MZI);
evaluating Eq. (15) literally reproduces the MR total closely; the MZI total
depends on whether the wall-plug division applies to its laser (core/power.py
docstring) — both readings are reported.
"""

from __future__ import annotations

from repro.core import power

from .common import csv_row


def run() -> list[str]:
    rows = []
    for spec in (power.SILICON_MR, power.ALL_OPTICAL_MZI):
        for wp in (True, False):
            total = spec.total_mw(apply_wall_plug=wp)
            tag = "wallplug" if wp else "optical-only"
            rows.append(csv_row(f"table1/{spec.name}/total_mw/{tag}", f"{total:.2f}",
                                f"paper={power.PAPER_TOTALS_MW[spec.name]}"))
        br = spec.breakdown_mw()
        for k, v in br.items():
            if k != "total":
                rows.append(csv_row(f"table1/{spec.name}/{k}_mw", f"{v:.3f}", ""))
    mr = power.SILICON_MR.total_mw()
    mzi = power.ALL_OPTICAL_MZI.total_mw()
    rows.append(csv_row("table1/mr_vs_mzi_power_ratio", f"{mzi / mr:.2f}",
                        "paper=4.34x (549.54/126.48)"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
