"""Streaming WDM ensemble benchmark: peak memory + wall, f32 vs bf16 chunks.

Quantifies what ISSUE 4 adds on top of the PR 3 streaming work.  The paper's
scalability pitch (Section VI) is wavelength-division multiplexing — R
microring channels sharing one delay loop — but the materialized WDM path
(`channel_states` + `fit_ridge_batched`) stages the full [R, K, N] channel-
state tensor in HBM: a long stream at R = 64 / K = 10k / N = 100 is ~256 MB
of f32 states consumed exactly once, and it grows linearly in K.  The
streaming WDM fit (`pipeline/ridge.fit_ridge_streaming_wdm`) scans K-chunks
with the per-lane-mask reservoir kernel (all R channels = ONE launch) and
folds per-channel Gram stacks, so the largest live state block is the
(lane-padded) chunk — independent of K.  `stream_state_dtype="bfloat16"`
additionally halves the chunk's HBM round-trip (DESIGN.md §9).

Memory numbers are derived from the traced jaxpr (`repro.analysis`), so
they are exact on any backend; wall times are measured only where the
backend can afford them (every cell on TPU, the small cells in interpret
mode — byte columns are what CI gates on).

Emits ``BENCH_wdm_streaming.json``; the ``--smoke`` run is the tier-1 CI
regression gate:

* streamed fits must hold NO full-K state tensor (f32 and bf16 chunks),
* streamed ``peak_state_bytes`` must not exceed 2× the lane/feature-padded
  chunk budget — including the R = 64 / K = 10k headline cell,
* bf16 chunks must actually halve the peak live state block (ratio ≤ 0.6),
* streamed-vs-materialized NRMSE parity ≤ 1e-3 with f32 chunks; SER parity
  ≤ max(1e-3, 1.5/t_test) — SER is quantized to whole test symbols, so the
  gate must admit one borderline symbol flipping on an in-tolerance NRMSE
  drift — and within the documented looser band (≤ 0.06 NRMSE / 0.05 SER)
  with bf16 chunks.

  PYTHONPATH=src python -m benchmarks.wdm_streaming [--smoke] [--out PATH]
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import (MaxPallasCalls, MaxScans, NoStateTensor, Program,
                            check_rules, max_intermediate_bytes,
                            state_tensor_bytes)
from repro.core import SiliconMR, make_mask
from repro.kernels.dfr_scan import padded_lanes
from repro.pipeline import channel_states, fit_ridge_batched, fit_ridge_streaming_wdm

from .common import csv_row, stack_datasets, time_fn

GRID_R = (4, 16, 64)
GRID_K = (1000, 10000)
N_NODES = 100
WASHOUT = 60
LAMS = (1e-6, 1e-4)
PARITY_TOL = 1e-3
# bf16 chunks round states to 8 mantissa bits; measured drift on the chan-eq
# parity cell is ~0.025 NRMSE/SER (DESIGN.md §9) — gate with 2x head-room.
BF16_NRMSE_TOL = 0.06
BF16_SER_TOL = 0.05
# Off-TPU the kernels run interpret-mode-slow; only time cells up to this
# many state elements so the full grid still finishes.  TPU times all.
CPU_TIME_BUDGET = 4 * 1000 * 100


def _chunk_for(k: int) -> int:
    """Tile-aligned chunk (multiple of the 8-row T tiles)."""
    return min(256, max(8, (k // 8) & ~7))


def _masks(r: int, n: int) -> jnp.ndarray:
    return jnp.stack([make_mask(n, seed=10 + i) for i in range(r)])


def _fit_fns(r: int, n: int, chunk: int, state_dtype: str | None):
    model = SiliconMR()
    masks = _masks(r, n)

    def materialized(j, y):
        st = channel_states(model, j, masks, method="kernel")
        return fit_ridge_batched(st[:, WASHOUT:], y[:, WASHOUT:],
                                 lambdas=LAMS, use_kernel=True)

    def streamed(j, y):
        w, idx, _ = fit_ridge_streaming_wdm(
            model, masks, j, y, washout=WASHOUT, chunk_k=chunk, lambdas=LAMS,
            state_method="kernel", use_kernel=True, state_dtype=state_dtype)
        return w, idx

    return jax.jit(materialized), jax.jit(streamed)


def measure_cell(r: int, k: int, *, n: int = N_NODES,
                 state_dtype: str | None = None, chunk: int | None = None,
                 timed: bool | None = None, iters: int = 2) -> dict:
    chunk = chunk or _chunk_for(k)
    mat, stream = _fit_fns(r, n, chunk, state_dtype)
    j = jnp.zeros((r, k), jnp.float32)
    y = jnp.zeros((r, k), jnp.float32)

    tag = state_dtype or "float32"
    prog_m = Program(mat, (j, y), name=f"wdm_materialized_R{r}_K{k}_{tag}")
    prog_s = Program(stream, (j, y), name=f"wdm_streamed_R{r}_K{k}_{tag}")
    cj_m, cj_s = prog_m.closed_jaxpr, prog_s.closed_jaxpr
    # chunk budget = lane-padded channels x chunk x feature-tile-padded F at
    # the chunk dtype — the largest state block the streamed path may keep
    itemsize = jnp.dtype(state_dtype or jnp.float32).itemsize
    fp = -(-(n + 1) // 128) * 128
    budget = padded_lanes(r) * chunk * fp * itemsize
    # the shared contract set (same rules the tier-1 tests run): one chunk
    # scan, ONE launch pair, no full-K tensor, chunk blocks within 2x budget
    violations = check_rules(prog_s, [
        MaxScans(1), MaxPallasCalls(2),
        NoStateTensor(k, r * k * n, what="full-K state tensor"),
        NoStateTensor(chunk, r * chunk * n, max_bytes=2 * budget,
                      what="chunk state block"),
    ])
    entry = {
        "r": r, "k": k, "n": n, "chunk": chunk,
        "state_dtype": tag,
        "materialized": {
            "peak_state_bytes": state_tensor_bytes(cj_m, k, r * k * n),
            "peak_any_bytes": max_intermediate_bytes(cj_m),
        },
        "streamed": {
            "peak_state_bytes": state_tensor_bytes(cj_s, chunk, r * chunk * n),
            "peak_any_bytes": max_intermediate_bytes(cj_s),
            "full_k_state_bytes": state_tensor_bytes(cj_s, k, r * k * n),
            "chunk_budget_bytes": budget,
            "contract_violations": [str(v) for v in violations],
        },
    }
    entry["state_bytes_ratio"] = round(
        entry["materialized"]["peak_state_bytes"]
        / max(1, entry["streamed"]["peak_state_bytes"]), 2)

    if timed is None:
        timed = (jax.default_backend() == "tpu" or r * k * n <= CPU_TIME_BUDGET)
    entry["timed"] = bool(timed)
    if timed:
        rng = np.random.default_rng(r + k + n)
        j = jnp.asarray(rng.uniform(0, 1, (r, k)), jnp.float32)
        y = jnp.asarray(rng.standard_normal((r, k)), jnp.float32)
        entry["materialized"]["wall_us"] = round(time_fn(mat, j, y, iters=iters), 1)
        entry["streamed"]["wall_us"] = round(time_fn(stream, j, y, iters=iters), 1)
    return entry


def parity_cell(*, r: int, n: int, n_symbols: int, chunk: int,
                lams: tuple[float, ...] = LAMS) -> dict:
    """Streamed vs materialized WDMExperiment on the chan-eq task, f32 and
    bf16 chunks (noise off)."""
    import dataclasses

    from repro.core import tasks
    from repro.pipeline import ExperimentConfig, WDMExperiment

    args = stack_datasets([tasks.channel_equalization(n_symbols, snr_db=24.0,
                                                      seed=s)
                           for s in range(r)])
    base = ExperimentConfig(model=SiliconMR(), n_nodes=n, washout=WASHOUT,
                            ridge_l2=lams, state_noise_rel=0.0,
                            state_method="kernel", readout_use_kernel=True)
    res_m = WDMExperiment(base, r).run(*args)
    res_s = WDMExperiment(dataclasses.replace(base, stream_chunk_k=chunk),
                          r).run(*args)
    res_b = WDMExperiment(dataclasses.replace(base, stream_chunk_k=chunk,
                                              stream_state_dtype="bfloat16"),
                          r).run(*args)
    t_test = int(args[3].shape[-1])
    return {
        "r": r, "n": n, "n_symbols": n_symbols, "chunk": chunk,
        # SER is quantized to 1/t_test: a single flipped borderline symbol
        # moves it by one quantum even when the continuous NRMSE agrees to
        # <1e-3, so check() gates SER at max(PARITY_TOL, 1.5 quanta)
        "t_test": t_test,
        "ser_quantum": 1.0 / t_test,
        "nrmse_materialized": [round(float(v), 6) for v in res_m.nrmse],
        "nrmse_streamed": [round(float(v), 6) for v in res_s.nrmse],
        "nrmse_streamed_bf16": [round(float(v), 6) for v in res_b.nrmse],
        "max_abs_nrmse_diff": float(np.max(np.abs(res_s.nrmse - res_m.nrmse))),
        "max_abs_ser_diff": float(np.max(np.abs(res_s.ser - res_m.ser))),
        "bf16_max_abs_nrmse_diff": float(np.max(np.abs(res_b.nrmse - res_s.nrmse))),
        "bf16_max_abs_ser_diff": float(np.max(np.abs(res_b.ser - res_s.ser))),
    }


def check(report: dict) -> list[str]:
    """Regression gates (bytes + parity everywhere; wall time on TPU)."""
    failures = []
    by_key = {}
    for e in report["cells"]:
        s = e["streamed"]
        by_key[(e["r"], e["k"], e["state_dtype"])] = s
        where = f"R={e['r']} K={e['k']} dtype={e['state_dtype']}"
        # memory-shape gates are the shared repro.analysis rules, evaluated
        # at measure time and serialized with the cell
        for v in s["contract_violations"]:
            failures.append(f"streamed WDM contract at {where}: {v}")
        if (report["config"]["backend"] == "tpu" and e["r"] >= 16
                and e.get("timed")
                and s["wall_us"] > e["materialized"]["wall_us"]):
            failures.append(
                f"streamed slower than materialized at {where}: "
                f"{s['wall_us']} vs {e['materialized']['wall_us']} us")
    for (r, k, dtype), s in by_key.items():
        if dtype != "bfloat16":
            continue
        s32 = by_key.get((r, k, "float32"))
        if s32 and s["peak_state_bytes"] > 0.6 * s32["peak_state_bytes"]:
            failures.append(
                f"bf16 chunks do not halve peak state bytes at R={r} K={k}: "
                f"{s['peak_state_bytes']} vs f32 {s32['peak_state_bytes']}")
    for p in report["parity"]:
        # SER moves in quanta of 1/t_test — one borderline symbol decided
        # differently after a <=1e-3 NRMSE drift is one whole quantum (the
        # pre-PR-8 smoke cell failed exactly this way: 1/200 = 5.0e-3 SER
        # diff at 5.6e-4 NRMSE diff).  Gate SER at >= one quantum with
        # headroom; NRMSE keeps the tight continuous tolerance.
        ser_tol = max(PARITY_TOL, 1.5 * p.get("ser_quantum", 0.0))
        if p["max_abs_nrmse_diff"] > PARITY_TOL or p["max_abs_ser_diff"] > ser_tol:
            failures.append(
                f"streamed-vs-materialized WDM parity {p['max_abs_nrmse_diff']:.2e}"
                f"/{p['max_abs_ser_diff']:.2e} exceeds {PARITY_TOL}/{ser_tol:.1e} "
                f"at R={p['r']} N={p['n']}")
        if (p["bf16_max_abs_nrmse_diff"] > BF16_NRMSE_TOL
                or p["bf16_max_abs_ser_diff"] > BF16_SER_TOL):
            failures.append(
                f"bf16-chunk parity {p['bf16_max_abs_nrmse_diff']:.2e}"
                f"/{p['bf16_max_abs_ser_diff']:.2e} exceeds documented bounds "
                f"{BF16_NRMSE_TOL}/{BF16_SER_TOL} at R={p['r']} N={p['n']}")
    return failures


def build_report(*, smoke: bool) -> dict:
    if smoke:
        # small timed cells + the headline R=64/K=10k cell trace-only (the
        # acceptance gate of ISSUE 4 must hold at the full operating point;
        # tracing costs no kernel execution, so N shrinks but R/K do not)
        cells = []
        for dtype in (None, "bfloat16"):
            cells.append(measure_cell(4, 96, n=16, state_dtype=dtype,
                                      chunk=32, iters=1))
            cells.append(measure_cell(64, 10000, n=16, state_dtype=dtype,
                                      timed=False))
        parity = [parity_cell(r=4, n=24, n_symbols=600, chunk=64,
                              lams=(1e-4,))]
    else:
        cells = [measure_cell(r, k, state_dtype=dtype)
                 for r in GRID_R for k in GRID_K
                 for dtype in (None, "bfloat16")]
        parity = [parity_cell(r=4, n=N_NODES, n_symbols=1800, chunk=128)]
    return {
        "config": {"backend": jax.default_backend(), "smoke": smoke,
                   "n_nodes": N_NODES, "washout": WASHOUT,
                   "wall_note": "off-TPU walls are interpret-mode functional "
                                "numbers; byte columns are backend-exact"},
        "cells": cells,
        "parity": parity,
    }


def run() -> list[str]:
    """benchmarks.run section: CSV rows + the JSON artifact."""
    report = build_report(smoke=False)
    with open("BENCH_wdm_streaming.json", "w") as fh:
        json.dump(report, fh, indent=2)
    failures = check(report)
    if failures:  # same regression gate as --smoke; run.py reports + exits 1
        raise AssertionError("wdm_streaming check FAILED: " + "; ".join(failures))
    rows = []
    for e in report["cells"]:
        name = (f"wdm_streaming/R{e['r']}_K{e['k']}_{e['state_dtype']}")
        rows.append(csv_row(f"{name}/state_bytes_ratio",
                            f"{e['state_bytes_ratio']:.1f}",
                            f"mat={e['materialized']['peak_state_bytes']};"
                            f"stream={e['streamed']['peak_state_bytes']}"))
        if e.get("timed"):
            rows.append(csv_row(
                f"{name}/wall_us",
                f"{e['streamed']['wall_us']:.0f}",
                f"materialized={e['materialized']['wall_us']:.0f}"))
    for p in report["parity"]:
        rows.append(csv_row("wdm_streaming/parity_max_nrmse_diff",
                            f"{p['max_abs_nrmse_diff']:.2e}",
                            f"tol={PARITY_TOL}"))
        rows.append(csv_row("wdm_streaming/bf16_parity_max_nrmse_diff",
                            f"{p['bf16_max_abs_nrmse_diff']:.2e}",
                            f"tol={BF16_NRMSE_TOL}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes / trace-only headline cell (CI gate on "
                         "peak state bytes + WDM parity, f32 and bf16 chunks)")
    ap.add_argument("--out", default="BENCH_wdm_streaming.json")
    args = ap.parse_args()
    report = build_report(smoke=args.smoke)
    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2)
    print(json.dumps(report, indent=2))
    failures = check(report)
    if failures:
        raise SystemExit("wdm_streaming check FAILED: " + "; ".join(failures))


if __name__ == "__main__":
    main()
