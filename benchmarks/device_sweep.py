"""Device design-space-exploration benchmark: CMT robustness maps + the
calibration parity gate (ISSUE 10 / DESIGN.md §14).

The repro's device axis (`repro.devices`) claims three things this bench
measures instead of asserting:

1. **Calibration parity** — the calibrated CMT cavity's zero-power limit is
   the paper's `SiliconMR`: per-tick worst-case deviation over the [0, 1]³
   operating box, per-branch small-signal gain deltas, and NARMA10 NRMSE on
   the same seeds within ``PARITY_NRMSE`` (the ISSUE 10 acceptance bound).
2. **One-program sweeps** — the full (detuning × loss × power) robustness
   map runs as ONE jit-compiled vmapped Experiment: grid points are batch
   lanes, swept parameters are operands.  Gated two ways: the registry's
   ``device_sweep*`` / ``experiment_cmt_kernel`` contract sets must hold
   (jaxpr: no full-stream state tensor, scan/launch budgets, no silent f32
   chunk), and a second sweep with NEW grid values must leave the pipeline's
   compile cache untouched (``devices.sweep.pipeline_cache_size``).
3. **Robustness physics** — NARMA10 NRMSE and channel-equalization SER
   heatmaps over the box, with the stable operating region flagged in the
   JSON (arXiv:2310.09433's loss/detuning/power sensitivity, measured on
   this implementation), plus the arXiv:2101.01664 MR operating point
   (thermally-dominant, red-detuned) validated as a preset cell.

Emits ``BENCH_device_sweep.json``; ``--smoke`` is the tier-1 CI gate.

  PYTHONPATH=src python -m benchmarks.device_sweep [--smoke] [--out PATH]
"""

from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.analysis import check_rules
from repro.analysis.registry import get_entry_points
from repro.core import SiliconMR, tasks
from repro.devices import (MRCavityCMT, SweepGrid, calibrated_twin,
                           calibration_report, node_parity,
                           pipeline_cache_size, run_device_sweep)
from repro.pipeline import Experiment, ExperimentConfig

from .common import csv_row

PARITY_NRMSE = 2e-2          # ISSUE 10 acceptance: CMT low-power vs SiliconMR
PARITY_TICK = 1e-4           # per-tick worst-case over the operating box
NARMA_STABLE = 0.8           # NARMA10 NRMSE bound defining "stable" cells
SER_STABLE = 0.15            # chan-eq SER bound defining "stable" cells
PRESET_NRMSE = 0.95          # preset gate: usable (finite, beats the mean
                             # predictor's NRMSE = 1), not best-accuracy —
                             # a red-detuned thermal point trades accuracy
                             # for thermal headroom by construction
N_NODES = 64
WASHOUT = 50
CHUNK = 128
LAMS = (1e-8, 1e-6, 1e-4)
NARMA_SAMPLES = 1200
CHEQ_SYMBOLS = 1500

# The arXiv:2101.01664 silicon-MR operating point, qualitatively: CW pump
# red-detuned off resonance, thermally-dominant nonlinearity (their ~ms-scale
# thermal response dwarfs the free-carrier term at the powers used), linear
# loss at the fabricated Q.  A *validation preset*, not a fit: the gate is
# that this independently-published point sits in the usable region.
MR_2101_01664 = dict(detune=0.4, loss_scale=1.2, power=1.0)


def grids(smoke: bool) -> SweepGrid:
    if smoke:
        return SweepGrid(detune=(-1.0, 0.0, 1.0), loss_scale=(1.0, 1.4),
                         power=(0.0, 1.0))
    return SweepGrid(detune=(-1.5, -0.75, 0.0, 0.75, 1.5),
                     loss_scale=(1.0, 1.25, 1.5),
                     power=(0.0, 0.5, 1.0, 2.0))


def _round_map(a: np.ndarray) -> list:
    return np.round(a.astype(float), 4).tolist()


def parity_cells(twin: MRCavityCMT, mr: SiliconMR) -> dict:
    """Calibration parity: per-tick, small-signal, and NARMA10-level."""
    ds = tasks.narma10(NARMA_SAMPLES, seed=0)
    cfg_kw = dict(n_nodes=N_NODES, washout=WASHOUT, ridge_l2=LAMS,
                  state_method="fast", stream_chunk_k=CHUNK,
                  state_noise_rel=0.0)
    r_mr = Experiment(ExperimentConfig(model=mr, **cfg_kw)).run_dataset(ds)
    r_tw = Experiment(ExperimentConfig(model=twin, **cfg_kw)).run_dataset(ds)
    return {
        "tick_parity_max_abs": node_parity(mr, twin),
        "small_signal": calibration_report(mr, twin),
        "narma10_nrmse_silicon_mr": round(float(r_mr.nrmse[0]), 5),
        "narma10_nrmse_cmt_twin": round(float(r_tw.nrmse[0]), 5),
        "narma10_nrmse_delta": round(
            abs(float(r_mr.nrmse[0]) - float(r_tw.nrmse[0])), 5),
        "required_delta": PARITY_NRMSE,
    }


def sweep_cell(model: MRCavityCMT, grid: SweepGrid, dataset, *,
               metric: str, stable_max: float) -> dict:
    res = run_device_sweep(model, grid, dataset, n_nodes=N_NODES,
                           washout=WASHOUT, stream_chunk_k=CHUNK,
                           ridge_l2=LAMS)
    vals = getattr(res, metric)
    region = res.stable_region(nrmse_max=stable_max) if metric == "nrmse" \
        else _ser_region(res, stable_max)
    return {
        "metric": metric,
        "grid": {"detune": list(grid.detune),
                 "loss_scale": list(grid.loss_scale),
                 "power": list(grid.power)},
        "heatmap": _round_map(vals),
        "n_lanes": grid.size,
        "stable": region["summary"],
        "stable_map": region["map"].astype(int).tolist(),
        "_result": res,
    }


def _ser_region(res, ser_max: float) -> dict:
    ok = np.isfinite(res.ser) & (res.ser <= ser_max)
    summary = {"ser_max": ser_max, "n_stable": int(ok.sum()),
               "n_total": int(ok.size),
               "stable_fraction": round(float(ok.mean()), 4)}
    if ok.any():
        masked = np.where(ok, res.ser, np.inf)
        best = np.unravel_index(int(np.argmin(masked)), ok.shape)
        summary["best_point"] = {**res.grid.point(best),
                                 "ser": round(float(res.ser[best]), 4),
                                 "nrmse": round(float(res.nrmse[best]), 4)}
    return {"map": ok, "summary": summary}


def preset_cell(twin: MRCavityCMT, dataset) -> dict:
    """The arXiv:2101.01664 operating point as a 1-point 'grid'."""
    grid = SweepGrid(detune=(MR_2101_01664["detune"],),
                     loss_scale=(MR_2101_01664["loss_scale"],),
                     power=(MR_2101_01664["power"],))
    res = run_device_sweep(twin, grid, dataset, n_nodes=N_NODES,
                           washout=WASHOUT, stream_chunk_k=CHUNK,
                           ridge_l2=LAMS)
    return {"point": MR_2101_01664,
            "narma10_nrmse": round(float(res.nrmse.ravel()[0]), 4),
            "usable_bound": PRESET_NRMSE}


def contract_cells() -> list[dict]:
    """The registry's CMT contract sets, traced and checked here so the
    artifact records the jaxpr gate alongside the numbers it protects."""
    out = []
    for ep in get_entry_points(["device_sweep", "device_sweep_bf16",
                                "experiment_cmt_kernel"]):
        prog, rules = ep.build()
        viols = check_rules(prog, rules)
        out.append({"entry_point": ep.name, "n_rules": len(rules),
                    "violations": [str(v) for v in viols]})
    return out


def check(report: dict) -> list[str]:
    failures = []
    p = report["parity"]
    if p["narma10_nrmse_delta"] > PARITY_NRMSE:
        failures.append(
            f"calibrated CMT low-power NARMA10 NRMSE differs from SiliconMR "
            f"by {p['narma10_nrmse_delta']} > {PARITY_NRMSE}")
    if p["tick_parity_max_abs"] > PARITY_TICK:
        failures.append(
            f"per-tick parity {p['tick_parity_max_abs']} > {PARITY_TICK}")
    for c in report["contracts"]:
        for v in c["violations"]:
            failures.append(f"contract at {c['entry_point']}: {v}")
    rt = report["no_retrace"]
    if not rt["ok"]:
        failures.append(
            f"sweep with new grid values retraced: pipeline cache "
            f"{rt['cache_before']} -> {rt['cache_after']}")
    narma = report["sweeps"]["narma10"]
    if narma["stable"]["n_stable"] == 0:
        failures.append("no stable operating region on the NARMA10 map "
                        f"(NRMSE <= {NARMA_STABLE})")
    pre = report["preset_2101_01664"]
    if not np.isfinite(pre["narma10_nrmse"]) or \
            pre["narma10_nrmse"] > PRESET_NRMSE:
        failures.append(
            f"arXiv:2101.01664 preset point unusable: NARMA10 NRMSE "
            f"{pre['narma10_nrmse']} > {PRESET_NRMSE}")
    return failures


def build_report(*, smoke: bool) -> dict:
    mr = SiliconMR()
    twin = calibrated_twin(mr)
    grid = grids(smoke)
    narma = tasks.narma10(NARMA_SAMPLES, seed=0)

    report = {
        "config": {"backend": jax.default_backend(), "smoke": smoke,
                   "n_nodes": N_NODES, "chunk": CHUNK,
                   "narma_stable": NARMA_STABLE, "ser_stable": SER_STABLE,
                   "model": repr(twin)},
        "parity": parity_cells(twin, mr),
        "contracts": contract_cells(),
    }

    sweeps = {"narma10": sweep_cell(twin, grid, narma, metric="nrmse",
                                    stable_max=NARMA_STABLE)}
    cache_before = pipeline_cache_size()
    # the no-retrace proof: same shapes, entirely new grid VALUES
    shifted = SweepGrid(detune=tuple(d + 0.05 for d in grid.detune),
                        loss_scale=tuple(l + 0.05 for l in grid.loss_scale),
                        power=tuple(pw + 0.05 for pw in grid.power))
    run_device_sweep(twin, shifted, narma, n_nodes=N_NODES, washout=WASHOUT,
                     stream_chunk_k=CHUNK, ridge_l2=LAMS)
    cache_after = pipeline_cache_size()
    report["no_retrace"] = {"cache_before": cache_before,
                            "cache_after": cache_after,
                            "ok": cache_before == cache_after}

    if not smoke:
        cheq = tasks.channel_equalization(CHEQ_SYMBOLS, seed=0)
        sweeps["chan_eq"] = sweep_cell(twin, grid, cheq, metric="ser",
                                       stable_max=SER_STABLE)
    for cell in sweeps.values():
        cell.pop("_result", None)
    report["sweeps"] = sweeps
    report["preset_2101_01664"] = preset_cell(twin, narma)
    return report


def run() -> list[str]:
    """benchmarks.run section: CSV rows + the JSON artifact."""
    report = build_report(smoke=False)
    failures = check(report)
    with open("BENCH_device_sweep.json", "w") as fh:
        json.dump(report, fh, indent=2)
    if failures:
        raise AssertionError("device_sweep check FAILED: " + "; ".join(failures))
    rows = [csv_row("device_sweep/parity_narma10_delta",
                    f"{report['parity']['narma10_nrmse_delta']:.5f}",
                    f"bound={PARITY_NRMSE}"),
            csv_row("device_sweep/tick_parity",
                    f"{report['parity']['tick_parity_max_abs']:.2e}",
                    f"bound={PARITY_TICK}")]
    for name, cell in report["sweeps"].items():
        s = cell["stable"]
        best = s.get("best_point", {})
        rows.append(csv_row(
            f"device_sweep/{name}/stable_fraction", s["stable_fraction"],
            f"lanes={cell['n_lanes']};best={best}"))
    rows.append(csv_row("device_sweep/no_retrace",
                        int(report["no_retrace"]["ok"]),
                        f"cache={report['no_retrace']['cache_after']}"))
    rows.append(csv_row("device_sweep/preset_2101_01664_nrmse",
                        report["preset_2101_01664"]["narma10_nrmse"], ""))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: parity + contracts + no-retrace + the "
                         "NARMA10 map (skips the chan-eq SER map)")
    ap.add_argument("--out", default="BENCH_device_sweep.json")
    args = ap.parse_args()
    report = build_report(smoke=args.smoke)
    failures = check(report)
    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2)
    print(json.dumps(report, indent=2))
    if failures:
        raise SystemExit("device_sweep check FAILED: " + "; ".join(failures))


if __name__ == "__main__":
    main()
