"""Benchmark harness entry point: one section per paper table/figure plus the
roofline analysis.  Prints ``name,value,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run [--only fig5,fig6,...]
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list of fig5,fig6,fig7,table1,kernels,"
                         "kernel_batching,streaming_fusion,wdm_streaming,"
                         "composed_reservoirs,dfr_serving,chaos_soak,"
                         "device_sweep,roofline")
    args = ap.parse_args()

    from . import (chaos_soak, composed_reservoirs, device_sweep, dfr_serving,
                   fig5_nrmse, fig6_ser, fig7_training_time, kernel_batching,
                   kernel_bench, roofline, streaming_fusion, table1_power,
                   wdm_streaming)

    sections = {
        "fig5": fig5_nrmse.run,
        "fig6": fig6_ser.run,
        "fig7": fig7_training_time.run,
        "table1": table1_power.run,
        "kernels": kernel_bench.run,
        "kernel_batching": kernel_batching.run,
        "streaming_fusion": streaming_fusion.run,
        "wdm_streaming": wdm_streaming.run,
        "composed_reservoirs": composed_reservoirs.run,
        "dfr_serving": dfr_serving.run,
        "chaos_soak": chaos_soak.run,
        "device_sweep": device_sweep.run,
        "roofline": roofline.run,
    }
    chosen = args.only.split(",") if args.only else list(sections)
    print("name,value,derived")
    failed = 0
    for name in chosen:
        t0 = time.time()
        try:
            for row in sections[name]():
                print(row)
        except Exception as e:  # noqa: BLE001 — report and continue
            failed += 1
            print(f"{name}/ERROR,{type(e).__name__},{e}")
        print(f"{name}/elapsed_s,{time.time()-t0:.1f},", flush=True)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
