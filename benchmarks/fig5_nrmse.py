"""Paper Fig. 5: NRMSE on NARMA10 and Santa Fe for the three accelerators.

Reproduction targets (the paper reports relative numbers only):
  * NARMA10:  Silicon MR ~35 % lower NRMSE than All Optical (MZI),
              on par with Electronic (MG).
  * Santa Fe: Silicon MR ≫ MZI (paper: 98.7 % lower), MG slightly best.
Datasets sized per the paper: NARMA10 2000 (1000/1000), Santa Fe 6000
(4000/2000, Haken–Lorenz surrogate — DESIGN.md §7).

Each (task, accelerator) cell runs through the jit-end-to-end pipeline
(repro.pipeline.Experiment via benchmarks.common.fit_and_eval); the device
model and N differ per cell, so each cell is its own compiled program.
"""

from __future__ import annotations

from repro.configs import dfrc_tasks
from repro.core import tasks

from .common import csv_row, fit_and_eval


def run() -> list[str]:
    rows = []
    cfgs = dfrc_tasks()

    narma = tasks.narma10(2000, seed=0)
    sf = tasks.santa_fe(6000, seed=0)

    results = {}
    for task_name, ds in [("narma10", narma), ("santa_fe", sf)]:
        for acc_name, cfg in cfgs[task_name].items():
            err = fit_and_eval(cfg, ds, "nrmse")
            results[(task_name, acc_name)] = err
            rows.append(csv_row(f"fig5/{task_name}/{acc_name}/nrmse", f"{err:.4f}",
                                f"N={cfg.n_nodes}"))

    for task_name, claim in [("narma10", 0.35), ("santa_fe", 0.987)]:
        mr = results[(task_name, "Silicon MR")]
        mzi = results[(task_name, "All Optical (MZI)")]
        rel = 1.0 - mr / mzi
        rows.append(csv_row(f"fig5/{task_name}/mr_vs_mzi_reduction", f"{rel:.3f}",
                            f"paper_claims={claim}"))
    mr, mg = results[("narma10", "Silicon MR")], results[("narma10", "Electronic (MG)")]
    rows.append(csv_row("fig5/narma10/mr_vs_mg_ratio", f"{mr / mg:.3f}", "paper:on-par"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
