"""Microbenchmarks for the Pallas kernels (interpret mode on CPU — numbers
are functional sanity, not TPU perf; the TPU claims live in §Roofline)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SiliconMR, make_mask
from repro.kernels.dfr_scan import dfr_scan
from repro.kernels.ridge_gram import gram_accumulate

from .common import csv_row


def _time(fn, *args, iters=3):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters * 1e6


def run() -> list[str]:
    rows = []
    rng = np.random.default_rng(0)

    b, k, n = 256, 64, 64
    j = jnp.asarray(rng.uniform(0, 1, (b, k)), jnp.float32)
    mask = make_mask(n)
    s0 = jnp.zeros((b, n), jnp.float32)
    us = _time(lambda a, m, s: dfr_scan(SiliconMR(), a, m, s), j, mask, s0)
    rows.append(csv_row("kernel/dfr_scan_us", f"{us:.0f}", f"B={b},K={k},N={n},interpret"))

    x = jnp.asarray(rng.standard_normal((2048, 256)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((2048, 1)), jnp.float32)
    us = _time(gram_accumulate, x, y)
    rows.append(csv_row("kernel/ridge_gram_us", f"{us:.0f}", "T=2048,F=256,interpret"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
