"""Streaming fused reservoir -> readout benchmark: peak memory + wall time.

Quantifies what ISSUE 3 fixes.  The materialized kernel path writes the full
[B, T, N] state tensor to HBM (``dfr_scan``) and reads it all back
(``ridge_gram``) — at the paper's N = 900 / T = 4000 operating point a
B = 512 sweep stages ~7 GB of f32 states that are consumed exactly once.
The streaming path (``pipeline/ridge.fit_ridge_streaming``) scans over
K-chunks with the reservoir state carried between chunks and per-chunk
states folded into running Gram stacks, so the largest live state block is
the (lane-padded) chunk.

Two memory numbers per cell, both derived from the traced jaxpr
(``repro.analysis``) so they are exact on any backend:

* ``peak_state_bytes`` — largest intermediate with a stream axis alongside a
  node/feature axis (the tensor class the streaming path exists to kill);
* ``peak_any_bytes``  — largest single intermediate of any kind (on the
  streamed path this is typically the [B, F, F] Gram stack, the irreducible
  cost of per-instance ridge statistics).

Wall times are measured where the backend can afford them: every cell on
TPU, only the CPU-feasible cells in interpret mode (wall numbers off-TPU are
functional, as in kernel_batching; the byte columns are what CI gates on).

Emits ``BENCH_streaming_fusion.json``; the ``--smoke`` run is the tier-1 CI
regression gate:

* streamed ``peak_state_bytes`` must not exceed 2× the lane-padded chunk
  budget B_pad·chunk·(N+1)·4,
* streamed and materialized NRMSE must agree to 1e-3 (noise off),
* on TPU only: streamed wall time must not lose to materialized at B = 64.

  PYTHONPATH=src python -m benchmarks.streaming_fusion [--smoke] [--out PATH]
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import (MaxScans, NoStateTensor, Program, check_rules,
                            max_intermediate_bytes, state_tensor_bytes)
from repro.core import SiliconMR, make_mask
from repro.core.reservoir import generate_states
from repro.kernels.dfr_scan import padded_lanes
from repro.pipeline.ridge import fit_ridge_batched, fit_ridge_streaming

from .common import csv_row, stack_datasets, time_fn

GRID_N = (100, 900)
GRID_T = (1000, 4000)
GRID_B = (8, 64, 512)
WASHOUT = 60
LAMS = (1e-6, 1e-4)
PARITY_TOL = 1e-3
# Off-TPU (interpret mode) the kernels are emulation-slow; only time cells up
# to this many state elements so the full grid still finishes.  TPU times all.
CPU_TIME_BUDGET = 8 * 1000 * 100


def _chunk_for(t: int) -> int:
    """Tile-aligned chunk (multiple of the 8-row T tiles) — aligned chunks
    keep the chunked Gram's f32 association closest to one-shot."""
    return min(256, max(8, (t // 8) & ~7))


def _fit_fns(n: int, t: int, chunk: int):
    model = SiliconMR()
    mask = make_mask(n, seed=1)

    def materialized(j, y):
        st = generate_states(model, j, mask, method="kernel")
        return fit_ridge_batched(st[:, WASHOUT:], y[:, WASHOUT:],
                                 lambdas=LAMS, use_kernel=True)

    def streamed(j, y):
        w, idx, _ = fit_ridge_streaming(model, mask, j, y, washout=WASHOUT,
                                        chunk_k=chunk, lambdas=LAMS,
                                        state_method="kernel", use_kernel=True)
        return w, idx

    return jax.jit(materialized), jax.jit(streamed)


def measure_cell(n: int, t: int, b: int, *, chunk: int | None = None,
                 timed: bool | None = None, iters: int = 2) -> dict:
    chunk = chunk or _chunk_for(t)
    mat, stream = _fit_fns(n, t, chunk)
    j = jnp.zeros((b, t), jnp.float32)
    y = jnp.zeros((b, t), jnp.float32)

    prog_m = Program(mat, (j, y), name=f"materialized_N{n}_T{t}_B{b}")
    prog_s = Program(stream, (j, y), name=f"streamed_N{n}_T{t}_B{b}")
    cj_m, cj_s = prog_m.closed_jaxpr, prog_s.closed_jaxpr
    # chunk budget = lane-padded batch × chunk × feature-tile-padded F, the
    # largest state block the streamed path is *allowed* to keep live
    fp = -(-(n + 1) // 128) * 128
    budget = padded_lanes(b) * chunk * fp * 4
    # the shared contract set (same rules the tier-1 tests run): one chunk
    # scan, no full-T tensor, chunk blocks within 2x the budget
    violations = check_rules(prog_s, [
        MaxScans(1),
        NoStateTensor(t, b * t * n, what="full-T state tensor"),
        NoStateTensor(chunk, b * chunk * n, max_bytes=2 * budget,
                      what="chunk state block"),
    ])
    entry = {
        "n": n, "t": t, "b": b, "chunk": chunk,
        "materialized": {
            "peak_state_bytes": state_tensor_bytes(cj_m, t, b * t * n),
            "peak_any_bytes": max_intermediate_bytes(cj_m),
        },
        "streamed": {
            "peak_state_bytes": state_tensor_bytes(cj_s, chunk, b * chunk * n),
            "peak_any_bytes": max_intermediate_bytes(cj_s),
            "full_t_state_bytes": state_tensor_bytes(cj_s, t, b * t * n),
            "chunk_budget_bytes": budget,
            "contract_violations": [str(v) for v in violations],
        },
    }
    entry["state_bytes_ratio"] = round(
        entry["materialized"]["peak_state_bytes"]
        / max(1, entry["streamed"]["peak_state_bytes"]), 2)

    if timed is None:
        timed = (jax.default_backend() == "tpu"
                 or b * t * n <= CPU_TIME_BUDGET)
    entry["timed"] = bool(timed)
    if timed:
        rng = np.random.default_rng(n + t + b)
        j = jnp.asarray(rng.uniform(0, 1, (b, t)), jnp.float32)
        y = jnp.asarray(rng.standard_normal((b, t)), jnp.float32)
        entry["materialized"]["wall_us"] = round(time_fn(mat, j, y, iters=iters), 1)
        entry["streamed"]["wall_us"] = round(time_fn(stream, j, y, iters=iters), 1)
    return entry


def parity_cell(*, n: int, t: int, b: int, chunk: int,
                lams: tuple[float, ...] = LAMS) -> dict:
    """Streamed vs materialized NRMSE on a real task fit (noise off)."""
    import dataclasses

    from repro.core import tasks
    from repro.pipeline import Experiment, ExperimentConfig

    args = stack_datasets([tasks.narma10(2 * t, seed=s) for s in range(b)])
    base = ExperimentConfig(model=SiliconMR(), n_nodes=n, washout=WASHOUT,
                            ridge_l2=lams, state_noise_rel=0.0,
                            state_method="kernel", readout_use_kernel=True)
    res_m = Experiment(base).run(*args)
    res_s = Experiment(dataclasses.replace(base, stream_chunk_k=chunk)).run(*args)
    return {
        "n": n, "t": t, "b": b, "chunk": chunk,
        "nrmse_materialized": [round(float(v), 6) for v in res_m.nrmse],
        "nrmse_streamed": [round(float(v), 6) for v in res_s.nrmse],
        "max_abs_nrmse_diff": float(np.max(np.abs(res_s.nrmse - res_m.nrmse))),
        "max_abs_ser_diff": float(np.max(np.abs(res_s.ser - res_m.ser))),
    }


def check(report: dict) -> list[str]:
    """Regression gates (bytes + parity everywhere; wall time on TPU)."""
    failures = []
    for e in report["cells"]:
        s = e["streamed"]
        # memory-shape gates are the shared repro.analysis rules, evaluated
        # at measure time and serialized with the cell
        for v in s["contract_violations"]:
            failures.append(
                f"streamed contract at N={e['n']} T={e['t']} B={e['b']}: {v}")
        if (report["config"]["backend"] == "tpu" and e["b"] == 64
                and e.get("timed")
                and s["wall_us"] > e["materialized"]["wall_us"]):
            failures.append(
                f"streamed slower than materialized at B=64 "
                f"(N={e['n']} T={e['t']}): {s['wall_us']} vs "
                f"{e['materialized']['wall_us']} us")
        # the acceptance bar of the streaming PR: >= 4x lower peak state
        # memory at the paper's headline operating point
        if (e["n"] == 900 and e["t"] == 4000 and e["b"] >= 64
                and e["state_bytes_ratio"] < 4.0):
            failures.append(
                f"peak state memory ratio {e['state_bytes_ratio']} < 4x at "
                f"N=900 T=4000 B={e['b']}")
    for p in report["parity"]:
        if p["max_abs_nrmse_diff"] > PARITY_TOL or p["max_abs_ser_diff"] > PARITY_TOL:
            failures.append(
                f"streamed-vs-materialized parity {p['max_abs_nrmse_diff']:.2e}"
                f"/{p['max_abs_ser_diff']:.2e} exceeds {PARITY_TOL} at "
                f"N={p['n']} T={p['t']}")
    return failures


def build_report(*, smoke: bool) -> dict:
    if smoke:
        # well-regularised single-λ smoke parity: a tiny N=16 fit under a
        # multi-λ GCV grid is ill-conditioned enough that f32 Gram
        # association noise alone moves NRMSE > 1e-3 — not the property the
        # gate is protecting
        cells = [measure_cell(16, 96, 8, chunk=32, iters=1),
                 measure_cell(16, 96, 64, chunk=32, iters=1)]
        parity = [parity_cell(n=16, t=180, b=4, chunk=64, lams=(1e-4,))]
    else:
        cells = [measure_cell(n, t, b) for n in GRID_N for t in GRID_T
                 for b in GRID_B]
        parity = [parity_cell(n=100, t=500, b=4, chunk=128)]
    return {
        "config": {"backend": jax.default_backend(), "smoke": smoke,
                   "washout": WASHOUT,
                   "wall_note": "off-TPU walls are interpret-mode functional "
                                "numbers; byte columns are backend-exact"},
        "cells": cells,
        "parity": parity,
    }


def run() -> list[str]:
    """benchmarks.run section: CSV rows + the JSON artifact."""
    report = build_report(smoke=False)
    with open("BENCH_streaming_fusion.json", "w") as fh:
        json.dump(report, fh, indent=2)
    failures = check(report)
    if failures:  # same regression gate as --smoke; run.py reports + exits 1
        raise AssertionError("streaming_fusion check FAILED: " + "; ".join(failures))
    rows = []
    for e in report["cells"]:
        name = f"streaming_fusion/N{e['n']}_T{e['t']}_B{e['b']}"
        rows.append(csv_row(f"{name}/state_bytes_ratio",
                            f"{e['state_bytes_ratio']:.1f}",
                            f"mat={e['materialized']['peak_state_bytes']};"
                            f"stream={e['streamed']['peak_state_bytes']}"))
        if e.get("timed"):
            rows.append(csv_row(
                f"{name}/wall_us",
                f"{e['streamed']['wall_us']:.0f}",
                f"materialized={e['materialized']['wall_us']:.0f}"))
    for p in report["parity"]:
        rows.append(csv_row("streaming_fusion/parity_max_nrmse_diff",
                            f"{p['max_abs_nrmse_diff']:.2e}",
                            f"tol={PARITY_TOL}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes / 1 iter (CI gate on peak state bytes "
                         "+ streamed-vs-materialized parity)")
    ap.add_argument("--out", default="BENCH_streaming_fusion.json")
    args = ap.parse_args()
    report = build_report(smoke=args.smoke)
    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2)
    print(json.dumps(report, indent=2))
    failures = check(report)
    if failures:
        raise SystemExit("streaming_fusion check FAILED: " + "; ".join(failures))


if __name__ == "__main__":
    main()
