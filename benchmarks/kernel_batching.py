"""Kernel-path batching benchmark: padded-lane waste, old vs new tiling.

Quantifies what ISSUE 2 fixes.  For the reservoir scan, the old behaviour
(fixed ``block_s = 8``) pads every batch to a multiple of 8 × 128 = 1024
lanes — a B = 8 sweep runs 128× wasted reservoir work — while the auto
heuristic (smallest block_s ∈ {1, 2, 4, 8} covering B) pads B ≤ 128 to a
single 128-lane vreg row.  For the readout, the old per-instance
``lax.map`` of ``gram_accumulate`` launches is compared against ONE
batch-gridded ``gram_accumulate_batched`` call.

Emits ``BENCH_kernel_batching.json``:

  {"reservoir": [{batch, tiling, block_s, lanes, padded_lane_fraction,
                  wall_us}, ...],
   "readout":   [{batch, path, wall_us}, ...]}

Wall times are interpret-mode (CPU) functional numbers off-TPU — the
padded-lane fractions are exact either way and are what CI gates on: the
``--smoke`` run fails if auto-tiling at B = 8 pads beyond 128 lanes.

  PYTHONPATH=src python -m benchmarks.kernel_batching [--smoke] [--out PATH]
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import (MaxPallasCalls, Program, VmemBudget, check_rules,
                            pallas_eqns)
from repro.core import SiliconMR, make_mask
from repro.kernels.dfr_scan import auto_block_s, dfr_scan, padded_lanes
from repro.kernels.ridge_gram import gram_accumulate, gram_accumulate_batched

from .common import csv_row, time_fn

BATCHES = (1, 8, 64, 512)


def reservoir_section(*, k: int, n: int, iters: int) -> list[dict]:
    model = SiliconMR()
    mask = make_mask(n, seed=1)
    rng = np.random.default_rng(0)
    entries = []
    for b in BATCHES:
        j = jnp.asarray(rng.uniform(0, 1, (b, k)), jnp.float32)
        s0 = jnp.zeros((b, n), jnp.float32)
        for tiling, block_s in (("fixed8", 8), ("auto", auto_block_s(b))):
            lanes = padded_lanes(b, block_s)
            us = time_fn(lambda jj, ss, bs=block_s: dfr_scan(model, jj, mask, ss, block_s=bs),
                       j, s0, iters=iters)
            entries.append({
                "batch": b,
                "tiling": tiling,
                "block_s": block_s,
                "lanes": lanes,
                "padded_lane_fraction": (lanes - b) / lanes,
                "wall_us": round(us, 1),
            })
    return entries


def readout_section(*, t: int, f: int, iters: int) -> list[dict]:
    rng = np.random.default_rng(1)
    entries = []
    for b in BATCHES:
        x = jnp.asarray(rng.standard_normal((b, t, f)), jnp.float32)
        y = jnp.asarray(rng.standard_normal((b, t, 1)), jnp.float32)

        def mapped(xx, yy):
            return jax.lax.map(lambda xy: gram_accumulate(xy[0], xy[1]), (xx, yy))

        # Both paths jitted end-to-end so the eager pad/slice dispatch in the
        # Python wrappers doesn't skew the comparison.
        for path, fn in (("map", jax.jit(mapped)),
                         ("batched", jax.jit(gram_accumulate_batched))):
            entries.append({
                "batch": b,
                "path": path,
                "wall_us": round(time_fn(fn, x, y, iters=iters), 1),
            })
    return entries


def readout_contracts(*, t: int, f: int) -> list[dict]:
    """Static contracts for the batched Gram: ONE launch whose per-block VMEM
    estimate must fit the budget and stay B-independent.

    The B-independence column is the device-memory half of the interpret-mode
    anomaly diagnosis (DESIGN.md §11): the kernel's working set does not grow
    with B, so the batched path's wall-time blow-up at large B in the readout
    section above can only come from the grid emulation, not the memory model
    the kernel compiles to.
    """
    entries = []
    for b in BATCHES:
        x = jnp.zeros((b, t, f), jnp.float32)
        y = jnp.zeros((b, t, 1), jnp.float32)
        prog = Program(gram_accumulate_batched, (x, y),
                       name=f"batched_gram_B{b}")
        vmem = [VmemBudget.estimate_bytes(eqn)
                for eqn, _ in pallas_eqns(prog.closed_jaxpr)]
        violations = check_rules(prog, [MaxPallasCalls(1), VmemBudget()])
        entries.append({
            "batch": b,
            "vmem_block_bytes": max(vmem) if vmem else 0,
            "contract_violations": [str(v) for v in violations],
        })
    return entries


def check(report: dict) -> list[str]:
    """Gate the batching fix: auto-tiling must not over-pad small sweeps, and
    the batched Gram launch must honour its static contracts."""
    failures = []
    for e in report["reservoir"]:
        if e["tiling"] == "auto" and e["batch"] <= 128 and e["lanes"] > 128:
            failures.append(f"auto tiling at B={e['batch']} pads to {e['lanes']} lanes (> 128)")
    for e in report.get("readout_contracts", []):
        for v in e["contract_violations"]:
            failures.append(f"batched Gram contract at B={e['batch']}: {v}")
    sizes = {e["vmem_block_bytes"] for e in report.get("readout_contracts", [])}
    if len(sizes) > 1:
        failures.append(
            f"batched Gram VMEM block estimate varies with B: {sorted(sizes)} "
            f"— the launch working set must be batch-independent")
    return failures


def build_report(*, smoke: bool) -> dict:
    k, n, t, f = (4, 8, 64, 16) if smoke else (64, 64, 512, 64)
    iters = 1 if smoke else 3
    return {
        "config": {"backend": jax.default_backend(), "smoke": smoke,
                   "reservoir": {"K": k, "N": n}, "readout": {"T": t, "F": f}},
        "reservoir": reservoir_section(k=k, n=n, iters=iters),
        "readout": readout_section(t=t, f=f, iters=iters),
        "readout_contracts": readout_contracts(t=t, f=f),
    }


def run() -> list[str]:
    """benchmarks.run section: CSV rows + the JSON artifact."""
    report = build_report(smoke=False)
    with open("BENCH_kernel_batching.json", "w") as fh:
        json.dump(report, fh, indent=2)
    failures = check(report)
    if failures:  # same regression gate as --smoke; run.py reports + exits 1
        raise AssertionError("kernel_batching check FAILED: " + "; ".join(failures))
    rows = []
    for e in report["reservoir"]:
        rows.append(csv_row(f"kernel_batching/reservoir_B{e['batch']}_{e['tiling']}_us",
                            f"{e['wall_us']:.0f}",
                            f"lanes={e['lanes']};padfrac={e['padded_lane_fraction']:.3f}"))
    for e in report["readout"]:
        rows.append(csv_row(f"kernel_batching/readout_B{e['batch']}_{e['path']}_us",
                            f"{e['wall_us']:.0f}", ""))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes / 1 iter (CI gate on padded-lane fractions)")
    ap.add_argument("--out", default="BENCH_kernel_batching.json")
    args = ap.parse_args()
    report = build_report(smoke=args.smoke)
    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2)
    print(json.dumps(report, indent=2))
    failures = check(report)
    if failures:
        raise SystemExit("kernel_batching check FAILED: " + "; ".join(failures))


if __name__ == "__main__":
    main()
