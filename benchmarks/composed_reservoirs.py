"""Composed-reservoir benchmark: topology payoff + streaming memory contract.

Quantifies what ISSUE 9's reservoir-graph refactor buys.  The paper's
accelerator is ONE delay loop + ONE MR neuron; the related work composes
reservoirs — deep/cascaded photonic RC with an on-chip link nonlinearity
(arXiv:2512.10626) and series-coupled microrings with high linear memory
capacity (arXiv:2308.15902).  This bench runs the depth∈{1,2,3} ×
loops∈{1,2} grid at MATCHED total virtual nodes (width 48) on the linear
memory-capacity probe (`core/tasks.memory_capacity`, scored by
`metrics.memory_capacity_score`), so the payoff is measured, not asserted:

* the single-loop baseline is the paper's operating point (SiliconMR
  defaults, τ_ph = 50 ps) — MC ≈ 4.0–4.2 over mask seeds;
* the winning composed cells are *series-coupled multi-timescale* chains: a
  long slow ring (τ_ph = 150 ps) whose mean-tap drives a short paper-point
  ring through a sin² (MZI) link biased at its max-slope point
  (link_gain 0.28 puts the ~2.8±0.4 mean-tap drive at sin² argument ≈ π/4).
  Measured MC ≈ 5.1–5.2 at the same 48 virtual nodes — the heterogeneous-Q
  composition is exactly the arXiv:2308.15902 pitch.  Homogeneous splits
  (same τ everywhere) LOSE capacity at matched width because linear MC is
  dominated by loop length; the JSON records those cells too.

Beyond linear capacity, the bit cells probe *nonlinear* memory on binary
product tasks (delayed XOR, parity-3): the readout must multiply delayed
inputs, which linear MC alone cannot buy.  These run the same 48-node
layouts at γ = 0.6 (the paper's γ = 0.9 leaves every topology at chance on
product tasks; see the M_BIT note), so the sin²-link compositions can show
— or fail to show — a payoff past capacity.  The JSON records the
composed-vs-single-loop bit-error margins (``bit_payoff``) without gating
them; the margins are the measurement.

Memory cells trace `fit_ridge_streaming_composed` (kernel path) at
K = 10 000 and derive exact peak-bytes numbers from the jaxpr
(`repro.analysis`): no stage of the chain may materialize a full-K state
tensor, and the peak live state block must stay within 2× the summed
per-stage lane/feature-padded chunk budget.

Emits ``BENCH_composed_reservoirs.json``; the ``--smoke`` run is the tier-1
CI regression gate:

* a depth ≥ 2 or loops ≥ 2 cell must beat the single-loop baseline's linear
  MC by ≥ 0.3 at matched total virtual nodes (ISSUE 9 acceptance; measured
  margin ≈ 1.0 over mask seeds),
* the composed streamed fits must hold NO full-K stage tensor, one chunk
  scan, ≤ depth+1 Pallas launches, peak state block ≤ 2× chunk budget.

  PYTHONPATH=src python -m benchmarks.composed_reservoirs [--smoke] [--out PATH]
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import (MaxPallasCalls, MaxScans, NoStateTensor, Program,
                            check_rules, max_intermediate_bytes,
                            state_tensor_bytes)
from repro.core import ReservoirStage, SiliconMR, build_stage_masks, chain, tasks
from repro.core.metrics import memory_capacity_score
from repro.kernels.dfr_scan import padded_lanes
from repro.pipeline import (Experiment, ExperimentConfig,
                            fit_ridge_streaming_composed)

from .common import csv_row, stack_datasets

WIDTH = 48                   # matched total virtual nodes for every cell
MC_MAX_DELAY = 24
MC_SAMPLES = 1200
MC_TASK_SEEDS = 3
WASHOUT = 40
CHUNK = 64                   # payoff cells stream at this chunk
LAMS = (1e-8, 1e-6, 1e-4)
MC_MARGIN = 0.3              # composed must beat baseline by this much
# trace cells: full-K budget proof at the long-stream operating point.
# 160 (not 128) so the chunk axis never collides with the 128-wide
# feature-tile axes of the Gram pad in NoStateTensor dimension matching.
TRACE_K = 10_000
TRACE_CHUNK = 160

M_PAPER = SiliconMR()                      # τ_ph = 50 ps operating point
M_SLOW = SiliconMR(tau_ph_ps=150.0)        # engineered lower-Q slow ring
# sin² link biased at max slope: mean-tap drive ≈ 2.8 ± 0.4, and
# 0.28 · 2.8 ≈ π/4 where |d sin²/dp| is maximal (graph.stage_link_drive)
SIN2 = dict(link="sin2", link_gain=0.28)


def topologies() -> dict[str, object]:
    """The depth × loops grid, every cell at ``WIDTH`` total virtual nodes."""
    s = ReservoirStage
    return {
        "d1_l1_baseline": chain(
            s(model=M_PAPER, n_nodes=48, mask_seed=3)),
        "d1_l2": chain(
            s(model=M_PAPER, n_nodes=24, loops=2, mask_seed=3)),
        "d2_l1": chain(
            s(model=M_SLOW, n_nodes=40, mask_seed=3, **SIN2),
            s(model=M_PAPER, n_nodes=8, mask_seed=10)),
        "d2_l2": chain(
            s(model=M_SLOW, n_nodes=20, loops=2, mask_seed=3, **SIN2),
            s(model=M_PAPER, n_nodes=8, mask_seed=10)),
        "d3_l1": chain(
            s(model=M_SLOW, n_nodes=36, mask_seed=3, **SIN2),
            s(model=M_PAPER, n_nodes=8, mask_seed=10, **SIN2),
            s(model=M_PAPER, n_nodes=4, mask_seed=17)),
        "d3_l2": chain(
            s(model=M_SLOW, n_nodes=16, loops=2, mask_seed=3, **SIN2),
            s(model=M_PAPER, n_nodes=6, loops=2, mask_seed=10, **SIN2),
            s(model=M_PAPER, n_nodes=4, mask_seed=17)),
    }


def _stage_desc(stage: ReservoirStage) -> str:
    return (f"{stage.n_nodes}x{stage.loops}@tau{stage.model.tau_ph_ps:g}"
            f"/{stage.link}:{stage.link_gain:g}")


def _mc_batch():
    return stack_datasets([
        tasks.memory_capacity(MC_SAMPLES, max_delay=MC_MAX_DELAY, seed=s)
        for s in range(MC_TASK_SEEDS)])


def mc_cell(name: str, graph, batch) -> dict:
    """Linear MC of one topology over the task-seed stack (ONE jit run)."""
    cfg = ExperimentConfig(model=M_PAPER, n_nodes=graph.width,
                           washout=WASHOUT, ridge_l2=LAMS, topology=graph,
                           stream_chunk_k=CHUNK, state_method="fast",
                           state_noise_rel=0.0)
    res = Experiment(cfg).run(*batch)
    mcs = [memory_capacity_score(batch[3][b], res.y_pred[b])
           for b in range(batch[3].shape[0])]
    return {
        "name": name,
        "depth": graph.depth,
        "loops": max(st.loops for st in graph.stages),
        "width": graph.width,
        "stages": [_stage_desc(st) for st in graph.stages],
        "mc_per_seed": [round(float(m), 4) for m in mcs],
        "mc_mean": round(float(np.mean(mcs)), 4),
    }


# Nonlinear-memory payoff probes: binary tasks where the readout must
# compute a PRODUCT of delayed inputs (delayed XOR, parity-3), so linear MC
# alone cannot solve them.  These run at their OWN operating point: feedback
# strength γ sets the nonlinear-mixing regime, and at the paper's γ = 0.9
# every topology sits at chance on product tasks (measured: bit error
# 0.49-0.51), while γ ≲ 0.3 makes the single loop perfect (no headroom).
# γ = 0.6 is the informative middle — single-loop bit error ≈ 0.17, so a
# composed payoff (or penalty) is visible in either direction.  Each task
# thresholds at the midpoint of ITS target alphabet (XOR targets {0, 1},
# parity targets ±1).  Recorded, not gated: the margins are the measurement.
M_BIT = SiliconMR(gamma=0.6)
M_BIT_SLOW = SiliconMR(gamma=0.6, tau_ph_ps=150.0)
BIT_TASKS = {
    "delayed_xor": (lambda s: tasks.delayed_xor(1200, delay=2, seed=s), 0.5),
    "parity3": (lambda s: tasks.parity(1200, order=3, delay=1, seed=s), 0.0),
}
BIT_SEEDS = 2


def bit_topologies() -> dict[str, object]:
    """The bit-task depth grid: same 48-node layouts, γ = 0.6 models."""
    s = ReservoirStage
    return {
        "d1_l1_baseline": chain(
            s(model=M_BIT, n_nodes=48, mask_seed=3)),
        "d2_l1": chain(
            s(model=M_BIT_SLOW, n_nodes=40, mask_seed=3, **SIN2),
            s(model=M_BIT, n_nodes=8, mask_seed=10)),
        "d3_l1": chain(
            s(model=M_BIT_SLOW, n_nodes=36, mask_seed=3, **SIN2),
            s(model=M_BIT, n_nodes=8, mask_seed=10, **SIN2),
            s(model=M_BIT, n_nodes=4, mask_seed=17)),
    }


def bit_cell(task_name: str, make, thr: float, name: str, graph) -> dict:
    """Bit-error rate of one topology on a binary product task."""
    batch = stack_datasets([make(s) for s in range(BIT_SEEDS)])
    cfg = ExperimentConfig(model=M_BIT, n_nodes=graph.width,
                           washout=WASHOUT, ridge_l2=LAMS, topology=graph,
                           stream_chunk_k=CHUNK, state_method="fast",
                           state_noise_rel=0.0)
    res = Experiment(cfg).run(*batch)
    tg = np.asarray(batch[3]) > thr
    yp = np.asarray(res.y_pred) > thr
    err = np.mean(tg != yp, axis=1)
    return {"task": task_name, "name": name, "depth": graph.depth,
            "width": graph.width,
            "bit_error_per_seed": [round(float(e), 4) for e in err],
            "bit_error_mean": round(float(err.mean()), 4)}


def bit_margins(cells: list[dict]) -> dict:
    """Composed-vs-single-loop bit-error margins per task (+ = payoff)."""
    out = {}
    for task in BIT_TASKS:
        rows = {c["name"]: c for c in cells if c["task"] == task}
        base = rows["d1_l1_baseline"]
        best = min((c for c in rows.values() if c["depth"] >= 2),
                   key=lambda c: c["bit_error_mean"])
        out[task] = {
            "baseline_bit_error": base["bit_error_mean"],
            "best_composed": best["name"],
            "best_composed_bit_error": best["bit_error_mean"],
            "margin": round(base["bit_error_mean"]
                            - best["bit_error_mean"], 4),
        }
    return out


def nrmse_cell(name: str, graph, batch) -> dict:
    """NARMA10 NRMSE of one topology (regression payoff column)."""
    cfg = ExperimentConfig(model=M_PAPER, n_nodes=graph.width, washout=50,
                           ridge_l2=(1e-10,) + LAMS, topology=graph,
                           stream_chunk_k=CHUNK, state_method="fast",
                           state_noise_rel=0.0)
    res = Experiment(cfg).run(*batch)
    return {"name": name, "depth": graph.depth, "width": graph.width,
            "nrmse_per_seed": [round(float(v), 4) for v in res.nrmse],
            "nrmse_mean": round(float(res.nrmse.mean()), 4)}


def _fpad(x: int) -> int:
    """Round up to the 128-wide feature tile."""
    return -(-x // 128) * 128


def chunk_budget(graph, b: int, chunk: int) -> int:
    """The largest state the composed streamed fit may legitimately hold:
    every stage's lane/feature-padded chunk block (all live at once inside
    one scan step — stage k+1's drive needs stage k's chunk) plus the
    concatenated bias-augmented feature block, all f32."""
    per_stage = sum(padded_lanes(b * st.loops) * chunk * _fpad(st.n_nodes)
                    for st in graph.stages)
    features = b * chunk * _fpad(graph.width + 1)
    return 4 * (per_stage + features)


def trace_cell(name: str, graph, *, b: int = 3, k: int = TRACE_K,
               chunk: int = TRACE_CHUNK) -> dict:
    """Jaxpr-exact memory proof for the composed streamed fit (no kernel
    execution — trace only, so the K = 10k cell is free on any backend)."""
    masks = build_stage_masks(graph)
    j = jnp.zeros((b, k), jnp.float32)
    y = jnp.zeros((b, k), jnp.float32)

    def fit(jj, yy):
        return fit_ridge_streaming_composed(
            graph, masks, jj, yy, washout=WASHOUT, chunk_k=chunk,
            lambdas=LAMS, state_method="kernel", use_kernel=True)

    prog = Program(fit, (j, y), name=f"composed_{name}_K{k}")
    cj = prog.closed_jaxpr
    n_min = min(st.n_nodes for st in graph.stages)
    budget = chunk_budget(graph, b, chunk)
    violations = check_rules(prog, [
        MaxScans(1),
        MaxPallasCalls(graph.depth + 1),
        NoStateTensor(k, b * k * n_min, what="full-K stage tensor"),
        NoStateTensor(chunk, b * chunk * n_min, max_bytes=2 * budget,
                      what="chunk stage block"),
    ])
    return {
        "name": name, "depth": graph.depth, "width": graph.width,
        "k": k, "chunk": chunk, "b": b,
        "peak_state_bytes": state_tensor_bytes(cj, chunk, b * chunk * n_min),
        "peak_any_bytes": max_intermediate_bytes(cj),
        "full_k_state_bytes": state_tensor_bytes(cj, k, b * k * n_min),
        "chunk_budget_bytes": budget,
        "contract_violations": [str(v) for v in violations],
    }


def check(report: dict) -> list[str]:
    """Regression gates: MC payoff + memory contracts."""
    failures = []
    cells = {c["name"]: c for c in report["mc_cells"]}
    base = cells.get("d1_l1_baseline")
    if base is None:
        return ["missing d1_l1_baseline MC cell"]
    composed = [c for c in cells.values()
                if c["depth"] >= 2 or c["loops"] >= 2]
    best = max(composed, key=lambda c: c["mc_mean"])
    report["payoff"] = {
        "baseline_mc": base["mc_mean"],
        "best_composed": best["name"],
        "best_composed_mc": best["mc_mean"],
        "margin": round(best["mc_mean"] - base["mc_mean"], 4),
        "required_margin": MC_MARGIN,
    }
    if best["mc_mean"] < base["mc_mean"] + MC_MARGIN:
        failures.append(
            f"no composed cell beats the single-loop baseline by {MC_MARGIN} "
            f"at width {WIDTH}: best {best['name']} MC {best['mc_mean']} vs "
            f"baseline {base['mc_mean']}")
    for t in report["trace_cells"]:
        where = f"{t['name']} K={t['k']}"
        for v in t["contract_violations"]:
            failures.append(f"composed streaming contract at {where}: {v}")
        if t["full_k_state_bytes"]:
            failures.append(
                f"full-K stage tensor ({t['full_k_state_bytes']} bytes) "
                f"materialized at {where}")
    return failures


def build_report(*, smoke: bool) -> dict:
    topo = topologies()
    batch = _mc_batch()
    mc_cells = [mc_cell(name, g, batch) for name, g in topo.items()]
    trace_cells = [trace_cell(name, topo[name])
                   for name in ("d1_l1_baseline", "d2_l1", "d3_l1")]
    report = {
        "config": {"backend": jax.default_backend(), "smoke": smoke,
                   "width": WIDTH, "mc_max_delay": MC_MAX_DELAY,
                   "mc_samples": MC_SAMPLES, "chunk": CHUNK,
                   "trace_k": TRACE_K, "trace_chunk": TRACE_CHUNK,
                   "note": "payoff cells stream on the fast path; byte "
                           "columns are jaxpr-exact on any backend"},
        "mc_cells": mc_cells,
        "trace_cells": trace_cells,
    }
    bit_topo = bit_topologies()
    bit_cells = [bit_cell(task, make, thr, name, g)
                 for task, (make, thr) in BIT_TASKS.items()
                 for name, g in bit_topo.items()]
    report["bit_cells"] = bit_cells
    report["bit_payoff"] = bit_margins(bit_cells)
    if not smoke:
        nb = stack_datasets([tasks.narma10(2000, seed=s) for s in range(4)])
        report["nrmse_cells"] = [
            nrmse_cell(name, topo[name], nb)
            for name in ("d1_l1_baseline", "d2_l1", "d3_l1")]
    return report


def run() -> list[str]:
    """benchmarks.run section: CSV rows + the JSON artifact."""
    report = build_report(smoke=False)
    failures = check(report)
    with open("BENCH_composed_reservoirs.json", "w") as fh:
        json.dump(report, fh, indent=2)
    if failures:
        raise AssertionError(
            "composed_reservoirs check FAILED: " + "; ".join(failures))
    rows = []
    for c in report["mc_cells"]:
        rows.append(csv_row(f"composed_reservoirs/{c['name']}/mc",
                            f"{c['mc_mean']:.3f}",
                            f"depth={c['depth']};loops={c['loops']};"
                            f"width={c['width']}"))
    p = report["payoff"]
    rows.append(csv_row("composed_reservoirs/payoff_margin",
                        f"{p['margin']:.3f}",
                        f"best={p['best_composed']};"
                        f"baseline={p['baseline_mc']:.3f}"))
    for task, m in report["bit_payoff"].items():
        rows.append(csv_row(f"composed_reservoirs/{task}/bit_margin",
                            f"{m['margin']:.4f}",
                            f"best={m['best_composed']};"
                            f"baseline={m['baseline_bit_error']:.4f}"))
    for c in report.get("nrmse_cells", []):
        rows.append(csv_row(f"composed_reservoirs/{c['name']}/narma10_nrmse",
                            f"{c['nrmse_mean']:.4f}", f"depth={c['depth']}"))
    for t in report["trace_cells"]:
        rows.append(csv_row(
            f"composed_reservoirs/{t['name']}/peak_state_bytes",
            t["peak_state_bytes"],
            f"budget={t['chunk_budget_bytes']};full_k={t['full_k_state_bytes']}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: MC payoff grid + trace-only memory "
                         "contracts (skips the NARMA10 NRMSE cells)")
    ap.add_argument("--out", default="BENCH_composed_reservoirs.json")
    args = ap.parse_args()
    report = build_report(smoke=args.smoke)
    failures = check(report)
    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2)
    print(json.dumps(report, indent=2))
    if failures:
        raise SystemExit(
            "composed_reservoirs check FAILED: " + "; ".join(failures))


if __name__ == "__main__":
    main()
