"""§Roofline: three-term roofline per (arch × shape) from the compiled dry-run.

Hardware model (assignment): TPU v5e-class chip —
  peak = 197 TFLOP/s bf16,  HBM = 819 GB/s,  ICI ≈ 50 GB/s/link (~3 links
  usable per collective on a 2-D torus; we charge the per-device collective
  bytes against one link — the conservative reading).

Terms (per device, seconds per training/serving step):
  compute    = HLO_FLOPs / peak
  memory     = HLO_bytes / HBM_bw
  collective = collective_bytes / link_bw

Sources:
  * HLO_FLOPs / HLO_bytes: ``compiled.cost_analysis()`` via the
    *structure-calibrated* extraction (launch/calibrate.py) — XLA counts a
    while body once, so per-unit costs are measured on 1-unit vs 2-unit
    variants at full tensor dims and recombined exactly.  Residual
    under-counts from inner sequence loops (sLSTM scan, ReservoirMixer
    period scan, chunked-attention KV scan) get analytic corrections below.
  * collective_bytes: parsed from the compiled HLO (launch/dryrun.py),
    ring-algorithm wire-bytes convention, same calibration.
  * MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) per the assignment;
    ratio MODEL_FLOPS / HLO_FLOPs measures how much compiled compute is
    "useful" (remat + attention + dispatch overhead show up here).

CPU-backend caveat recorded with every row: XLA-CPU stores bf16 temporaries
as f32 (fusion-boundary promotion), so memory_analysis() and byte counts are
upper bounds ≈ 2× on activation traffic; TPU numbers are strictly lower.
"""

from __future__ import annotations

import json
import pathlib

from repro.configs import SHAPES, get_config, list_archs, runnable_cells

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s
LINK_BW = 50e9               # B/s per ICI link

DRYRUN_DIR = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def _analytic_corrections(cfg, shape: str) -> float:
    """FLOPs (per device, one step) that inner `while` loops hide from the
    calibrated HLO count.  Documented in the module docstring."""
    info = SHAPES[shape]
    b, s = info["batch"], info["seq"]
    if info["kind"] == "decode":
        s = 1  # one new token
    n_dev = 256
    extra = 0.0
    mult = 3.0 if info["kind"] == "train" else 1.0  # fwd+bwd+remat ≈ 3-4× fwd
    # sLSTM sequential scan: recurrent matmul per step, counted once per unit.
    n_slstm = sum(1 for blk in cfg.unit if blk.mixer == "slstm") * cfg.n_units
    if n_slstm and s > 1:
        d, h = cfg.d_model, cfg.n_heads
        per_step = 2.0 * b * (4.0 * d * d / h)  # block-diag recurrence
        extra += mult * n_slstm * (s - 1) * per_step / n_dev
    # ReservoirMixer period scan: ~8 flops per (node, channel, token).
    n_res = sum(1 for blk in cfg.unit if blk.mixer == "reservoir") * cfg.n_units
    if n_res and s > 1:
        r = max(1, cfg.d_model // cfg.reservoir_nodes)
        extra += mult * n_res * (s - 1) * 8.0 * b * r * cfg.reservoir_nodes / n_dev
    # Chunked-attention KV scan (prefill >8k): QK^T + PV flops, counted for
    # one chunk only; add the remaining chunks analytically.
    if info["kind"] == "prefill" and s > 8192:
        n_attn = sum(1 for blk in cfg.unit if blk.mixer == "attn") * cfg.n_units
        full = 4.0 * b * s * s * cfg.n_heads * cfg.head_dim  # QK + PV, fwd
        n_chunks = s // 2048
        extra += n_attn * full * (n_chunks - 1) / n_chunks / n_dev
    return extra


def analytic_hbm_bytes(cfg, shape: str) -> float:
    """First-principles per-device HBM traffic for one step.

    XLA-CPU's ``bytes accessed`` counts every producer/consumer pair at CPU
    fusion granularity (operands + results of each instruction), so it
    overstates TPU HBM traffic severalfold (a fused producer never
    round-trips HBM).  This model counts what must move on a TPU:

      train:  optimizer state (p,m,v f32 read+write) + grad accumulation
              (f32 rw per microbatch) + per-microbatch weight reads (bf16,
              the TP shard) + activations r/w per layer (±remat reread)
              + logits (f32)
      serve:  weight shard read + cache read/write + activations
    """
    info = SHAPES[shape]
    b, s = info["batch"], info["seq"]
    n_dev, tp = 256, 16
    p_total = cfg.param_count()
    p_active = cfg.active_param_count()
    p_shard = p_total / n_dev
    d = cfg.d_model

    if info["kind"] == "train":
        m = cfg.microbatches
        tok_loc = b * s / m / tp  # per-device tokens per microbatch (data=16)
        byt = 24.0 * p_shard                       # optimizer p,m,v f32 rw
        byt += m * 8.0 * p_shard                   # grad accum f32 rw
        byt += m * 2.0 * (p_active / tp)           # weight reads, bf16 TP shard
        act_rw = 8.0 * tok_loc * d * 2.0 * cfg.n_layers     # ~8 tensors/layer bf16
        byt += m * act_rw * 2.0                    # fwd + remat reread in bwd
        byt += m * tok_loc * (cfg.vocab_size / tp) * 4.0 * 2.0  # logits f32 rw
        return byt

    tok_loc = (b * s if info["kind"] == "prefill" else b) / tp
    byt = 2.0 * (p_active / tp)                    # weight shard read, bf16
    byt += 8.0 * tok_loc * d * 2.0 * cfg.n_layers  # activations
    # attention caches: full cache read (decode) or write (prefill)
    kv_bytes = (
        cfg.attn_layers * b * s * cfg.n_kv_heads * cfg.head_dim * 2 * 2 / n_dev
        if any(bl.mixer in ("attn", "cross_attn") for bl in cfg.unit) else 0.0
    )
    byt += kv_bytes
    byt += tok_loc * (cfg.vocab_size / tp) * 4.0
    return byt


def model_flops(cfg, shape: str) -> float:
    """MODEL_FLOPS per the assignment: 6·N·D train, 2·N·D per generated/
    prefilled token for serving (N = active params)."""
    info = SHAPES[shape]
    n = cfg.active_param_count()
    if info["kind"] == "train":
        return 6.0 * n * info["batch"] * info["seq"]
    if info["kind"] == "prefill":
        return 2.0 * n * info["batch"] * info["seq"]
    return 2.0 * n * info["batch"]  # decode: one token per sequence


def load_cell(arch: str, shape: str, mesh: str = "pod", tag: str = "") -> dict | None:
    suffix = f"__{tag}" if tag else ""
    base = DRYRUN_DIR / f"{arch}__{shape}__{mesh}{suffix}.json"
    calib = DRYRUN_DIR / f"calib__{arch}__{shape}__pod{suffix}.json"
    if not base.exists():
        return None
    rec = json.loads(base.read_text())
    if calib.exists():
        rec["calib"] = json.loads(calib.read_text())
    return rec


def analyze_cell(arch: str, shape: str, mesh: str = "pod", tag: str = "") -> dict | None:
    rec = load_cell(arch, shape, mesh, tag)
    if rec is None:
        return None
    cfg = get_config(arch)
    n_dev = rec["n_devices"]

    if "calib" in rec:
        tot = rec["calib"]["total"]
        flops = tot["flops"] + _analytic_corrections(cfg, shape)
        bytes_ = tot["bytes"]
        coll = tot["coll"]
        source = "calibrated"
    else:
        flops, bytes_, coll = rec["flops"], rec["bytes_accessed"], rec["collectives"]["total"]
        source = "raw(uncalibrated)"

    t_compute = flops / PEAK_FLOPS
    t_memory_hlo = bytes_ / HBM_BW
    t_memory = analytic_hbm_bytes(cfg, shape) / HBM_BW
    t_coll = coll / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape) / n_dev
    bound = max(terms.values())
    return {
        "arch": arch,
        "shape": shape,
        "mesh": mesh,
        "compute_s": t_compute,
        "memory_s": t_memory,
        "memory_s_hlo_upper": t_memory_hlo,
        "collective_s": t_coll,
        "dominant": dominant,
        "model_flops_per_dev": mf,
        "hlo_flops_per_dev": flops,
        "useful_ratio": mf / flops if flops else 0.0,
        # fraction of roofline-attainable achieved if the dominant term fully
        # overlaps the others (perfect overlap assumption):
        "roofline_fraction": (mf / PEAK_FLOPS) / bound if bound else 0.0,
        "hbm_gib": rec["memory"]["temp_bytes"] / 2**30,
        "source": source,
    }


def run() -> list[str]:
    rows = []
    for arch in list_archs(include_extras=True):
        for shape in runnable_cells(arch):
            r = analyze_cell(arch, shape)
            if r is None:
                continue
            rows.append(
                f"roofline/{arch}/{shape},"
                f"{r['roofline_fraction']:.4f},"
                f"dom={r['dominant']};comp={r['compute_s']:.2e}s;"
                f"mem={r['memory_s']:.2e}s;coll={r['collective_s']:.2e}s;"
                f"useful={r['useful_ratio']:.3f};src={r['source']}"
            )
    return rows


def markdown_table(mesh: str = "pod") -> str:
    hdr = ("| arch | shape | compute s | memory s | (HLO mem s) | collective s "
           "| dominant | MODEL/HLO | roofline frac |\n|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for arch in list_archs(include_extras=True):
        for shape in runnable_cells(arch):
            r = analyze_cell(arch, shape, mesh)
            if r is None:
                continue
            lines.append(
                f"| {arch} | {shape} | {r['compute_s']:.2e} | {r['memory_s']:.2e} "
                f"| {r['memory_s_hlo_upper']:.2e} | {r['collective_s']:.2e} "
                f"| **{r['dominant']}** "
                f"| {r['useful_ratio']:.3f} | {r['roofline_fraction']:.3f} |"
            )
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table())
