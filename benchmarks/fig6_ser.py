"""Paper Fig. 6: nonlinear channel equalisation SER vs SNR (12–32 dB, step 4).

Reproduction targets: SER decreases with SNR for every accelerator; on
average Silicon MR ~58.8 % lower SER than All Optical (MZI), close to
Electronic (MG).  9000 symbols (6000 train / 3000 test) per the paper.
"""

from __future__ import annotations

import numpy as np

from repro.configs import dfrc_tasks
from repro.core import tasks

from .common import csv_row, fit_and_eval_batch

SNRS = [12.0, 16.0, 20.0, 24.0, 28.0, 32.0]


def run() -> list[str]:
    rows = []
    cfgs = dfrc_tasks()["channel_eq"]
    mean_ser = {}
    # All SNR points are equal-shape task instances -> one compiled sweep
    # per accelerator (the SNR axis is the pipeline's vmapped batch axis).
    datasets = [tasks.channel_equalization(9000, snr_db=snr, seed=0) for snr in SNRS]
    for acc_name, cfg in cfgs.items():
        sers = fit_and_eval_batch(cfg, datasets, "ser")
        for snr, ser in zip(SNRS, sers):
            rows.append(csv_row(f"fig6/snr{snr:g}/{acc_name}/ser", f"{ser:.4f}",
                                f"N={cfg.n_nodes}"))
        mean_ser[acc_name] = float(np.mean(sers))
        rows.append(csv_row(f"fig6/mean/{acc_name}/ser", f"{mean_ser[acc_name]:.4f}", ""))
    rel = 1.0 - mean_ser["Silicon MR"] / max(mean_ser["All Optical (MZI)"], 1e-9)
    rows.append(csv_row("fig6/mr_vs_mzi_mean_reduction", f"{rel:.3f}", "paper_claims=0.588"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
