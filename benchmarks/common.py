"""Shared benchmark helpers: run DFRC accelerators on benchmark tasks.

Benchmarks go through the jit-end-to-end pipeline (repro.pipeline): one
compiled Experiment per accelerator config, batched over task instances —
``fit_and_eval_batch`` evaluates a whole stack of datasets (seeds, SNR
points) in a single call instead of a per-config Python loop.
"""

from __future__ import annotations

import time

import numpy as np

from repro.pipeline import Experiment, ExperimentConfig


def stack_datasets(datasets):
    """Equal-shape core.tasks Datasets -> (tr_in, tr_tg, te_in, te_tg) stacks
    with the instance axis leading (the pipeline's vmapped batch axis)."""
    return tuple(np.stack([getattr(d, f) for d in datasets])
                 for f in ("inputs_train", "targets_train",
                           "inputs_test", "targets_test"))


def time_fn(fn, *args, iters: int = 3) -> float:
    """Mean wall microseconds per call, first (compile) call excluded."""
    import jax

    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def experiment_for(cfg) -> Experiment:
    """Experiment from either a core DFRCConfig or an ExperimentConfig."""
    if not isinstance(cfg, ExperimentConfig):
        cfg = ExperimentConfig.from_dfrc(cfg)
    return Experiment(cfg)


def _metric(res, metric: str) -> np.ndarray:
    if metric == "nrmse":
        return res.nrmse
    if metric == "ser":
        return res.ser
    raise ValueError(metric)


def fit_and_eval(cfg, ds, metric: str) -> float:
    """One accelerator on one dataset -> scalar metric (B = 1 pipeline run)."""
    return float(_metric(experiment_for(cfg).run_dataset(ds), metric)[0])


def fit_and_eval_batch(cfg, datasets, metric: str) -> np.ndarray:
    """One accelerator on a stack of equal-shape datasets -> metric [B].

    All B instances (different seeds / SNRs / task draws) run in ONE jit
    call, vmapped inside the pipeline.
    """
    return _metric(experiment_for(cfg).run(*stack_datasets(datasets)), metric)


def csv_row(name: str, value, derived: str = "") -> str:
    return f"{name},{value},{derived}"
