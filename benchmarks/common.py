"""Shared benchmark helpers: run one DFRC accelerator on one task."""

from __future__ import annotations

from repro.core import DFRCAccelerator


def fit_and_eval(cfg, ds, metric: str) -> float:
    acc = DFRCAccelerator(cfg).fit(ds.inputs_train, ds.targets_train)
    if metric == "nrmse":
        return acc.evaluate_nrmse(ds.inputs_test, ds.targets_test)
    if metric == "ser":
        return acc.evaluate_ser(ds.inputs_test, ds.targets_test)
    raise ValueError(metric)


def csv_row(name: str, value, derived: str = "") -> str:
    return f"{name},{value},{derived}"
