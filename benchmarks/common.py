"""Shared benchmark helpers: run DFRC accelerators on benchmark tasks.

Benchmarks go through the jit-end-to-end pipeline (repro.pipeline): one
compiled Experiment per accelerator config, batched over task instances —
``fit_and_eval_batch`` evaluates a whole stack of datasets (seeds, SNR
points) in a single call instead of a per-config Python loop.
"""

from __future__ import annotations

import numpy as np

from repro.pipeline import Experiment, ExperimentConfig


def experiment_for(cfg) -> Experiment:
    """Experiment from either a core DFRCConfig or an ExperimentConfig."""
    if not isinstance(cfg, ExperimentConfig):
        cfg = ExperimentConfig.from_dfrc(cfg)
    return Experiment(cfg)


def _metric(res, metric: str) -> np.ndarray:
    if metric == "nrmse":
        return res.nrmse
    if metric == "ser":
        return res.ser
    raise ValueError(metric)


def fit_and_eval(cfg, ds, metric: str) -> float:
    """One accelerator on one dataset -> scalar metric (B = 1 pipeline run)."""
    return float(_metric(experiment_for(cfg).run_dataset(ds), metric)[0])


def fit_and_eval_batch(cfg, datasets, metric: str) -> np.ndarray:
    """One accelerator on a stack of equal-shape datasets -> metric [B].

    All B instances (different seeds / SNRs / task draws) run in ONE jit
    call, vmapped inside the pipeline.
    """
    tr_in = np.stack([d.inputs_train for d in datasets])
    tr_tg = np.stack([d.targets_train for d in datasets])
    te_in = np.stack([d.inputs_test for d in datasets])
    te_tg = np.stack([d.targets_test for d in datasets])
    return _metric(experiment_for(cfg).run(tr_in, tr_tg, te_in, te_tg), metric)


def csv_row(name: str, value, derived: str = "") -> str:
    return f"{name},{value},{derived}"
