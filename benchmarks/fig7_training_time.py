"""Paper Fig. 7: training time per accelerator per task.

Training time = state-collection time (n_train · τ, physical) + readout
solve (host linear algebra) — core/timing.py.  The paper's headline: ~98×
faster than 'All Optical (MZI)' and ~93× faster than 'Electronic (MG)' on
average (collection-dominated regimes).

Two row families:

* ``collect_s`` / ``total_s`` — the paper's analytic claim model (the
  collection term is physical hardware time and can only be modelled);
* ``pipeline_fit_s`` — *measured*: the digital-twin training (state
  generation + ridge/GCV fit + evaluation) through the batched
  ``repro.pipeline.Experiment``, a stack of task seeds vmapped into ONE
  compiled call per (task, accelerator) cell — matching fig6's structure;
  no per-instance host loop, and the readout-solve claim is grounded in an
  executed program instead of a flops formula.
"""

from __future__ import annotations

import time

import numpy as np

from repro.configs import dfrc_tasks
from repro.core import tasks, timing

from .common import csv_row, experiment_for, stack_datasets

N_TRAIN = {"narma10": 1000, "santa_fe": 4000, "channel_eq": 6000}
MODELS = {
    "Silicon MR": timing.TIMING_SILICON_MR,
    "All Optical (MZI)": timing.TIMING_MZI,
    "Electronic (MG)": timing.TIMING_MG,
}
N_SEEDS = 2  # batch axis of the measured pipeline cells


def _task_batch(task: str):
    """Equal-shape task instances (seeds) stacked on the batch axis,
    sized to the paper's n_train split."""
    mk = {
        "narma10": lambda s: tasks.narma10(2000, seed=s),
        "santa_fe": lambda s: tasks.santa_fe(6000, train_frac=2.0 / 3.0, seed=s),
        "channel_eq": lambda s: tasks.channel_equalization(9000, snr_db=28.0, seed=s),
    }[task]
    return stack_datasets([mk(s) for s in range(N_SEEDS)])


def measured_rows() -> list[str]:
    rows = []
    cfgs = dfrc_tasks()
    for task in N_TRAIN:
        batch = _task_batch(task)
        for acc_name, cfg in cfgs[task].items():
            exp = experiment_for(cfg)
            exp.run(*batch)                      # compile once
            t0 = time.perf_counter()
            exp.run(*batch)                      # ONE call, N_SEEDS vmapped
            wall = time.perf_counter() - t0
            rows.append(csv_row(f"fig7/{task}/{acc_name}/pipeline_fit_s",
                                f"{wall / N_SEEDS:.3e}",
                                f"batched_{N_SEEDS}_seeds;N={cfg.n_nodes}"))
    return rows


def run() -> list[str]:
    rows = []
    cfgs = dfrc_tasks()
    speedups_mzi, speedups_mg = [], []
    for task, n_train in N_TRAIN.items():
        times = {}
        for acc_name, tm in MODELS.items():
            n_nodes = cfgs[task][acc_name].n_nodes
            t_collect = tm.collection_time_s(n_train, n_nodes)
            t_total = tm.training_time_s(n_train, n_nodes)
            times[acc_name] = (t_collect, t_total)
            rows.append(csv_row(f"fig7/{task}/{acc_name}/collect_s", f"{t_collect:.3e}", ""))
            rows.append(csv_row(f"fig7/{task}/{acc_name}/total_s", f"{t_total:.3e}", ""))
        speedups_mzi.append(times["All Optical (MZI)"][0] / times["Silicon MR"][0])
        speedups_mg.append(times["Electronic (MG)"][0] / times["Silicon MR"][0])
    rows.append(csv_row("fig7/collect_speedup_vs_mzi_geomean",
                        f"{float(np.exp(np.mean(np.log(speedups_mzi)))):.1f}",
                        "paper_claims~98x (collection-dominated)"))
    rows.append(csv_row("fig7/collect_speedup_vs_mg_geomean",
                        f"{float(np.exp(np.mean(np.log(speedups_mg)))):.1f}",
                        "paper_claims~93x vs MZI wording; MG >> MZI >> MR"))
    rows.extend(measured_rows())
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
