"""Paper Fig. 7: training time per accelerator per task.

Training time = state-collection time (n_train · τ, physical) + readout
solve (host linear algebra) — core/timing.py.  The paper's headline: ~98×
faster than 'All Optical (MZI)' and ~93× faster than 'Electronic (MG)' on
average (collection-dominated regimes).
"""

from __future__ import annotations

import numpy as np

from repro.configs import dfrc_tasks
from repro.core import timing

from .common import csv_row

N_TRAIN = {"narma10": 1000, "santa_fe": 4000, "channel_eq": 6000}
MODELS = {
    "Silicon MR": timing.TIMING_SILICON_MR,
    "All Optical (MZI)": timing.TIMING_MZI,
    "Electronic (MG)": timing.TIMING_MG,
}


def run() -> list[str]:
    rows = []
    cfgs = dfrc_tasks()
    speedups_mzi, speedups_mg = [], []
    for task, n_train in N_TRAIN.items():
        times = {}
        for acc_name, tm in MODELS.items():
            n_nodes = cfgs[task][acc_name].n_nodes
            t_collect = tm.collection_time_s(n_train, n_nodes)
            t_total = tm.training_time_s(n_train, n_nodes)
            times[acc_name] = (t_collect, t_total)
            rows.append(csv_row(f"fig7/{task}/{acc_name}/collect_s", f"{t_collect:.3e}", ""))
            rows.append(csv_row(f"fig7/{task}/{acc_name}/total_s", f"{t_total:.3e}", ""))
        speedups_mzi.append(times["All Optical (MZI)"][0] / times["Silicon MR"][0])
        speedups_mg.append(times["Electronic (MG)"][0] / times["Silicon MR"][0])
    rows.append(csv_row("fig7/collect_speedup_vs_mzi_geomean",
                        f"{float(np.exp(np.mean(np.log(speedups_mzi)))):.1f}",
                        "paper_claims~98x (collection-dominated)"))
    rows.append(csv_row("fig7/collect_speedup_vs_mg_geomean",
                        f"{float(np.exp(np.mean(np.log(speedups_mg)))):.1f}",
                        "paper_claims~93x vs MZI wording; MG >> MZI >> MR"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
