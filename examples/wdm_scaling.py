"""Beyond-paper: WDM-multiplexed reservoir ensembles, streamed.

The paper's accelerator processes ONE scalar series through one MR.  A
chip-scale deployment would wavelength-division multiplex R channels through
the same ring + waveguide (each λ sees independent dynamics) — the paper's
Section VI scaling pitch.  This example shows both WDM workloads on the
pipeline:

1. **Throughput scaling (the streaming WDM subsystem, DESIGN.md §9)** — R
   independent streams, one per wavelength, each fit with its own readout by
   ``WDMExperiment``: the whole ensemble runs as ONE jit program whose
   reservoir is a single per-lane-mask Pallas launch per chunk, and with
   ``stream_chunk_k`` set the fit + evaluation scan over K-chunks — the
   [R, K, N] channel-state tensor never exists, so K (stream length) scales
   past HBM.  ``stream_state_dtype="bfloat16"`` halves chunk HBM traffic.

2. **Accuracy scaling (ensemble readout)** — R delayed copies of one input
   act as a deeper virtual reservoir: concatenating the per-channel features
   ([K, R·N]) into one ridge readout improves NARMA10 NRMSE at constant
   optical hardware (``channel_states`` + ``fit_ridge``).

  PYTHONPATH=src python examples/wdm_scaling.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SiliconMR, make_mask, nrmse, tasks
from repro.pipeline import (ExperimentConfig, WDMExperiment, apply_readout,
                            channel_states, fit_ridge)

N = 100        # virtual nodes per wavelength channel
WASHOUT = 60
CHUNK_K = 256  # streaming chunk (periods) — peak state memory is O(R·chunk·N)
LAMS = (1e-10, 1e-8, 1e-6, 1e-4, 1e-2)
model = SiliconMR()

# ---------------------------------------------------------------------------
# 1. Throughput scaling: R wavelength channels, per-channel streamed readouts
# ---------------------------------------------------------------------------
print("== streaming WDM subsystem: R channels, one delay loop, chunked fit ==")
base = ExperimentConfig(model=model, n_nodes=N, washout=WASHOUT, ridge_l2=LAMS,
                        state_noise_rel=0.0, state_method="kernel",
                        readout_use_kernel=True, stream_chunk_k=CHUNK_K)
print(f"{'R (WDM channels)':18s} {'chunks':>7s} {'mean NRMSE':>11s} {'worst':>8s}")
r4_stacks = r4_res = None
for r in [1, 2, 4, 8]:
    # each wavelength carries an independent task instance (its own seed)
    dss = [tasks.narma10(2000, seed=s) for s in range(r)]
    stacks = tuple(np.stack([getattr(d, f) for d in dss]) for f in
                   ("inputs_train", "targets_train", "inputs_test",
                    "targets_test"))
    res = WDMExperiment(base, r).run(*stacks)
    if r == 4:
        r4_stacks, r4_res = stacks, res
    n_chunks = -(-stacks[0].shape[1] // CHUNK_K)
    print(f"{r:18d} {n_chunks:7d} {res.nrmse.mean():11.4f} {res.nrmse.max():8.4f}")

# bf16 state chunks: half the HBM round-trip per chunk, documented parity
res16 = WDMExperiment(dataclasses.replace(base, stream_state_dtype="bfloat16"),
                      4).run(*r4_stacks)
print(f"bf16 chunks @ R=4: mean NRMSE {res16.nrmse.mean():.4f} "
      f"(f32 {r4_res.nrmse.mean():.4f}, drift "
      f"{np.max(np.abs(res16.nrmse - r4_res.nrmse)):.4f})")

# ---------------------------------------------------------------------------
# 2. Accuracy scaling: ensemble feature concat (materialized channel_states)
# ---------------------------------------------------------------------------
print("\n== ensemble readout: R delayed copies -> one concatenated fit ==")
ds = tasks.narma10(2000, seed=0)
lo, ptp = ds.inputs_train.min(), np.ptp(ds.inputs_train)
jtr = jnp.asarray((ds.inputs_train - lo) / ptp, jnp.float32)
jte = jnp.asarray((ds.inputs_test - lo) / ptp, jnp.float32)

print(f"{'R (WDM channels)':18s} {'features':>9s} {'NRMSE':>8s}")
for r in [1, 2, 4, 8]:
    # channel i sees the input delayed by i samples with its own mask seed
    masks = jnp.stack([make_mask(N, seed=10 + i) for i in range(r)])
    j_tr = jnp.stack([jnp.roll(jtr, i) for i in range(r)])   # [R, K]
    j_te = jnp.stack([jnp.roll(jte, i) for i in range(r)])
    st_tr, s_carry = channel_states(model, j_tr, masks, return_final=True)
    st_te = channel_states(model, j_te, masks, s0=s_carry)
    xtr = jnp.moveaxis(st_tr, 0, 1).reshape(jtr.shape[0], r * N)  # [K, R·N]
    xte = jnp.moveaxis(st_te, 0, 1).reshape(jte.shape[0], r * N)

    # digitiser-noise regularisation + GCV λ, as the accelerator does
    noise = 0.003 * jnp.std(xtr) * jax.random.normal(jax.random.PRNGKey(0), xtr.shape)
    w, _ = fit_ridge(xtr[WASHOUT:] + noise[WASHOUT:],
                     jnp.asarray(ds.targets_train, jnp.float32)[WASHOUT:],
                     lambdas=LAMS)
    err = nrmse(ds.targets_test, np.asarray(apply_readout(xte, w)))
    print(f"{r:18d} {r * N:9d} {err:8.4f}")
