"""Beyond-paper: WDM-multiplexed reservoir ensembles.

The paper's accelerator processes ONE scalar series through one MR.  A
chip-scale deployment would wavelength-division multiplex R independent
channels through the same ring + waveguide (each λ sees independent
dynamics).  This example shows the accuracy/parallelism trade: an ensemble
of R reservoirs driven by R delayed copies of the input acts as a deeper
virtual reservoir, improving NARMA10 NRMSE at constant optical hardware.

  PYTHONPATH=src python examples/wdm_scaling.py
"""

import numpy as np

from repro.core import SiliconMR, fit_readout, generate_states, make_mask, nrmse, tasks

ds = tasks.narma10(2000, seed=0)
lo, ptp = ds.inputs_train.min(), np.ptp(ds.inputs_train)
jtr = ((ds.inputs_train - lo) / ptp).astype(np.float32)
jte = ((ds.inputs_test - lo) / ptp).astype(np.float32)

N = 100  # virtual nodes per wavelength channel
model = SiliconMR()

print(f"{'R (WDM channels)':18s} {'features':>9s} {'NRMSE':>8s}")
for r in [1, 2, 4, 8]:
    # channel i sees the input delayed by i samples with its own mask seed
    feats_tr, feats_te = [], []
    for i in range(r):
        mask = make_mask(N, seed=10 + i)
        tr = np.roll(jtr, i)
        te = np.roll(jte, i)
        import jax.numpy as jnp

        str_ = generate_states(model, jnp.asarray(tr), mask)
        ste_ = generate_states(model, jnp.asarray(te), mask, s0=str_[-1])
        feats_tr.append(np.asarray(str_))
        feats_te.append(np.asarray(ste_))
    xtr = np.concatenate(feats_tr, axis=-1)
    xte = np.concatenate(feats_te, axis=-1)
    import jax.numpy as jnp

    # digitiser-noise regularisation + GCV λ, as the accelerator does
    rng = np.random.default_rng(0)
    xtr_n = xtr + rng.normal(0, 0.003 * xtr.std(), xtr.shape)
    ro = fit_readout(jnp.asarray(xtr_n[60:], jnp.float32), ds.targets_train[60:],
                     l2=(1e-10, 1e-8, 1e-6, 1e-4, 1e-2))
    err = nrmse(ds.targets_test, np.asarray(ro(jnp.asarray(xte, jnp.float32))))
    print(f"{r:18d} {r * N:9d} {err:8.4f}")
