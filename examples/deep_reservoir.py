"""Deep reservoir graphs: composition beats a single loop at matched size.

The paper's accelerator is ONE delay loop + ONE MR neuron; the related work
composes reservoirs — series-coupled microrings with high linear memory
capacity (arXiv:2308.15902) and deep photonic RC with an on-chip link
nonlinearity between layers (arXiv:2512.10626).  This example builds both a
depth-1 single loop (the paper's operating point) and a depth-2
series-coupled chain with the SAME total virtual node count, runs each as a
streamed `Experiment` (`ExperimentConfig.topology` — the composed per-stage
carries thread through one chunk scan, so no stage ever materializes a
[B, T, N] block), and scores them on the linear memory-capacity probe:

* depth-1: 48 nodes, one τ_ph = 50 ps ring (SiliconMR defaults);
* depth-2: a 40-node slow ring (τ_ph = 150 ps) whose mean-tap output drives
  an 8-node paper-point ring through a sin² (MZI) link biased at its
  max-slope point — the heterogeneous-Q series coupling of arXiv:2308.15902.

MC = Σ_d r²(u(k−d), ŷ_d): how many delayed copies of the input the readout
can reconstruct (one multi-channel fit reconstructs every delay at once —
the whole suite is ONE vmapped jit call per topology).  Measured: the
depth-2 chain scores ≈ 5.2 vs ≈ 4.2 for the matched single loop, a ~25%
capacity gain from topology alone; benchmarks/composed_reservoirs.py runs
the full depth × loops grid and gates this payoff in CI.

  PYTHONPATH=src python examples/deep_reservoir.py
"""

import numpy as np

from repro.core import ReservoirStage, SiliconMR, chain, tasks
from repro.core.metrics import memory_capacity_score
from repro.pipeline import Experiment, ExperimentConfig

MAX_DELAY = 24
SEEDS = 3

paper_ring = SiliconMR()                  # τ_ph = 50 ps operating point
slow_ring = SiliconMR(tau_ph_ps=150.0)    # engineered lower-Q ring

topologies = {
    "depth-1 (48 nodes, one loop)": chain(
        ReservoirStage(model=paper_ring, n_nodes=48, mask_seed=3)),
    # sin² link biased at max slope: the 40-node stage's mean-tap drive is
    # ≈ 2.8 ± 0.4, and 0.28 · 2.8 ≈ π/4 where |d sin²/dp| peaks
    "depth-2 (40 slow -> 8 paper)": chain(
        ReservoirStage(model=slow_ring, n_nodes=40, mask_seed=3,
                       link="sin2", link_gain=0.28),
        ReservoirStage(model=paper_ring, n_nodes=8, mask_seed=10)),
}

# one MC probe, SEEDS instances stacked on the vmapped batch axis
batch = [tasks.memory_capacity(1200, max_delay=MAX_DELAY, seed=s)
         for s in range(SEEDS)]
tr_in, tr_tg, te_in, te_tg = (
    np.stack([getattr(d, f) for d in batch])
    for f in ("inputs_train", "targets_train", "inputs_test", "targets_test"))

print(f"{'topology':32s} width  MC (of {MAX_DELAY} delay channels)")
scores = {}
for name, graph in topologies.items():
    cfg = ExperimentConfig(model=paper_ring, n_nodes=graph.width, washout=40,
                           ridge_l2=(1e-8, 1e-6, 1e-4), topology=graph,
                           stream_chunk_k=64, state_method="fast",
                           state_noise_rel=0.0)
    res = Experiment(cfg).run(tr_in, tr_tg, te_in, te_tg)
    mcs = [memory_capacity_score(te_tg[b], res.y_pred[b])
           for b in range(SEEDS)]
    scores[name] = float(np.mean(mcs))
    print(f"{name:32s} {graph.width:4d}  {scores[name]:.2f} "
          f"(per seed: {', '.join(f'{m:.2f}' for m in mcs)})")

d1, d2 = scores.values()
print(f"\ndepth-2 vs depth-1 at matched {48} virtual nodes: "
      f"{100 * (d2 / d1 - 1):+.1f}% memory capacity")
assert d2 > d1, "composition should beat the matched single loop"
