"""Device design-space exploration: one compiled program, a whole map.

The physics-fidelity device subsystem (repro.devices, DESIGN.md §14) models
the microring as a coupled-mode-theory cavity — intracavity energy, free
carriers and temperature, sub-stepped inside every virtual-node tick — and
calibrates its zero-power limit to the paper's SiliconMR tick map.  From
that anchor, a (detuning × loss × power) robustness sweep answers the
fabrication question the ideal model cannot: how far off-nominal can the
fabricated ring drift before the accelerator stops computing?

The sweep is the point of this example: every grid cell becomes a batch
lane of ONE jit-compiled Experiment (swept parameters are traced operands,
not jit statics), so the map below compiles once — and re-running with new
grid values compiles nothing (watch the cache counter).

  PYTHONPATH=src python examples/device_sweep.py

Where to next:
  benchmarks/device_sweep.py — the gated version: calibration-parity bound,
                               jaxpr contract checks, NARMA10 + channel-eq
                               stable-region maps (BENCH_device_sweep.json)
"""

from repro.core import SiliconMR, tasks
from repro.devices import (SweepGrid, calibrated_twin, node_parity,
                           pipeline_cache_size, run_device_sweep)

mr = SiliconMR()
cavity = calibrated_twin(mr)   # CMT cavity whose low-power limit IS SiliconMR
print(f"calibration: per-tick |CMT - SiliconMR| over [0,1]^3 = "
      f"{node_parity(mr, cavity):.2e}\n")

grid = SweepGrid(detune=(-1.0, -0.5, 0.0, 0.5, 1.0),   # linewidths off resonance
                 loss_scale=(1.0, 1.5),                # fabricated-Q penalty
                 power=(0.0, 1.0))                     # nonlinearities off/on
res = run_device_sweep(cavity, grid, tasks.narma10(1200, seed=0),
                       n_nodes=64, washout=50, stream_chunk_k=128)

print(f"NARMA10 NRMSE over the {grid.shape} grid ({grid.size} lanes, "
      f"one program):")
for i, d in enumerate(grid.detune):
    for j, l in enumerate(grid.loss_scale):
        row = " ".join(f"{res.nrmse[i, j, k]:.3f}" for k in range(len(grid.power)))
        print(f"  detune {d:+.1f}  loss x{l:.1f}:  {row}")

region = res.stable_region(nrmse_max=0.8)
print(f"\nstable region (NRMSE <= 0.8): {region['summary']['n_stable']}/"
      f"{grid.size} cells, best point {region['summary']['best_point']}")

c0 = pipeline_cache_size()
shifted = SweepGrid(detune=tuple(d + 0.1 for d in grid.detune),
                    loss_scale=(1.1, 1.6), power=(0.2, 1.2))
run_device_sweep(cavity, shifted, tasks.narma10(1200, seed=0),
                 n_nodes=64, washout=50, stream_chunk_k=128)
print(f"\nre-sweep with new grid values: compiled programs {c0} -> "
      f"{pipeline_cache_size()} (no retrace)")
