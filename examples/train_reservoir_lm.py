"""End-to-end driver: train a ~100M-param reservoir LM for a few hundred steps.

The ``reservoir_lm`` architecture carries the paper's technique inside the
LM framework: every layer's sequence mixer is a fixed silicon-MR
delayed-feedback reservoir (3 WDM channels × 256 virtual nodes), with only
readouts + MLPs trained.  This exercises the full production path — sharded
train step, fault-tolerant driver, async checkpointing, deterministic data.

Reduced by default so a CPU run finishes in minutes; pass --full-width for
the actual 100M config (slower on CPU, same code path).

  PYTHONPATH=src python examples/train_reservoir_lm.py --steps 200
"""

import argparse

from repro.launch.train import main as train_main


def run(steps: int, full_width: bool):
    args = [
        "--arch", "reservoir_lm",
        "--steps", str(steps),
        "--checkpoint-dir", "checkpoints/reservoir_lm",
        "--checkpoint-every", "100",
    ]
    if full_width:
        # the real 100M config (d_model 768, 12 layers, 32k vocab)
        args += ["--no-reduce"]
    else:
        args += ["--batch", "8", "--seq", "256", "--d-model", "256",
                 "--layers", "4", "--vocab", "2048", "--lr", "3e-3"]
    history = train_main(args)
    losses = [h["loss"] for h in history]
    n = max(1, len(losses) // 10)
    first, last = sum(losses[:n]) / n, sum(losses[-n:]) / n
    assert last < first, "training did not reduce loss"
    print(f"loss {first:.3f} -> {last:.3f} over {len(losses)} steps "
          f"({(1 - last / first) * 100:.1f}% reduction)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full-width", action="store_true")
    a = ap.parse_args()
    run(a.steps, a.full_width)
