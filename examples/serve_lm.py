"""Serve a small model with batched requests: prefill + decode loop.

Drives the production serving path (launch/serve.py) for a couple of the
assigned architectures at reduced width — batched prompts, one prefill, then
token-by-token decode with a donated KV cache.

  PYTHONPATH=src python examples/serve_lm.py
"""

from repro.launch.serve import main as serve_main

for arch in ["granite-8b", "qwen3-moe-30b-a3b", "xlstm-1.3b"]:
    print(f"--- {arch} ---")
    serve_main(["--arch", arch, "--requests", "4", "--prompt-len", "16",
                "--new-tokens", "8"])
