"""Nonlinear channel equalisation (paper Section V.C.3, Fig. 6).

Sweeps SNR 12-32 dB and plots (as ASCII) the SER of the Silicon-MR DFRC
against the baselines — the task where the reservoir must invert a
nonlinear, noisy communication channel.

The sweep runs through the jit-end-to-end batched pipeline
(repro.pipeline.Experiment): the SNR axis is the pipeline's vmapped batch
axis, so each accelerator's whole 6-point sweep — state generation, ridge/GCV
readout fit, SER — is ONE compiled call instead of a per-SNR Python loop of
host ``DFRCAccelerator`` fits.

  PYTHONPATH=src python examples/channel_equalization.py
"""

import numpy as np

from repro.core import MZISine, MackeyGlass, SiliconMR, tasks
from repro.pipeline import Experiment, ExperimentConfig

SNRS = [12.0, 16.0, 20.0, 24.0, 28.0, 32.0]
LAMS = (1e-10, 1e-8, 1e-6, 1e-4, 1e-2)

accelerators = {
    "Silicon MR": ExperimentConfig(model=SiliconMR(), n_nodes=30, washout=60,
                                   ridge_l2=LAMS, quantize=True),
    "Electronic (MG)": ExperimentConfig(model=MackeyGlass(), n_nodes=400, washout=60,
                                        ridge_l2=LAMS, mask_levels=(-1.0, 1.0),
                                        quantize=True),
    "All Optical (MZI)": ExperimentConfig(model=MZISine(), n_nodes=400, washout=60,
                                          ridge_l2=LAMS, quantize=True),
}

# All SNR points share shapes -> stack them as one batch of task instances.
datasets = [tasks.channel_equalization(9000, snr_db=snr, seed=0) for snr in SNRS]
tr_in = np.stack([d.inputs_train for d in datasets])
tr_tg = np.stack([d.targets_train for d in datasets])
te_in = np.stack([d.inputs_test for d in datasets])
te_tg = np.stack([d.targets_test for d in datasets])

table = {}
for name, cfg in accelerators.items():
    res = Experiment(cfg).run(tr_in, tr_tg, te_in, te_tg)  # one jit call
    table[name] = [float(s) for s in res.ser]

print(f"{'SNR(dB)':10s}" + "".join(f"{s:>9.0f}" for s in SNRS))
for name, sers in table.items():
    print(f"{name:10.10s}" + "".join(f"{s:>9.4f}" for s in sers))

mean = {n: float(np.mean(s)) for n, s in table.items()}
print(f"\nmean SER — MR {mean['Silicon MR']:.4f} vs MZI "
      f"{mean['All Optical (MZI)']:.4f} "
      f"({100 * (1 - mean['Silicon MR'] / mean['All Optical (MZI)']):.1f}% lower; "
      f"paper claims 58.8%)")
