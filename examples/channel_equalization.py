"""Nonlinear channel equalisation (paper Section V.C.3, Fig. 6).

Sweeps SNR 12-32 dB and plots (as ASCII) the SER of the Silicon-MR DFRC
against the baselines — the task where the reservoir must invert a
nonlinear, noisy communication channel.

  PYTHONPATH=src python examples/channel_equalization.py
"""

import numpy as np

from repro.core import (
    DFRCAccelerator,
    DFRCConfig,
    MZISine,
    MackeyGlass,
    SiliconMR,
    tasks,
)

SNRS = [12.0, 16.0, 20.0, 24.0, 28.0, 32.0]

accelerators = {
    "Silicon MR": DFRCConfig(model=SiliconMR(), n_nodes=30, washout=60,
                             ridge_l2=(1e-10, 1e-8, 1e-6, 1e-4, 1e-2), quantize=True),
    "Electronic (MG)": DFRCConfig(model=MackeyGlass(), n_nodes=400, washout=60,
                                  ridge_l2=(1e-10, 1e-8, 1e-6, 1e-4, 1e-2), mask_levels=(-1.0, 1.0), quantize=True),
    "All Optical (MZI)": DFRCConfig(model=MZISine(), n_nodes=400, washout=60,
                                    ridge_l2=(1e-10, 1e-8, 1e-6, 1e-4, 1e-2), quantize=True),
}

table = {}
for name, cfg in accelerators.items():
    sers = []
    for snr in SNRS:
        ds = tasks.channel_equalization(9000, snr_db=snr, seed=0)
        acc = DFRCAccelerator(cfg).fit(ds.inputs_train, ds.targets_train)
        sers.append(acc.evaluate_ser(ds.inputs_test, ds.targets_test))
    table[name] = sers

print(f"{'SNR(dB)':10s}" + "".join(f"{s:>9.0f}" for s in SNRS))
for name, sers in table.items():
    print(f"{name:10.10s}" + "".join(f"{s:>9.4f}" for s in sers))

mean = {n: float(np.mean(s)) for n, s in table.items()}
print(f"\nmean SER — MR {mean['Silicon MR']:.4f} vs MZI "
      f"{mean['All Optical (MZI)']:.4f} "
      f"({100 * (1 - mean['Silicon MR'] / mean['All Optical (MZI)']):.1f}% lower; "
      f"paper claims 58.8%)")
