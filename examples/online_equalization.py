"""Online equalisation of a drifting channel (DESIGN.md §10).

The offline story (examples/channel_equalization.py) fits one readout per
SNR point and evaluates on a held-out stream of the SAME channel.  Real
links drift — here the link changes HALFWAY through the stream
(tasks.channel_equalization_drift): the multipath echoes flip/strengthen
and the SNR steps 28 dB -> 16 dB, so the optimal equaliser itself moves
and the readout must track it while serving.  Online sessions
(pipeline/session) run the identical reservoir over the identical stream,
differing only in the forgetting factor:

* λ = 1.0  — the plain running Gram: every symbol ever seen keeps full
  weight, so after the step the solve stays anchored to the stale old-link
  statistics for thousands of symbols;
* λ < 1   — RLS exponential forgetting: carried statistics decay by λ per
  chunk, so the effective window is ~chunk/(1−λ) symbols and the readout
  re-centres on the new link.

Symbol error rate is measured on the session's OWN streaming predictions
(predict-then-update: each chunk is predicted with the readout solved
before that chunk arrived — no lookahead).

  PYTHONPATH=src python examples/online_equalization.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import SiliconMR, make_mask, tasks
from repro.core.tasks import quantize_symbols
from repro.pipeline import SessionConfig, session_init, session_step

N_SYM, CHUNK, DRIFT = 6000, 50, 0.5
LAMBDAS = (1.0, 0.98, 0.95)
LAMS_L2 = (1e-8, 1e-6, 1e-4)

ds = tasks.channel_equalization_drift(N_SYM, snr_db=28.0, snr_db_after=16.0,
                                      drift_frac=DRIFT, seed=0)
x, d = ds.inputs_test, ds.targets_test
# reservoir drive in [0, 1] (same per-stream affine layer as the offline
# Experiment pipeline — the MR nonlinearity needs a non-negative drive)
x = (x - x.min()) / (x.max() - x.min() + 1e-12)

mask = make_mask(30, seed=0)
drift_at = int(N_SYM * DRIFT)
# steady windows clear of the cold start and of the adaptation transient
windows = {
    "pre-drift  [1500:3000]": slice(1500, drift_at),
    "adapt      [3000:4000]": slice(drift_at, drift_at + 1000),
    "post-drift [4000:6000]": slice(drift_at + 1000, N_SYM),
}

table = {}
for lam in LAMBDAS:
    cfg = SessionConfig(model=SiliconMR(), n_nodes=30, washout=50,
                        ridge_l2=LAMS_L2, chunk_k=CHUNK, forgetting=lam,
                        state_method="fast", use_kernel=False)
    state = session_init(cfg, 1)
    preds = []
    for lo in range(0, N_SYM, CHUNK):
        jc = jnp.asarray(x[None, lo:lo + CHUNK], jnp.float32)
        yc = jnp.asarray(d[None, lo:lo + CHUNK], jnp.float32)
        y_hat, state = session_step(cfg, mask, state, jc, yc, refresh=True)
        preds.append(np.asarray(y_hat)[0, :, 0])
    y = quantize_symbols(np.concatenate(preds))
    table[lam] = {name: float(np.mean(y[sl] != d[sl]))
                  for name, sl in windows.items()}

print(f"{'window':24s}" + "".join(f"  λ={lam:<6g}" for lam in LAMBDAS))
for name in windows:
    print(f"{name:24s}" + "".join(f"  {table[lam][name]:8.4f}"
                                  for lam in LAMBDAS))

post = "post-drift [4000:6000]"
best = min(LAMBDAS[1:], key=lambda lam: table[lam][post])
print(f"\npost-drift SER — λ={best:g}: {table[best][post]:.4f} vs "
      f"λ=1.0: {table[1.0][post]:.4f} "
      f"({100 * (1 - table[best][post] / max(table[1.0][post], 1e-12)):.1f}% lower: "
      f"forgetting re-centres the readout on the drifted link)")
