"""Quickstart: the paper's headline experiment in ~20 lines.

Builds the Silicon-MR DFRC accelerator (paper Fig. 4), trains its readout on
NARMA10, and compares against the two prior-work baselines the paper
evaluates (Electronic MG, All-Optical MZI).  Each accelerator runs through
the jit-end-to-end pipeline: mask -> reservoir -> ridge readout fit/eval is
ONE compiled call (repro.pipeline.Experiment) — batch a [B, T] stack of
inputs to sweep seeds or SNRs in the same call.

  PYTHONPATH=src python examples/quickstart.py

Where to next:
  examples/channel_equalization.py — the offline SNR sweep (Fig. 6)
  examples/deep_reservoir.py       — composed reservoir graphs: a depth-2
                                     series-coupled chain beats the matched
                                     single loop on memory capacity
  examples/online_equalization.py  — ONLINE readouts tracking a drifting
                                     link (RLS forgetting, DESIGN.md §10)
  examples/device_sweep.py         — CMT cavity physics + a (detuning ×
                                     loss × power) robustness map as ONE
                                     compiled program (DESIGN.md §14)
  launch/serve_dfr.py              — continuous-batching DFR serving:
    PYTHONPATH=src python -m repro.launch.serve_dfr --requests 64 --batch 16
"""

from repro.core import MZISine, MackeyGlass, SiliconMR, tasks
from repro.pipeline import Experiment, ExperimentConfig

ds = tasks.narma10(2000, seed=0)  # 1000 train / 1000 test, as in the paper

LAMS = (1e-10, 1e-8, 1e-6, 1e-4, 1e-2)
accelerators = {
    "Silicon MR (this paper)": ExperimentConfig(model=SiliconMR(), n_nodes=400,
                                                washout=60, ridge_l2=LAMS),
    "Electronic (MG)": ExperimentConfig(model=MackeyGlass(), n_nodes=400,
                                        washout=60, ridge_l2=LAMS,
                                        mask_levels=(-1.0, 1.0)),
    "All Optical (MZI)": ExperimentConfig(model=MZISine(), n_nodes=400,
                                          washout=60, ridge_l2=LAMS),
}

print(f"{'accelerator':28s} NRMSE (NARMA10, lower is better)")
results = {}
for name, cfg in accelerators.items():
    res = Experiment(cfg).run_dataset(ds)   # fit + predict + metric, one jit call
    results[name] = float(res.nrmse[0])
    print(f"{name:28s} {results[name]:.4f}")

mr, mzi = results["Silicon MR (this paper)"], results["All Optical (MZI)"]
print(f"\nSilicon MR vs MZI: {100 * (1 - mr / mzi):.1f}% lower NRMSE "
      f"(paper claims 35%)")
