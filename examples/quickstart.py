"""Quickstart: the paper's headline experiment in ~20 lines.

Builds the Silicon-MR DFRC accelerator (paper Fig. 4), trains its readout on
NARMA10, and compares against the two prior-work baselines the paper
evaluates (Electronic MG, All-Optical MZI).

  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import (
    DFRCAccelerator,
    DFRCConfig,
    MZISine,
    MackeyGlass,
    SiliconMR,
    tasks,
)

ds = tasks.narma10(2000, seed=0)  # 1000 train / 1000 test, as in the paper

accelerators = {
    "Silicon MR (this paper)": DFRCConfig(model=SiliconMR(), n_nodes=400,
                                          washout=60, ridge_l2=(1e-10, 1e-8, 1e-6, 1e-4, 1e-2)),
    "Electronic (MG)": DFRCConfig(model=MackeyGlass(), n_nodes=400,
                                  washout=60, ridge_l2=(1e-10, 1e-8, 1e-6, 1e-4, 1e-2), mask_levels=(-1.0, 1.0)),
    "All Optical (MZI)": DFRCConfig(model=MZISine(), n_nodes=400,
                                    washout=60, ridge_l2=(1e-10, 1e-8, 1e-6, 1e-4, 1e-2)),
}

print(f"{'accelerator':28s} NRMSE (NARMA10, lower is better)")
results = {}
for name, cfg in accelerators.items():
    acc = DFRCAccelerator(cfg).fit(ds.inputs_train, ds.targets_train)
    err = acc.evaluate_nrmse(ds.inputs_test, ds.targets_test)
    results[name] = err
    print(f"{name:28s} {err:.4f}")

mr, mzi = results["Silicon MR (this paper)"], results["All Optical (MZI)"]
print(f"\nSilicon MR vs MZI: {100 * (1 - mr / mzi):.1f}% lower NRMSE "
      f"(paper claims 35%)")
