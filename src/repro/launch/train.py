"""Training launcher: mesh + sharded state + fault-tolerant driver.

Runs a real (small-scale) training job on the local devices — the same code
path the production mesh uses, minus device count.  Example:

  PYTHONPATH=src python -m repro.launch.train --arch reservoir_lm \
      --steps 200 --batch 8 --seq 256 --d-model 256 --layers 4

The full-size archs launch identically with ``--no-reduce`` on a real
cluster (the reduced flags exist so the CPU container can train a ~100M
model end-to-end; examples/train_reservoir_lm.py drives this module).
"""

from __future__ import annotations

import argparse
import dataclasses
import logging

import jax

from repro.compat import shardings_for, use_mesh
from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.optim import AdamWConfig
from repro.parallel.sharding import batch_pspec, named, param_pspecs
from repro.runtime.steps import init_train_state, train_step
from repro.runtime.trainer import TrainLoopConfig, run_training


def reduced_config(cfg, args):
    if args.no_reduce:
        return cfg
    return dataclasses.replace(
        cfg,
        n_layers=args.layers * len(cfg.unit),
        d_model=args.d_model,
        n_heads=max(4, args.d_model // 64),
        n_kv_heads=max(2, args.d_model // 128),
        head_dim=64,
        d_ff=args.d_model * 4 if cfg.d_ff else 0,
        vocab_size=args.vocab,
        max_seq_len=args.seq,
        n_experts=min(8, cfg.n_experts) if cfg.n_experts else 0,
        top_k=min(2, cfg.top_k) if cfg.top_k else 0,
        moe_d_ff=args.d_model if cfg.n_experts else 0,
        n_encoder_layers=min(2, cfg.n_encoder_layers),
        n_context_tokens=0,
        reservoir_nodes=min(128, cfg.reservoir_nodes),
        microbatches=args.microbatches,
        dtype="float32",
        remat="none",
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="reservoir_lm")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=1024)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--checkpoint-dir", default="checkpoints/train")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-reduce", action="store_true",
                    help="use the full assigned config (cluster scale)")
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args(argv)

    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(name)s %(message)s")

    cfg = reduced_config(get_config(args.arch), args)
    mesh = make_production_mesh() if args.production_mesh else make_debug_mesh()
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=min(50, args.steps // 5),
                          total_steps=args.steps)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch, seed=args.seed)

    with use_mesh(mesh):
        pspecs = param_pspecs(cfg, mesh)
        state_specs = shardings_for(mesh, {
            "params": pspecs, "opt": {"m": pspecs, "v": pspecs},
            "step": jax.sharding.PartitionSpec()})
        batch_specs = shardings_for(mesh, {
            "tokens": batch_pspec(mesh),
            "labels": batch_pspec(mesh),
        })
        step_fn = jax.jit(
            lambda s, b: train_step(cfg, opt_cfg, s, b),
            in_shardings=(state_specs, batch_specs),
            out_shardings=(state_specs, None),
            donate_argnums=(0,),
        )

        def init_fn():
            return jax.jit(
                lambda k: init_train_state(cfg, k), out_shardings=state_specs
            )(jax.random.PRNGKey(args.seed))

        state_sharding = jax.tree.map(lambda s: named(mesh, s), state_specs)

        state, history, watchdog = run_training(
            step_fn=step_fn,
            init_state_fn=init_fn,
            data_cfg=data_cfg,
            loop_cfg=TrainLoopConfig(
                total_steps=args.steps,
                checkpoint_every=args.checkpoint_every,
                checkpoint_dir=args.checkpoint_dir,
            ),
            state_sharding=state_sharding,
        )

    first = [h["loss"] for h in history[:5]]
    last = [h["loss"] for h in history[-5:]]
    print(f"arch={cfg.name} steps={len(history)} "
          f"loss {sum(first)/len(first):.4f} -> {sum(last)/len(last):.4f} "
          f"stragglers={len(watchdog.flagged)}")
    return history


if __name__ == "__main__":
    main()
