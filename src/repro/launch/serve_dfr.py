"""Online-learning DFR serving loop: continuous batching over live sessions.

The DFR analogue of ``launch/serve.py``'s prefill/decode server: requests
are *streams* (e.g. one user's drifting channel-equalization link), the
per-slot KV cache is the ``SessionState`` row (reservoir carry + running
Gram statistics + current readout), and the decode step is ``session_step``
— ONE reservoir pass per ``chunk_k``-period tick shared by prediction (with
the readout solved from earlier data) and the RLS Gram fold.  Continuous
batching: streams arrive mid-flight, get packed into free slots by resetting
that row in-graph (``reset`` is a traced operand — no recompile, no host
state surgery), and retire when consumed.  The readout refresh happens
in-graph on every ``refresh_every``-th tick, so exactly two step programs
exist (fold-only / fold+solve) and no tick ever materialises a full-stream
[B, T, N] state tensor (jaxpr-gated in tests/test_serving.py).

Robust serving (DESIGN.md §12) adds three host-side layers around the
in-graph health guard:

* **Ingest validation** — non-finite host samples never reach the device:
  a tick whose chunk carries NaN/Inf is *dropped* (fed as zeros with
  ``n_valid = 0``, so nothing folds) and counted; finite samples outside
  ``ingest_range`` are clamped and counted.  Counters surface in
  :meth:`DFRServer.stats`.
* **Dead-slot eviction** — a stream whose slot keeps tripping the in-graph
  quarantine (``SessionState.poison`` ≥ ``max_poison``) is evicted to
  ``server.evicted`` instead of burning its slot forever.
* **Crash recovery** — with a ``checkpoint_dir`` the server snapshots the
  session slab *plus all host queue metadata* (in-flight request bytes,
  consumption offsets, emitted predictions, counters) through
  ``CheckpointStore`` every ``checkpoint_every`` ticks (atomic, integrity
  checked, async).  :meth:`DFRServer.restore` resumes mid-stream and the
  resumed run is **bit-exact**: the slab round-trips through ``.npy``
  losslessly, request bytes round-trip base64, the refresh cadence is a
  pure function of the restored tick, and injected faults replay from
  ``fold_in(seed, tick)``.  Only wall-clock metrics (latencies) are
  best-effort across a crash.

Example:

  PYTHONPATH=src python -m repro.launch.serve_dfr --requests 32 --batch 8 \
      --nodes 64 --chunk 32 --forgetting 0.99 \
      --checkpoint-dir /tmp/dfr_ckpt --checkpoint-every 16 --resume
"""

from __future__ import annotations

import argparse
import base64
import dataclasses
import json
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointStore
from repro.core import tasks
from repro.core.masking import make_mask
from repro.pipeline.session import (SessionConfig, _session_step,
                                    session_init)
from repro.robustness.faults import FaultSpec, faulty_session_step


@dataclasses.dataclass
class StreamRequest:
    """One live stream: inputs, observed targets, and consumption progress."""

    rid: int
    j: np.ndarray                  # [K] received series (reservoir input)
    y: np.ndarray                  # [K] transmitted symbols (online targets)
    pos: int = 0                   # periods consumed so far
    y_hat: list = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return self.pos >= len(self.j)


def _arr_to_json(a: np.ndarray) -> dict:
    """Lossless (bit-exact) array → JSON: raw bytes, base64."""
    a = np.ascontiguousarray(a)
    return {"b64": base64.b64encode(a.tobytes()).decode("ascii"),
            "dtype": str(a.dtype), "shape": list(a.shape)}


def _arr_from_json(d: dict) -> np.ndarray:
    a = np.frombuffer(base64.b64decode(d["b64"]), dtype=d["dtype"])
    return a.reshape(d["shape"]).copy()


def _req_to_json(req: StreamRequest) -> dict:
    return {"rid": req.rid, "pos": req.pos,
            "j": _arr_to_json(req.j), "y": _arr_to_json(req.y),
            "y_hat": [_arr_to_json(y) for y in req.y_hat]}


def _req_from_json(d: dict) -> StreamRequest:
    return StreamRequest(rid=d["rid"], pos=d["pos"],
                         j=_arr_from_json(d["j"]), y=_arr_from_json(d["y"]),
                         y_hat=[_arr_from_json(y) for y in d["y_hat"]])


class DFRServer:
    """Fixed-slot continuous-batching server over one jitted session step.

    ``batch`` slots share one ``SessionState`` slab; the step function is
    jitted once per (cfg, refresh) with the slab donated, so steady-state
    ticks update it in place.  Idle slots tick along on zero input with
    ``n_valid = 0`` (nothing folds into their Gram) until a request lands.

    ``fault_spec`` (a traced :class:`~repro.robustness.faults.FaultSpec`)
    swaps the tick for the fault-injecting wrapper — same two compiled
    variants, used by the chaos soak to attack a live server.
    """

    def __init__(self, cfg: SessionConfig, batch: int, *, mask_seed: int = 0,
                 checkpoint_dir: str | None = None,
                 checkpoint_every: int = 0, checkpoint_keep: int = 3,
                 max_poison: int = 0,
                 ingest_range: tuple[float, float] | None = None,
                 fault_spec: FaultSpec | None = None, fault_seed: int = 0):
        self.cfg = cfg
        self.batch = batch
        self.mask = jnp.asarray(make_mask(cfg.n_nodes, seed=mask_seed))
        self.state = session_init(cfg, batch)
        self.slots: list[StreamRequest | None] = [None] * batch
        self.queue: deque[StreamRequest] = deque()
        self.tick = 0
        self.tick_seconds: list[float] = []
        self.completed: list[StreamRequest] = []
        self.evicted: list[StreamRequest] = []
        self.max_poison = max_poison
        self.ingest_range = ingest_range
        self.fault_spec = fault_spec
        self.fault_seed = fault_seed
        self.counters = {"dropped_ticks": 0, "dropped_values": 0,
                         "clamped_values": 0, "quarantine_events": 0,
                         "evictions": 0, "checkpoints_saved": 0}
        self.restored_from: int | None = None
        self.checkpoint_every = checkpoint_every
        self.store = (CheckpointStore(checkpoint_dir, keep=checkpoint_keep)
                      if checkpoint_dir else None)
        if fault_spec is None:
            self._step = jax.jit(_session_step,
                                 static_argnames=("cfg", "refresh"),
                                 donate_argnums=(2,))
        else:
            self._step = jax.jit(faulty_session_step,
                                 static_argnames=("cfg", "seed", "refresh"),
                                 donate_argnums=(3,))

    def submit(self, req: StreamRequest) -> None:
        self.queue.append(req)

    @property
    def active(self) -> int:
        return sum(s is not None for s in self.slots)

    def _run_step(self, jc, yc, *, refresh, n_valid, reset):
        if self.fault_spec is None:
            return self._step(self.cfg, self.mask, self.state, jc, yc,
                              refresh=refresh, n_valid=n_valid, reset=reset)
        return self._step(self.cfg, self.mask, self.fault_spec, self.state,
                          jc, yc, self.tick, seed=self.fault_seed,
                          refresh=refresh, n_valid=n_valid, reset=reset)

    def warmup(self) -> None:
        """Compile both step variants before timing (compile ≠ latency)."""
        ck = self.cfg.chunk_k
        z = jnp.zeros((self.batch, ck), jnp.float32)
        nv = jnp.zeros((self.batch,), jnp.int32)
        rs = jnp.zeros((self.batch,), bool)
        for refresh in (False, True):
            _, self.state = self._run_step(z, z, refresh=refresh,
                                           n_valid=nv, reset=rs)
        jax.block_until_ready(self.state.w)
        # the warmup state was donated-through; rebuild a fresh slab
        self.state = session_init(self.cfg, self.batch)

    def _sanitize(self, raw_j: np.ndarray, raw_y: np.ndarray):
        """Ingest validation for one slot's chunk (DESIGN.md §12).

        Returns (j, y, n_used) — non-finite samples anywhere in the chunk
        drop the *tick* (zero drive, ``n_used = 0`` so nothing folds and
        the stream still advances past the bad region); finite samples
        outside ``ingest_range`` are clamped in place.
        """
        bad = (~np.isfinite(raw_j)) | (~np.isfinite(raw_y))
        if bad.any():
            self.counters["dropped_ticks"] += 1
            self.counters["dropped_values"] += int(bad.sum())
            return (np.zeros_like(raw_j), np.zeros_like(raw_y), 0)
        if self.ingest_range is not None:
            lo, hi = self.ingest_range
            oob = (raw_j < lo) | (raw_j > hi)
            if oob.any():
                self.counters["clamped_values"] += int(oob.sum())
                raw_j = np.clip(raw_j, lo, hi)
        return raw_j, raw_y, len(raw_j)

    def step(self) -> None:
        """One serving tick: pack arrivals, run the step, retire finished."""
        ck = self.cfg.chunk_k
        reset = np.zeros((self.batch,), bool)
        for i in range(self.batch):
            if self.slots[i] is None and self.queue:
                self.slots[i] = self.queue.popleft()
                reset[i] = True
        jc = np.zeros((self.batch, ck), np.float32)
        yc = np.zeros((self.batch, ck), np.float32)
        nv = np.zeros((self.batch,), np.int32)
        served: list[tuple[int, StreamRequest, int]] = []
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            lo, hi = req.pos, min(req.pos + ck, len(req.j))
            sj, sy, n_used = self._sanitize(req.j[lo:hi], req.y[lo:hi])
            jc[i, : hi - lo] = sj
            yc[i, : hi - lo] = sy
            nv[i] = n_used
            served.append((i, req, hi - lo))
            req.pos = hi
        refresh = (self.tick % self.cfg.refresh_every) == 0

        t0 = time.perf_counter()
        y_hat, self.state = self._run_step(
            jnp.asarray(jc), jnp.asarray(yc),
            refresh=refresh, n_valid=jnp.asarray(nv), reset=jnp.asarray(reset))
        y_hat = jax.block_until_ready(y_hat)
        self.tick_seconds.append(time.perf_counter() - t0)

        yh = np.asarray(y_hat)[..., 0]
        for i, req, n_used in served:
            req.y_hat.append(yh[i, :n_used])
            if req.done:
                self.completed.append(req)
                self.slots[i] = None
        self.tick += 1

        # health bookkeeping + dead-slot eviction (the in-graph guard
        # already reset the row; the host decides whether the stream keeps
        # its slot).  ``quarantined`` flags THIS tick's events only.
        if self.cfg.guard:
            q, poison = jax.device_get((self.state.quarantined,
                                        self.state.poison))
            self.counters["quarantine_events"] += int(q.sum())
            if self.max_poison:
                for i, req in enumerate(self.slots):
                    if req is not None and int(poison[i]) >= self.max_poison:
                        self.counters["evictions"] += 1
                        self.evicted.append(req)
                        self.slots[i] = None

        if (self.store is not None and self.checkpoint_every
                and self.tick % self.checkpoint_every == 0):
            self.save_checkpoint()

    # -- crash recovery --------------------------------------------------------
    def _meta_blob(self) -> np.ndarray:
        meta = {
            "tick": self.tick,
            "counters": self.counters,
            "slots": [None if r is None else _req_to_json(r)
                      for r in self.slots],
            "queue": [_req_to_json(r) for r in self.queue],
            "completed": [_req_to_json(r) for r in self.completed],
            "evicted": [_req_to_json(r) for r in self.evicted],
        }
        return np.frombuffer(json.dumps(meta).encode("utf-8"), np.uint8)

    def snapshot_tree(self) -> dict:
        """The checkpoint pytree: the device slab + one host-metadata leaf.

        Fixed two-leaf-group structure (``CheckpointStore.restore`` matches
        treedefs, not shapes), so any server with the same ``SessionState``
        arity can restore it.
        """
        return {"meta": self._meta_blob(), "slab": self.state}

    def save_checkpoint(self) -> None:
        """Atomic async snapshot at the current tick (DESIGN.md §3/§12)."""
        assert self.store is not None, "no checkpoint_dir configured"
        # count first so the snapshot includes itself — a resumed server's
        # counter then matches the uninterrupted run's
        self.counters["checkpoints_saved"] += 1
        self.store.save_async(self.tick, self.snapshot_tree())

    def restore(self, *, step: int | None = None) -> int | None:
        """Resume from the newest intact checkpoint; returns its tick.

        Integrity failures (torn write, bit rot) fall back to the previous
        checkpoint inside ``CheckpointStore.restore``.  Everything the
        resumed ticks consume is restored bit-exactly; returns ``None`` (and
        leaves the server untouched) when nothing restorable exists.
        """
        assert self.store is not None, "no checkpoint_dir configured"
        template = {"meta": np.zeros((0,), np.uint8),
                    "slab": session_init(self.cfg, self.batch)}
        got_step, tree = self.store.restore(template, step=step)
        if got_step is None:
            return None
        self.state = jax.tree_util.tree_map(jnp.asarray, tree["slab"])
        meta = json.loads(np.asarray(tree["meta"]).tobytes().decode("utf-8"))
        self.tick = int(meta["tick"])
        self.counters = dict(meta["counters"])
        self.slots = [None if r is None else _req_from_json(r)
                      for r in meta["slots"]]
        self.queue = deque(_req_from_json(r) for r in meta["queue"])
        self.completed = [_req_from_json(r) for r in meta["completed"]]
        self.evicted = [_req_from_json(r) for r in meta["evicted"]]
        self.restored_from = got_step
        return got_step

    def close(self) -> None:
        """Flush any in-flight async checkpoint write."""
        if self.store is not None:
            self.store.wait()

    # -- observability ---------------------------------------------------------
    def stats(self) -> dict:
        """Health / progress counters for dashboards and the chaos soak."""
        return {
            "tick": self.tick,
            "active": self.active,
            "queued": len(self.queue),
            "completed": len(self.completed),
            "evicted": len(self.evicted),
            "restored_from": self.restored_from,
            **self.counters,
        }

    def drain(self, max_ticks: int = 100_000) -> None:
        while (self.queue or self.active) and self.tick < max_ticks:
            self.step()
        self.close()


def _latency_quantiles(seconds: list[float]):
    if not seconds:  # e.g. resumed from an already-drained checkpoint
        return float("nan"), float("nan")
    us = np.asarray(seconds) * 1e6
    return float(np.percentile(us, 50)), float(np.percentile(us, 99))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--stream-len", type=int, default=512)
    ap.add_argument("--nodes", type=int, default=64)
    ap.add_argument("--washout", type=int, default=32)
    ap.add_argument("--chunk", type=int, default=32)
    ap.add_argument("--forgetting", type=float, default=0.99)
    ap.add_argument("--refresh-every", type=int, default=4)
    ap.add_argument("--snr-db", type=float, default=24.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint-dir", type=str, default=None)
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="snapshot the server every N ticks (0 = off)")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the newest intact checkpoint")
    ap.add_argument("--max-poison", type=int, default=0,
                    help="evict a stream after N quarantine events (0 = never)")
    ap.add_argument("--ingest-range", type=float, nargs=2, default=None,
                    metavar=("LO", "HI"),
                    help="clamp finite host inputs to [LO, HI] at ingest")
    args = ap.parse_args(argv)

    cfg = SessionConfig(n_nodes=args.nodes, washout=args.washout,
                        chunk_k=args.chunk, forgetting=args.forgetting,
                        refresh_every=args.refresh_every,
                        ridge_l2=(1e-8, 1e-6, 1e-4), state_method="fast")
    server = DFRServer(cfg, args.batch, mask_seed=args.seed,
                       checkpoint_dir=args.checkpoint_dir,
                       checkpoint_every=args.checkpoint_every,
                       max_poison=args.max_poison,
                       ingest_range=(tuple(args.ingest_range)
                                     if args.ingest_range else None))
    server.warmup()
    if args.resume and server.store is not None:
        got = server.restore()
        if got is not None:
            print(f"resumed from checkpoint tick={got}")

    # requests: independent channel-equalization streams (one link each),
    # lengths padded to whole chunks so the per-session washout counter
    # tracks real periods exactly.  Same input layer as the Experiment
    # pipeline: per-stream affine map to [0, 1] — the masked drive of the
    # silicon MR is an optical intensity and cannot go negative.
    if server.restored_from is None:
        k = (args.stream_len // args.chunk) * args.chunk
        for r in range(args.requests):
            ds = tasks.channel_equalization(
                max(k, 64), snr_db=args.snr_db, train_frac=0.999,
                seed=args.seed + r)
            x = np.asarray(ds.inputs_train[:k], np.float32)
            x = (x - x.min()) / (x.max() - x.min() + 1e-12)
            server.submit(StreamRequest(
                rid=r, j=x, y=np.asarray(ds.targets_train[:k], np.float32)))

    t0 = time.perf_counter()
    server.drain()
    wall = time.perf_counter() - t0

    # online quality: post-washout symbol error per completed stream, plus
    # the steady-state (last-quarter) error once the readout has converged —
    # the overall number includes the unavoidable cold-start misses made
    # while the Gram was still filling
    sers, sers_tail = [], []
    sym = np.asarray(tasks.SYMBOLS, np.float32)
    for req in server.completed:
        yh = np.concatenate(req.y_hat)[args.washout:]
        yt = req.y[args.washout:len(req.j)]
        dec = sym[np.argmin(np.abs(yh[:, None] - sym[None, :]), axis=1)]
        sers.append(float(np.mean(dec != yt)))
        q = len(dec) // 4
        sers_tail.append(float(np.mean(dec[-q:] != yt[-q:])))
    p50, p99 = _latency_quantiles(server.tick_seconds)
    streams_per_s = len(server.completed) / max(wall, 1e-9)
    periods_per_s = sum(len(r.j) for r in server.completed) / max(wall, 1e-9)
    print(f"batch={args.batch} requests={len(server.completed)} "
          f"ticks={server.tick} wall={wall*1e3:.1f}ms "
          f"({streams_per_s:.1f} streams/s, {periods_per_s:.0f} periods/s) "
          f"tick p50={p50:.0f}us p99={p99:.0f}us "
          f"online-SER={np.mean(sers) if sers else float('nan'):.4f} "
          f"steady-SER={np.mean(sers_tail) if sers_tail else float('nan'):.4f} "
          f"stats={json.dumps(server.stats())}")
    return server


if __name__ == "__main__":
    main()
