"""Online-learning DFR serving loop: continuous batching over live sessions.

The DFR analogue of ``launch/serve.py``'s prefill/decode server: requests
are *streams* (e.g. one user's drifting channel-equalization link), the
per-slot KV cache is the ``SessionState`` row (reservoir carry + running
Gram statistics + current readout), and the decode step is ``session_step``
— ONE reservoir pass per ``chunk_k``-period tick shared by prediction (with
the readout solved from earlier data) and the RLS Gram fold.  Continuous
batching: streams arrive mid-flight, get packed into free slots by resetting
that row in-graph (``reset`` is a traced operand — no recompile, no host
state surgery), and retire when consumed.  The readout refresh happens
in-graph on every ``refresh_every``-th tick, so exactly two step programs
exist (fold-only / fold+solve) and no tick ever materialises a full-stream
[B, T, N] state tensor (jaxpr-gated in tests/test_serving.py).  Example:

  PYTHONPATH=src python -m repro.launch.serve_dfr --requests 32 --batch 8 \
      --nodes 64 --chunk 32 --forgetting 0.99
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tasks
from repro.core.masking import make_mask
from repro.pipeline.session import (SessionConfig, _session_step,
                                    session_init)


@dataclasses.dataclass
class StreamRequest:
    """One live stream: inputs, observed targets, and consumption progress."""

    rid: int
    j: np.ndarray                  # [K] received series (reservoir input)
    y: np.ndarray                  # [K] transmitted symbols (online targets)
    pos: int = 0                   # periods consumed so far
    y_hat: list = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return self.pos >= len(self.j)


class DFRServer:
    """Fixed-slot continuous-batching server over one jitted session step.

    ``batch`` slots share one ``SessionState`` slab; the step function is
    jitted once per (cfg, refresh) with the slab donated, so steady-state
    ticks update it in place.  Idle slots tick along on zero input with
    ``n_valid = 0`` (nothing folds into their Gram) until a request lands.
    """

    def __init__(self, cfg: SessionConfig, batch: int, *, mask_seed: int = 0):
        self.cfg = cfg
        self.batch = batch
        self.mask = jnp.asarray(make_mask(cfg.n_nodes, seed=mask_seed))
        self.state = session_init(cfg, batch)
        self.slots: list[StreamRequest | None] = [None] * batch
        self.queue: deque[StreamRequest] = deque()
        self.tick = 0
        self.tick_seconds: list[float] = []
        self.completed: list[StreamRequest] = []
        self._step = jax.jit(_session_step,
                             static_argnames=("cfg", "refresh"),
                             donate_argnums=(2,))

    def submit(self, req: StreamRequest) -> None:
        self.queue.append(req)

    @property
    def active(self) -> int:
        return sum(s is not None for s in self.slots)

    def warmup(self) -> None:
        """Compile both step variants before timing (compile ≠ latency)."""
        ck = self.cfg.chunk_k
        z = jnp.zeros((self.batch, ck), jnp.float32)
        nv = jnp.zeros((self.batch,), jnp.int32)
        rs = jnp.zeros((self.batch,), bool)
        st = self.state
        for refresh in (False, True):
            _, st = self._step(self.cfg, self.mask, st, z, z,
                               refresh=refresh, n_valid=nv, reset=rs)
        jax.block_until_ready(st.w)
        # the warmup state was donated-through; rebuild a fresh slab
        self.state = session_init(self.cfg, self.batch)

    def step(self) -> None:
        """One serving tick: pack arrivals, run the step, retire finished."""
        ck = self.cfg.chunk_k
        reset = np.zeros((self.batch,), bool)
        for i in range(self.batch):
            if self.slots[i] is None and self.queue:
                self.slots[i] = self.queue.popleft()
                reset[i] = True
        jc = np.zeros((self.batch, ck), np.float32)
        yc = np.zeros((self.batch, ck), np.float32)
        nv = np.zeros((self.batch,), np.int32)
        served: list[tuple[int, StreamRequest, int]] = []
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            lo, hi = req.pos, min(req.pos + ck, len(req.j))
            jc[i, : hi - lo] = req.j[lo:hi]
            yc[i, : hi - lo] = req.y[lo:hi]
            nv[i] = hi - lo
            served.append((i, req, hi - lo))
            req.pos = hi
        refresh = (self.tick % self.cfg.refresh_every) == 0

        t0 = time.perf_counter()
        y_hat, self.state = self._step(
            self.cfg, self.mask, self.state, jnp.asarray(jc), jnp.asarray(yc),
            refresh=refresh, n_valid=jnp.asarray(nv), reset=jnp.asarray(reset))
        y_hat = jax.block_until_ready(y_hat)
        self.tick_seconds.append(time.perf_counter() - t0)

        yh = np.asarray(y_hat)[..., 0]
        for i, req, n_used in served:
            req.y_hat.append(yh[i, :n_used])
            if req.done:
                self.completed.append(req)
                self.slots[i] = None
        self.tick += 1

    def drain(self, max_ticks: int = 100_000) -> None:
        while (self.queue or self.active) and self.tick < max_ticks:
            self.step()


def _latency_quantiles(seconds: list[float]):
    us = np.asarray(seconds) * 1e6
    return float(np.percentile(us, 50)), float(np.percentile(us, 99))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--stream-len", type=int, default=512)
    ap.add_argument("--nodes", type=int, default=64)
    ap.add_argument("--washout", type=int, default=32)
    ap.add_argument("--chunk", type=int, default=32)
    ap.add_argument("--forgetting", type=float, default=0.99)
    ap.add_argument("--refresh-every", type=int, default=4)
    ap.add_argument("--snr-db", type=float, default=24.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = SessionConfig(n_nodes=args.nodes, washout=args.washout,
                        chunk_k=args.chunk, forgetting=args.forgetting,
                        refresh_every=args.refresh_every,
                        ridge_l2=(1e-8, 1e-6, 1e-4), state_method="fast")
    server = DFRServer(cfg, args.batch, mask_seed=args.seed)
    server.warmup()

    # requests: independent channel-equalization streams (one link each),
    # lengths padded to whole chunks so the per-session washout counter
    # tracks real periods exactly.  Same input layer as the Experiment
    # pipeline: per-stream affine map to [0, 1] — the masked drive of the
    # silicon MR is an optical intensity and cannot go negative.
    k = (args.stream_len // args.chunk) * args.chunk
    for r in range(args.requests):
        ds = tasks.channel_equalization(
            max(k, 64), snr_db=args.snr_db, train_frac=0.999, seed=args.seed + r)
        x = np.asarray(ds.inputs_train[:k], np.float32)
        x = (x - x.min()) / (x.max() - x.min() + 1e-12)
        server.submit(StreamRequest(
            rid=r, j=x, y=np.asarray(ds.targets_train[:k], np.float32)))

    t0 = time.perf_counter()
    server.drain()
    wall = time.perf_counter() - t0

    # online quality: post-washout symbol error per completed stream, plus
    # the steady-state (last-quarter) error once the readout has converged —
    # the overall number includes the unavoidable cold-start misses made
    # while the Gram was still filling
    sers, sers_tail = [], []
    sym = np.asarray(tasks.SYMBOLS, np.float32)
    for req in server.completed:
        yh = np.concatenate(req.y_hat)[args.washout:]
        yt = req.y[args.washout:len(req.j)]
        dec = sym[np.argmin(np.abs(yh[:, None] - sym[None, :]), axis=1)]
        sers.append(float(np.mean(dec != yt)))
        q = len(dec) // 4
        sers_tail.append(float(np.mean(dec[-q:] != yt[-q:])))
    p50, p99 = _latency_quantiles(server.tick_seconds)
    streams_per_s = len(server.completed) / max(wall, 1e-9)
    periods_per_s = sum(len(r.j) for r in server.completed) / max(wall, 1e-9)
    print(f"batch={args.batch} requests={len(server.completed)} "
          f"ticks={server.tick} wall={wall*1e3:.1f}ms "
          f"({streams_per_s:.1f} streams/s, {periods_per_s:.0f} periods/s) "
          f"tick p50={p50:.0f}us p99={p99:.0f}us "
          f"online-SER={np.mean(sers):.4f} "
          f"steady-SER={np.mean(sers_tail):.4f}")
    return server


if __name__ == "__main__":
    main()
