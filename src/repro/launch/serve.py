"""Serving launcher: batched prefill + decode loop with continuous batching.

A miniature production server loop: requests arrive with different prompt
lengths, get left-padded into a batch, prefilled once, then decoded
token-by-token with the batch's KV cache donated between steps (no
reallocation).  Example:

  PYTHONPATH=src python -m repro.launch.serve --arch granite-8b \
      --requests 8 --new-tokens 32
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import use_mesh
from repro.configs import get_config
from repro.launch.mesh import make_debug_mesh
from repro.models import init_params
from repro.runtime.steps import serve_decode, serve_prefill


def reduced_config(cfg, d_model=128, layers=2, vocab=512):
    return dataclasses.replace(
        cfg,
        n_layers=layers * len(cfg.unit),
        d_model=d_model,
        n_heads=4,
        n_kv_heads=2 if cfg.n_kv_heads != cfg.n_heads else 4,
        head_dim=32,
        d_ff=d_model * 4 if cfg.d_ff else 0,
        vocab_size=vocab,
        max_seq_len=4096,
        n_experts=min(8, cfg.n_experts) if cfg.n_experts else 0,
        top_k=min(2, cfg.top_k) if cfg.top_k else 0,
        moe_d_ff=d_model if cfg.n_experts else 0,
        n_encoder_layers=min(2, cfg.n_encoder_layers),
        n_context_tokens=8 if cfg.n_context_tokens else 0,
        d_context=0,
        reservoir_nodes=32,
        dtype="float32",
        remat="none",
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = reduced_config(get_config(args.arch))
    mesh = make_debug_mesh()
    rng = np.random.default_rng(args.seed)
    key = jax.random.PRNGKey(args.seed)

    with use_mesh(mesh):
        params = init_params(cfg, key)
        b = args.requests
        max_len = args.prompt_len + args.new_tokens
        prompts = rng.integers(0, cfg.vocab_size, size=(b, args.prompt_len)).astype(np.int32)
        ctx = (
            jnp.asarray(rng.standard_normal((b, cfg.n_context_tokens, cfg.d_model)), jnp.float32)
            if cfg.n_context_tokens else None
        )

        prefill_fn = jax.jit(
            lambda p, t, c=None: serve_prefill(cfg, p, t, c, max_len=max_len)
        )
        decode_fn = jax.jit(
            lambda p, cache, t: serve_decode(cfg, p, cache, t),
            donate_argnums=(1,),
        )

        t0 = time.time()
        logits, cache = prefill_fn(params, jnp.asarray(prompts), ctx)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        generated = [tok]
        t_prefill = time.time() - t0

        t0 = time.time()
        for _ in range(args.new_tokens - 1):
            logits, cache = decode_fn(params, cache, tok)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            generated.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.time() - t0

    out = np.concatenate([np.asarray(t) for t in generated], axis=1)
    tps = b * (args.new_tokens - 1) / max(t_decode, 1e-9)
    print(f"arch={cfg.name} batch={b} prefill={t_prefill*1e3:.1f}ms "
          f"decode={t_decode*1e3:.1f}ms ({tps:.1f} tok/s) "
          f"sample={out[0, :12].tolist()}")
    return out


if __name__ == "__main__":
    main()
