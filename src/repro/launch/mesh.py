"""Production meshes (assignment spec).

Defined as functions, not module constants, so importing this module never
touches jax device state.  TPU v5e class constants for the roofline live in
benchmarks/roofline.py.
"""

from __future__ import annotations

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod; multi-pod adds a leading 2-pod axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_debug_mesh(n_devices: int | None = None):
    """Small host mesh for tests: (1, n) data×model over available devices."""
    n = n_devices or len(jax.devices())
    return make_mesh((1, n), ("data", "model"))
