import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Must precede all other imports (jax locks device count at first init).

"""Structure-calibrated cost extraction (DESIGN.md §6).

XLA's ``cost_analysis()`` counts a ``while`` body ONCE regardless of trip
count, so a scanned-layers model under-reports FLOPs / bytes / collectives
by ~n_layers × microbatches.  Rather than unrolling the full program (which
explodes compile time), we compile cheap *variants at full tensor dims* and
solve for the per-component costs exactly:

  train:  A = opt + emb + 1·unit                 (U'=1, M'=1)
          B = opt + emb + 2·unit                 (U'=2 fully unrolled, M'=1)
          C = opt + 2·(emb + 1·unit)             (U'=1, M'=2 fully unrolled)
          -> unit = B−A;  emb = C−A−unit;  opt = A−unit−emb
          total(U, M) = opt + M·(emb + U·unit)
  serve:  A = base + 1·unit;  B = base + 2·unit
          -> unit = B−A;  total(U) = base + U·unit
  (+ an E'=2 encoder variant for enc-dec archs.)

Known residual under-counts (inner ``while`` loops inside one unit body,
counted once per body): sLSTM's sequence scan, the ReservoirMixer period
scan, and the chunked-attention KV scan.  benchmarks/roofline.py adds
documented analytic corrections for these.

Writes experiments/dryrun/calib__<arch>__<shape>__pod.json.
"""

import argparse
import dataclasses
import json
import time

import jax

from repro.configs import get_config, input_specs, list_archs, runnable_cells, SHAPES
from repro.launch.dryrun import OUT_DIR, build_step, collective_bytes
from repro.launch.mesh import make_production_mesh
from repro.compat import use_mesh


def _variant(cfg, *, units: int, microbatches: int, enc_layers: int | None = None):
    return dataclasses.replace(
        cfg,
        n_layers=units * len(cfg.unit),
        microbatches=microbatches,
        analysis_unroll=max(units, microbatches),
        n_encoder_layers=(enc_layers if enc_layers is not None else cfg.n_encoder_layers),
    )


def _resize_batch(specs, batch: int):
    """Shrink the batch dim of train/prefill input specs (not decode caches)."""
    out = {}
    for k, v in specs.items():
        if k == "cache":
            out[k] = v
        else:
            out[k] = jax.ShapeDtypeStruct((batch, *v.shape[1:]), v.dtype)
    return out


def _measure(cfg, shape, mesh, batch: int | None = None):
    specs = input_specs(cfg, shape)
    if batch is not None:
        specs = _resize_batch(specs, batch)
    with use_mesh(mesh):
        fn, args = build_step(cfg, shape, mesh, specs=specs)
        compiled = fn.lower(*args).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(coll["total"]),
    }


def _sub(a, b):
    return {k: max(0.0, a[k] - b[k]) for k in a}


def calibrate_cell(arch: str, shape: str, *, force: bool = False,
                   overrides: dict | None = None, tag: str = "") -> dict:
    from repro.launch.dryrun import apply_overrides

    OUT_DIR.mkdir(parents=True, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    out_path = OUT_DIR / f"calib__{arch}__{shape}__pod{suffix}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg = apply_overrides(get_config(arch), overrides)
    mesh = make_production_mesh()
    kind = SHAPES[shape]["kind"]
    enc = cfg.n_encoder_layers

    # Train variants run at the *microbatch* batch size, so the measured
    # per-unit / per-embedding costs are exactly one microbatch's worth.
    b_mb = None
    if kind == "train":
        b_mb = SHAPES[shape]["batch"] // cfg.microbatches

    a = _measure(_variant(cfg, units=1, microbatches=1, enc_layers=min(1, enc)),
                 shape, mesh, batch=b_mb)
    b = _measure(_variant(cfg, units=2, microbatches=1, enc_layers=min(1, enc)),
                 shape, mesh, batch=b_mb)
    unit = _sub(b, a)

    rec = {"arch": arch, "shape": shape, "unit": unit, "n_units": cfg.n_units}
    if kind == "train":
        c = _measure(_variant(cfg, units=1, microbatches=2, enc_layers=min(1, enc)),
                     shape, mesh, batch=2 * b_mb)
        emb = _sub(_sub(c, a), unit)
        opt = _sub(_sub(a, unit), emb)
        rec.update({"emb": emb, "opt": opt, "microbatches": cfg.microbatches})
        total = {k: opt[k] + cfg.microbatches * (emb[k] + cfg.n_units * unit[k]) for k in unit}
    else:
        base = _sub(a, unit)
        rec["base"] = base
        total = {k: base[k] + cfg.n_units * unit[k] for k in unit}

    if enc:
        d = _measure(_variant(cfg, units=1, microbatches=1, enc_layers=2),
                     shape, mesh, batch=b_mb)
        enc_unit = _sub(d, a)
        rec["enc_unit"] = enc_unit
        mult = cfg.microbatches if kind == "train" else 1
        for k in total:
            total[k] += mult * (enc - 1) * enc_unit[k]

    rec["total"] = total
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--set", action="append", default=[])
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    overrides = dict(kv.split("=", 1) for kv in args.set)

    cells = []
    if args.all:
        for arch in list_archs(include_extras=True):
            for shape in runnable_cells(arch):
                cells.append((arch, shape))
    else:
        cells.append((args.arch, args.shape))

    for arch, shape in cells:
        t0 = time.time()
        try:
            rec = calibrate_cell(arch, shape, force=args.force,
                                 overrides=overrides, tag=args.tag)
            msg = f"ok flops={rec['total']['flops']:.3e} coll={rec['total']['coll']:.3e}B"
        except Exception as e:  # noqa: BLE001
            msg = f"FAIL {type(e).__name__}: {e}"
        print(f"[{time.time()-t0:7.1f}s] calib {arch:24s} {shape:12s} {msg}", flush=True)


if __name__ == "__main__":
    main()
