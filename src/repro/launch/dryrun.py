import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  Everything below is ordinary code.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this script:
  1. builds the production mesh (16×16, or 2×16×16 multi-pod),
  2. constructs ShapeDtypeStruct stand-ins for every input (no allocation),
  3. ``jax.jit(step, in_shardings, out_shardings).lower(...).compile()``,
  4. records ``memory_analysis()`` (proves the cell fits HBM),
     ``cost_analysis()`` (FLOPs / bytes for §Roofline), and a parse of the
     compiled HLO summing collective operand bytes,
  5. writes ``experiments/dryrun/<arch>__<shape>__<mesh>.json``.

Usage:
  python -m repro.launch.dryrun --arch granite-8b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all            # every runnable cell
  python -m repro.launch.dryrun --all --mesh multipod
"""

import argparse
import json
import pathlib
import re
import time

import jax

from repro.configs import (SHAPES, get_config, input_specs, list_archs,
                           runnable_cells)
from repro.launch.mesh import make_production_mesh
from repro.optim import AdamWConfig
from repro.parallel.sharding import (
    batch_pspec,
    cache_pspecs,
    data_pspecs,
    param_pspecs,
)
from repro.runtime.steps import serve_decode, serve_prefill, train_step
from repro.compat import shardings_for, use_mesh

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLL_RE = re.compile(
    r"(\w+\[[\d,]*\](?:\{[^}]*\})?|\((?:[^()]*)\))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.I)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device wire bytes by collective kind (ring-algorithm estimate).

    Result-shape convention: for a collective whose HLO result shape is r
    over a group of size n —
      all-reduce          2·r·(n−1)/n      (reduce-scatter + all-gather ring)
      all-gather          r·(n−1)/n        (each device receives r − its shard)
      reduce-scatter      r·(n−1)          (operand = r·n, sends (n−1) shards)
      all-to-all          r·(n−1)/n
      collective-permute  r
    """
    out: dict[str, float] = {}
    count: dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_txt, kind = m.group(1), m.group(2).lower()
        r = _shape_bytes(shape_txt)
        line_end = hlo_text.find("\n", m.end())
        line = hlo_text[m.end(): line_end if line_end > 0 else m.end() + 2000]
        g = _GROUPS_RE.search(line)
        n = len(g.group(1).split(",")) if g else 2
        if kind == "all-reduce":
            wire = 2.0 * r * (n - 1) / n
        elif kind == "all-gather":
            wire = r * (n - 1) / n
        elif kind == "reduce-scatter":
            wire = float(r) * (n - 1)
        elif kind == "all-to-all":
            wire = r * (n - 1) / n
        else:  # collective-permute
            wire = float(r)
        out[kind] = out.get(kind, 0.0) + wire
        count[kind] = count.get(kind, 0) + 1
    out["total"] = sum(out.values())
    out["counts"] = count
    return out


def build_step(cfg, shape: str, mesh, specs=None):
    """Returns (jitted step fn, kwargs of ShapeDtypeStructs).

    ``specs`` overrides the assignment-shape input specs (used by the
    calibration variants, which lower at microbatch-sized batches)."""
    kind = SHAPES[shape]["kind"]
    if specs is None:
        specs = input_specs(cfg, shape)

    if kind == "train":
        opt_cfg = AdamWConfig()
        state_shapes = jax.eval_shape(
            lambda: __import__("repro.runtime.steps",
                               fromlist=["init_train_state"]).init_train_state(
                cfg, jax.random.PRNGKey(0)
            )
        )
        pspecs = param_pspecs(cfg, mesh)
        state_specs = {
            "params": pspecs,
            "opt": {"m": pspecs, "v": pspecs},
            "step": jax.sharding.PartitionSpec(),
        }
        batch_specs = data_pspecs(cfg, mesh, specs)

        def step(state, batch):
            return train_step(cfg, opt_cfg, state, batch)

        fn = jax.jit(
            step,
            in_shardings=shardings_for(mesh, (state_specs, batch_specs)),
            out_shardings=shardings_for(mesh, (state_specs, None)),
            donate_argnums=(0,),
        )
        args = ({"params": state_shapes["params"], "opt": state_shapes["opt"],
                 "step": state_shapes["step"]}, specs)
        return fn, args

    pspecs = param_pspecs(cfg, mesh)
    params_shapes = jax.eval_shape(
        lambda: __import__("repro.models", fromlist=["init_params"]).init_params(
            cfg, jax.random.PRNGKey(0)
        )
    )

    if kind == "prefill":
        bspec = {k: batch_pspec(mesh, rank=len(v.shape)) for k, v in specs.items()}
        has_ctx = "context" in specs
        if has_ctx:
            cache_shapes = jax.eval_shape(
                lambda p, t, c: serve_prefill(cfg, p, t, c),
                params_shapes, specs["tokens"], specs["context"],
            )[1]
        else:
            cache_shapes = jax.eval_shape(
                lambda p, t: serve_prefill(cfg, p, t),
                params_shapes, specs["tokens"],
            )[1]
        out_cache_spec = cache_pspecs(cfg, mesh, cache_shapes)

        def step(params, tokens, context=None):
            return serve_prefill(cfg, params, tokens, context)

        in_sh = (pspecs, bspec["tokens"]) + ((bspec["context"],) if has_ctx else ())
        fn = jax.jit(step, in_shardings=shardings_for(mesh, in_sh),
                     out_shardings=shardings_for(mesh, (batch_pspec(mesh), out_cache_spec)))
        args = (params_shapes, specs["tokens"]) + ((specs["context"],) if has_ctx else ())
        return fn, args

    if kind == "decode":
        cache_spec = cache_pspecs(cfg, mesh, specs["cache"])
        tok_rank = len(specs["tokens"].shape)
        b = specs["tokens"].shape[0]
        b_total = 1
        for a in ("pod", "data"):
            if a in mesh.shape:
                b_total *= mesh.shape[a]
        tok_spec = batch_pspec(mesh, rank=tok_rank) if b % b_total == 0 else \
            jax.sharding.PartitionSpec(*([None] * tok_rank))

        def step(params, cache, tokens):
            return serve_decode(cfg, params, cache, tokens)

        fn = jax.jit(
            step,
            in_shardings=shardings_for(mesh, (pspecs, cache_spec, tok_spec)),
            out_shardings=shardings_for(mesh, (tok_spec, cache_spec)),
            donate_argnums=(1,),
        )
        args = (params_shapes, specs["cache"], specs["tokens"])
        return fn, args

    raise ValueError(kind)


def apply_overrides(cfg, overrides: dict | None):
    """dataclasses.replace with string values coerced to the field types."""
    if not overrides:
        return cfg
    import dataclasses

    fields = {f.name: f.type for f in dataclasses.fields(cfg)}
    coerced = {}
    for k, v in overrides.items():
        if k not in fields:
            raise KeyError(k)
        cur = getattr(cfg, k)
        coerced[k] = type(cur)(v) if not isinstance(v, type(cur)) else v
    return dataclasses.replace(cfg, **coerced)


def run_cell(arch: str, shape: str, mesh_name: str, *, force: bool = False,
             overrides: dict | None = None, tag: str = "") -> dict:
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    out_path = OUT_DIR / f"{arch}__{shape}__{mesh_name}{suffix}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg = apply_overrides(get_config(arch), overrides)
    mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
    t0 = time.time()
    with use_mesh(mesh):
        fn, args = build_step(cfg, shape, mesh)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll = collective_bytes(compiled.as_text())

    rec = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_name,
        "n_devices": int(mesh.size),
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "generated_code_bytes": int(getattr(mem, "generated_code_size_in_bytes", 0)),
        },
        "collectives": coll,
        "model_params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "seconds": {"lower": round(t_lower, 2), "compile": round(t_compile, 2)},
    }
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (repeatable); use with --tag")
    ap.add_argument("--tag", default="", help="suffix for the output JSON")
    args = ap.parse_args()
    overrides = dict(kv.split("=", 1) for kv in args.set)

    cells = []
    if args.all:
        for arch in list_archs(include_extras=True):
            for shape in runnable_cells(arch):
                cells.append((arch, shape))
    else:
        cells.append((args.arch, args.shape))

    for arch, shape in cells:
        t0 = time.time()
        try:
            rec = run_cell(arch, shape, args.mesh, force=args.force,
                           overrides=overrides, tag=args.tag)
            status = "ok"
            extra = (
                f"flops={rec['flops']:.3e} coll={rec['collectives']['total']:.3e}B "
                f"temp={rec['memory']['temp_bytes']/2**30:.2f}GiB"
            )
        except Exception as e:  # noqa: BLE001 — report and continue the sweep
            status, extra = "FAIL", f"{type(e).__name__}: {e}"
        print(f"[{time.time()-t0:7.1f}s] {arch:24s} {shape:12s} {args.mesh:8s} {status} {extra}",
              flush=True)


if __name__ == "__main__":
    main()
