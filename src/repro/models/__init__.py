"""Model zoo: composable transformer/SSM/MoE stacks for the assigned archs."""

from .config import BlockSpec, ModelConfig
from .losses import lm_loss
from .model import (
    decode_step,
    forward,
    init_cache,
    init_params,
    param_logical_axes,
    prefill,
)

__all__ = [
    "BlockSpec",
    "ModelConfig",
    "decode_step",
    "forward",
    "init_cache",
    "init_params",
    "lm_loss",
    "param_logical_axes",
    "prefill",
]
