"""xLSTM blocks — mLSTM (matrix memory) and sLSTM (scalar memory) mixers
[Beck et al., arXiv:2405.04517].  xlstm-1.3b stacks them 7:1.

mLSTM train path: the parallel (attention-like) form — exponential
input/forget gating builds a decay matrix D over the sequence, applied to
q·kᵀ (O(S²·d) like attention but state-free); decode is O(1) with the
(C, n, m) matrix-memory recurrence — which is what makes ``long_500k``
runnable for this family.

sLSTM: inherently sequential (recurrent R matrices, block-diagonal per
head); train runs a ``lax.scan`` over the sequence; decode is one step of
the same cell.

Per the assignment row (d_ff = 0), blocks carry their own projections and
there is no separate FFN: mLSTM up-projects by ``mlstm_expand`` before and
down-projects after mixing (pre-up-projection block), sLSTM is followed by
a gated ~4/3 projection (post-up-projection block), both per the paper.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------


def mlstm_defs(cfg) -> dict:
    d = cfg.d_model
    d_in = d * cfg.mlstm_expand
    h = cfg.n_heads
    hd = d_in // h
    return {
        "up_proj": ((d, 2 * d_in), ("embed", "mlp"), "fan_in"),
        "conv_w": ((4, d_in), (None, "mlp"), "fan_in"),
        "conv_b": ((d_in,), ("mlp",), "zeros"),
        "wq": ((d_in, h, hd), ("mlp", "heads", None), "fan_in"),
        "wk": ((d_in, h, hd), ("mlp", "heads", None), "fan_in"),
        "wv": ((d_in, h, hd), ("mlp", "heads", None), "fan_in"),
        "w_i": ((d_in, h), ("mlp", "heads"), "zeros"),
        "w_f": ((d_in, h), ("mlp", "heads"), "zeros"),
        "b_i": ((h,), ("heads",), "zeros"),
        "b_f": ((h,), ("heads",), lambda _k, s: jnp.full(s, 3.0)),  # open forget gates
        "skip_scale": ((d_in,), ("mlp",), "ones"),
        "out_norm": ((d_in,), ("mlp",), "zeros"),
        "down_proj": ((d_in, d), ("mlp", "embed"), "fan_in"),
    }


def _mlstm_gates(p, xc):
    """log input / forget gate pre-activations, f32.  xc [B,S,d_in]."""
    x32 = xc.astype(jnp.float32)
    i_pre = x32 @ p["w_i"] + p["b_i"]          # [B,S,H]
    f_pre = x32 @ p["w_f"] + p["b_f"]
    log_f = -jax.nn.softplus(-f_pre)           # log sigmoid(f)
    return i_pre, log_f


def _mlstm_qkv(p, xc, dt):
    q = jnp.einsum("bsd,dhk->bshk", xc, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", xc, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", xc, p["wv"].astype(dt))
    return q, k / math.sqrt(q.shape[-1]), v


def _causal_conv4(p, x):
    kw = p["conv_w"].shape[0]
    pad = jnp.zeros((x.shape[0], kw - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(
        xp[:, i : i + x.shape[1], :] * p["conv_w"][i].astype(x.dtype) for i in range(kw)
    )
    return jax.nn.silu(out + p["conv_b"].astype(x.dtype))


def apply_mlstm(cfg, p, x, *, cache=None):
    """x [B,S,d].  cache=(conv_state, C [B,H,hd,hd], n [B,H,hd], m [B,H])."""
    from .layers import rmsnorm

    dt = x.dtype
    d_in = cfg.d_model * cfg.mlstm_expand
    xz = x @ p["up_proj"].astype(dt)
    xr, z = jnp.split(xz, 2, axis=-1)

    if cache is None or x.shape[1] > 1:
        # Parallel (attention-like) form: train, and prefill from a fresh
        # cache (prefill always starts from zero state in the serving flow).
        xc = _causal_conv4(p, xr)
        q, k, v = _mlstm_qkv(p, xc, dt)
        i_pre, log_f = _mlstm_gates(p, xc)
        # D matrix: d[t,s] = exp(Σ_{r=s+1..t} log_f_r + i_s − m_t), s ≤ t
        cum_f = jnp.cumsum(log_f, axis=1)                     # [B,S,H]
        lse = cum_f[:, :, None, :] - cum_f[:, None, :, :] + i_pre[:, None, :, :]
        mask = jnp.tril(jnp.ones((x.shape[1], x.shape[1]), bool))
        lse = jnp.where(mask[None, :, :, None], lse, -jnp.inf)  # [B,T,S,H]
        m = jnp.max(lse, axis=2, keepdims=True)               # stabiliser
        dmat = jnp.exp(lse - m)                               # [B,T,S,H]
        scores = jnp.einsum("bthk,bshk->bhts", q, k, preferred_element_type=jnp.float32)
        w = scores * jnp.moveaxis(dmat, -1, 1)                # [B,H,T,S]
        denom = jnp.maximum(jnp.abs(jnp.sum(w, axis=-1)), jnp.exp(-m[:, :, 0, :]).swapaxes(1, 2))
        out = jnp.einsum("bhts,bshk->bthk", (w / denom[..., None]).astype(dt), v)
        if cache is None:
            new_cache = None
        else:
            # Final (C, n, m) state for subsequent decode steps.
            last_f = cum_f[:, -1:, :]                          # cumf_S
            st_lse = last_f - cum_f + i_pre                    # [B,S,H]
            m_state = jnp.max(st_lse, axis=1)                  # [B,H]
            w_state = jnp.exp(st_lse - m_state[:, None, :])    # [B,S,H]
            k32, v32 = k.astype(jnp.float32), v.astype(jnp.float32)
            c_state = jnp.einsum("bsh,bshk,bshv->bhkv", w_state, k32, v32)
            n_state = jnp.einsum("bsh,bshk->bhk", w_state, k32)
            kw = p["conv_w"].shape[0]
            pad = jnp.zeros((x.shape[0], kw - 1, xr.shape[-1]), dt)
            xp_full = jnp.concatenate([pad, xr], axis=1)
            new_cache = (
                xp_full[:, -(kw - 1):, :].astype(cache[0].dtype),
                c_state,
                n_state,
                m_state,
            )
    else:
        conv_state, c_mem, n_mem, m_mem = cache
        kw = p["conv_w"].shape[0]
        xp = jnp.concatenate([conv_state.astype(dt), xr], axis=1)
        xc = sum(xp[:, i : i + 1, :] * p["conv_w"][i].astype(dt) for i in range(kw))
        xc = jax.nn.silu(xc + p["conv_b"].astype(dt))
        q, k, v = _mlstm_qkv(p, xc, dt)                       # [B,1,H,hd]
        i_pre, log_f = _mlstm_gates(p, xc)                    # [B,1,H]
        i_t, f_t = i_pre[:, 0], log_f[:, 0]                   # [B,H]
        m_new = jnp.maximum(f_t + m_mem, i_t)
        a = jnp.exp(f_t + m_mem - m_new)[..., None]
        b = jnp.exp(i_t - m_new)[..., None]
        k0, v0, q0 = (t[:, 0].astype(jnp.float32) for t in (k, v, q))  # [B,H,hd]
        c_new = a[..., None] * c_mem + b[..., None] * jnp.einsum("bhk,bhv->bhkv", k0, v0)
        n_new = a * n_mem + b * k0
        num = jnp.einsum("bhk,bhkv->bhv", q0, c_new)
        den = jnp.maximum(jnp.abs(jnp.sum(q0 * n_new, axis=-1)), jnp.exp(-m_new))
        out = (num / den[..., None]).astype(dt)[:, None]      # [B,1,H,hd]
        new_cache = (xp[:, -(kw - 1):, :].astype(conv_state.dtype), c_new, n_new, m_new)

    b_, s_ = x.shape[0], x.shape[1]
    out = out.reshape(b_, s_, d_in)
    out = rmsnorm(out, p["out_norm"], cfg.norm_eps)
    out = out + xc * p["skip_scale"].astype(dt)
    out = out * jax.nn.silu(z)
    return out @ p["down_proj"].astype(dt), new_cache


def init_mlstm_cache(cfg, batch: int, dtype=jnp.float32):
    d_in = cfg.d_model * cfg.mlstm_expand
    h = cfg.n_heads
    hd = d_in // h
    return (
        jnp.zeros((batch, 3, d_in), dtype),
        jnp.zeros((batch, h, hd, hd), jnp.float32),
        jnp.zeros((batch, h, hd), jnp.float32),
        jnp.full((batch, h), -1e30, jnp.float32),
    )


# --------------------------------------------------------------------------
# sLSTM
# --------------------------------------------------------------------------


def slstm_defs(cfg) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    f = int(d * cfg.slstm_proj)
    return {
        "w_in": ((d, 4 * d), ("embed", "mlp"), "fan_in"),     # i,f,z,o pre-acts
        "r_rec": ((h, hd, 4 * hd), ("heads", None, None), "fan_in"),  # block-diag recurrence
        "bias": ((4 * d,), ("mlp",), "zeros"),
        "out_norm": ((d,), ("embed",), "zeros"),
        "up_gate": ((d, f), ("embed", "mlp"), "fan_in"),
        "up_proj": ((d, f), ("embed", "mlp"), "fan_in"),
        "down_proj": ((f, d), ("mlp", "embed"), "fan_in"),
    }


def _slstm_cell(cfg, p, carry, x_pre):
    """One sLSTM step.  carry = (c, n, m, h_prev) each [B, d] f32 (m [B, H])."""
    d = cfg.d_model
    h_heads = cfg.n_heads
    hd = d // h_heads
    c, n, m, h_prev = carry
    hp = h_prev.reshape(-1, h_heads, hd)
    rec = jnp.einsum("bhk,hkj->bhj", hp, p["r_rec"])          # [B,H,4hd]
    pre = x_pre + _interleave(rec, d)
    i_pre, f_pre, z_pre, o_pre = jnp.split(pre, 4, axis=-1)   # [B,d]
    log_f = -jax.nn.softplus(-f_pre)
    mh = m
    i_h = i_pre.reshape(-1, h_heads, hd)
    f_h = log_f.reshape(-1, h_heads, hd)
    m_new = jnp.maximum(f_h + mh[..., None] * 1.0, i_h).max(-1)  # per-head stabiliser
    scale_f = jnp.exp(f_h + mh[..., None] - m_new[..., None]).reshape(-1, d)
    scale_i = jnp.exp(i_h - m_new[..., None]).reshape(-1, d)
    c_new = scale_f * c + scale_i * jnp.tanh(z_pre)
    n_new = scale_f * n + scale_i
    h_new = jax.nn.sigmoid(o_pre) * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, m_new, h_new), h_new


def _interleave(rec, d):
    """[B,H,4hd] -> [B,4d] matching the i,f,z,o split layout."""
    b, h, four_hd = rec.shape
    hd = four_hd // 4
    parts = jnp.split(rec, 4, axis=-1)                        # 4 × [B,H,hd]
    return jnp.concatenate([pt.reshape(b, h * hd) for pt in parts], axis=-1)


def apply_slstm(cfg, p, x, *, cache=None):
    """x [B,S,d]; cache = (c, n, m, h) -> sequential scan (train) / one step."""
    from .layers import rmsnorm

    dt = x.dtype
    x_pre = (x @ p["w_in"].astype(dt)).astype(jnp.float32) + p["bias"]

    carry = cache if cache is not None else init_slstm_cache(cfg, x.shape[0])
    carry, hs = jax.lax.scan(
        lambda cr, xp: _slstm_cell(cfg, p, cr, xp), carry, jnp.moveaxis(x_pre, 1, 0)
    )
    y = jnp.moveaxis(hs, 0, 1).astype(dt)                     # [B,S,d]
    new_cache = carry if cache is not None else None

    y = rmsnorm(y, p["out_norm"], cfg.norm_eps)
    g = jax.nn.gelu(y @ p["up_gate"].astype(dt))
    u = y @ p["up_proj"].astype(dt)
    return (g * u) @ p["down_proj"].astype(dt), new_cache


def init_slstm_cache(cfg, batch: int):
    d = cfg.d_model
    return (
        jnp.zeros((batch, d), jnp.float32),
        jnp.zeros((batch, d), jnp.float32),
        jnp.full((batch, cfg.n_heads), -1e30, jnp.float32),
        jnp.zeros((batch, d), jnp.float32),
    )
