"""Model configuration for every assigned architecture family.

One dataclass covers dense / MoE / VLM / audio enc-dec / hybrid (Mamba+attn)
/ xLSTM stacks; per-arch instances live in ``repro/configs/<id>.py``.  The
config is a frozen, hashable static so it can be closed over by jit.

The stack is described as a list of repeating *units* (``stages``); each unit
is a short heterogeneous pattern of blocks (e.g. Jamba's
[mamba ×3, attn, mamba ×4] with MoE every 2nd layer) and the model scans over
unit repeats, keeping HLO size O(unit) instead of O(n_layers).
"""

from __future__ import annotations

import dataclasses
from typing import Literal

BlockKind = Literal["attn", "cross_attn", "mamba", "mlstm", "slstm", "reservoir"]
MLPKind = Literal["none", "dense", "moe"]


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """One layer inside a unit: sequence mixer + channel mixer."""

    mixer: BlockKind = "attn"
    mlp: MLPKind = "dense"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"  # dense | moe | vlm | audio | hybrid | ssm | reservoir

    # -- trunk dimensions -----------------------------------------------------
    n_layers: int = 12
    d_model: int = 1024
    n_heads: int = 16
    n_kv_heads: int = 16
    head_dim: int = 0           # 0 -> d_model // n_heads
    d_ff: int = 4096
    vocab_size: int = 32000
    max_seq_len: int = 8192

    # -- attention flavour ----------------------------------------------------
    qk_norm: bool = False       # qwen3-style per-head RMSNorm on q, k
    rope_theta: float = 10_000.0
    attn_logit_softcap: float = 0.0
    causal: bool = True         # decoder; encoders set False

    # -- channel mixer ---------------------------------------------------------
    mlp_act: str = "silu"       # "silu" (SwiGLU) | "gelu" (GeGLU, gemma)

    # -- MoE -------------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 1e-2

    # -- unit pattern ----------------------------------------------------------
    # Layer kinds inside one repeating unit; n_layers % len(unit) == 0.
    # Empty tuple -> homogeneous ("attn","dense"/"moe") unit of length 1.
    unit: tuple[BlockSpec, ...] = ()

    # -- Mamba (hybrid family) -------------------------------------------------
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2

    # -- xLSTM -----------------------------------------------------------------
    mlstm_expand: int = 2
    slstm_proj: float = 4.0 / 3.0

    # -- cross-attention context (VLM / enc-dec) --------------------------------
    n_context_tokens: int = 0   # image patches / encoder frames fed to cross-attn
    d_context: int = 0          # 0 -> d_model (stub frontends emit d_model)

    # -- encoder (audio enc-dec family) -----------------------------------------
    n_encoder_layers: int = 0

    # -- reservoir (paper-technique LM bridge) -----------------------------------
    reservoir_nodes: int = 256
    reservoir_gamma: float = 0.9
    reservoir_alpha_ratio: float = 1.0  # theta / tau_ph

    # -- numerics / execution ----------------------------------------------------
    dtype: str = "bfloat16"
    remat: str = "full"         # "none" | "full" | "dots"
    logit_dtype: str = "float32"
    tie_embeddings: bool = True
    norm_eps: float = 1e-6

    # -- distribution defaults (overridable at launch) ----------------------------
    strategy: str = "fsdp_tp"   # fsdp_tp | fsdp | fsdp_tp_ep
    microbatches: int = 1       # grad-accumulation steps inside train_step

    # -- cost-calibration (launch/calibrate.py) -----------------------------------
    # lax.scan unroll for the unit/microbatch loops.  XLA's cost_analysis
    # counts a while body once regardless of trip count; the calibration
    # variants set n_layers = k·|unit| with analysis_unroll = k so every
    # body instance is visible to the analysis (DESIGN.md §6).
    analysis_unroll: int = 1

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if not self.unit:
            mlp = "moe" if self.n_experts else "dense"
            object.__setattr__(self, "unit", (BlockSpec("attn", mlp),))
        if self.n_layers % len(self.unit):
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not divisible by "
                f"unit length {len(self.unit)}"
            )
        if self.d_context == 0 and self.n_context_tokens:
            object.__setattr__(self, "d_context", self.d_model)

    # -- derived ---------------------------------------------------------------
    @property
    def n_units(self) -> int:
        return self.n_layers // len(self.unit)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def moe_layers_per_unit(self) -> int:
        return sum(1 for b in self.unit if b.mlp == "moe")

    @property
    def attn_layers(self) -> int:
        per = sum(1 for b in self.unit if b.mixer in ("attn", "cross_attn"))
        return per * self.n_units

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + trunk), for roofline MODEL_FLOPS."""
        d, v = self.d_model, self.vocab_size
        total = d * v * (1 if self.tie_embeddings else 2)
        for blk in self.unit * self.n_units:
            total += self._mixer_params(blk.mixer) + self._mlp_params(blk.mlp)
            total += 2 * d  # pre-norms
        total += d  # final norm
        if self.n_encoder_layers:
            enc = self.n_encoder_layers * (
                self._mixer_params("attn") + self._mlp_params("dense") + 2 * self.d_model
            )
            total += enc
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE counts top_k experts only)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        dense_moe = 3 * d * self.moe_d_ff
        per_layer_full = self.n_experts * dense_moe
        per_layer_active = self.top_k * dense_moe
        n_moe = self.moe_layers_per_unit * self.n_units
        return self.param_count() - n_moe * (per_layer_full - per_layer_active)

    def _mixer_params(self, kind: str) -> int:
        d, hd = self.d_model, self.head_dim
        if kind == "attn":
            n = d * (self.n_heads + 2 * self.n_kv_heads) * hd + self.n_heads * hd * d
            if self.qk_norm:
                n += 2 * hd
            return n
        if kind == "cross_attn":
            dc = self.d_context or d
            return d * self.n_heads * hd + dc * 2 * self.n_kv_heads * hd + self.n_heads * hd * d
        if kind == "mamba":
            d_in = d * self.mamba_expand
            n = d * 2 * d_in                       # in_proj (x, z)
            n += d_in * self.mamba_d_conv          # depthwise conv
            n += d_in * (2 * self.mamba_d_state + 1) + d_in  # x->B,C,dt + dt bias
            n += d_in * self.mamba_d_state + d_in  # A_log, D
            n += d_in * d                          # out_proj
            return n
        if kind == "mlstm":
            d_in = d * self.mlstm_expand
            hd_in = d_in // self.n_heads
            n = d * 2 * d_in                       # up-proj (x, z)
            n += 3 * d_in * hd_in * self.n_heads // self.n_heads * 1  # placeholder, refined below
            n = d * 2 * d_in + 3 * d_in * d_in // self.n_heads + 2 * d_in + d_in * d
            return n
        if kind == "slstm":
            return 4 * d * d + 4 * d + int(2 * d * d * self.slstm_proj)
        if kind == "reservoir":
            return d * self.reservoir_nodes + self.reservoir_nodes * d
        raise ValueError(kind)

    def _mlp_params(self, kind: str) -> int:
        d = self.d_model
        if kind == "none":
            return 0
        if kind == "dense":
            return 3 * d * self.d_ff
        if kind == "moe":
            return self.n_experts * 3 * d * self.moe_d_ff + d * self.n_experts
        raise ValueError(kind)
