"""Core transformer layers: norms, RoPE, GQA attention, gated MLPs, embeddings.

Everything is pure-functional: ``*_defs(cfg)`` tables declare parameter
shapes together with their *logical sharding axes* (consumed by
``repro.parallel.sharding``), ``init_*`` build arrays from the defs, and
``apply_*`` run the computation.  Params are stored in float32 (master
weights; the optimizer works on them directly) and cast to ``cfg.dtype``
at use.

Logical axes vocabulary (mapped to mesh axes by parallel/sharding.py):
  "vocab"   embedding rows            -> model axis
  "embed"   d_model                   -> data axis under FSDP
  "heads"   query heads               -> model axis (TP)
  "kv"      kv heads                  -> model axis if divisible else replicated
  "hd"      head_dim                  -> never sharded
  "mlp"     d_ff / expanded inner dim -> model axis (TP)
  "expert"  MoE expert axis           -> model axis (EP)
  "ctx"     cross-attention context   -> like embed
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------------
# Param-def helpers
# --------------------------------------------------------------------------


def init_from_defs(defs: dict, key) -> dict:
    """Build a params dict from a defs table {name: (shape, axes, init)}.

    ``init`` is one of "fan_in" (truncated-normal, 1/sqrt(fan_in) with fan_in
    = first axis), "zeros", "ones", or a callable(key, shape)->array.
    """
    params = {}
    keys = jax.random.split(key, max(2, len(defs)))
    for (name, (shape, _axes, init)), k in zip(sorted(defs.items()), keys):
        if init == "fan_in":
            scale = 1.0 / math.sqrt(max(1, shape[0]))
            params[name] = scale * jax.random.truncated_normal(
                k, -2.0, 2.0, shape, jnp.float32
            )
        elif init == "zeros":
            params[name] = jnp.zeros(shape, jnp.float32)
        elif init == "ones":
            params[name] = jnp.ones(shape, jnp.float32)
        elif callable(init):
            params[name] = init(k, shape)
        else:
            raise ValueError(f"unknown init {init!r} for {name}")
    return params


def axes_from_defs(defs: dict) -> dict:
    return {name: axes for name, (_s, axes, _i) in defs.items()}


# --------------------------------------------------------------------------
# RMSNorm
# --------------------------------------------------------------------------


def rmsnorm(x, scale, eps):
    # f32 *accumulation* for the variance without materialising x in f32: if
    # any [B,S,d]-sized f32 view of the layer input reaches the backward,
    # XLA hoists the bf16->f32 convert of the remat-saved residual stack out
    # of the backward scan, costing +4.5 GiB/device at granite-8b train_4k
    # (EXPERIMENTS.md §Perf).  jnp.mean with dtype=f32 accumulates the bf16
    # squares in f32 (reduction precision kept; elementwise ops stay bf16).
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True, dtype=jnp.float32)
    rs = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * rs * (1.0 + scale).astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float, positions):
    """positions [...,] -> (cos, sin) [..., head_dim/2], f32."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x [..., S, H, D]; cos/sin [..., S, D/2] broadcast over heads."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c, s = cos[..., None, :], sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


# --------------------------------------------------------------------------
# Attention (self, GQA, optional qk-norm / softcap; cross variant)
# --------------------------------------------------------------------------


def attn_defs(cfg) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    defs = {
        "wq": ((d, cfg.n_heads, hd), ("embed", "heads", "hd"), "fan_in"),
        "wk": ((d, cfg.n_kv_heads, hd), ("embed", "kv", "hd"), "fan_in"),
        "wv": ((d, cfg.n_kv_heads, hd), ("embed", "kv", "hd"), "fan_in"),
        "wo": ((cfg.n_heads, hd, d), ("heads", "hd", "embed"), "fan_in"),
    }
    if cfg.qk_norm:
        defs["q_norm"] = ((hd,), ("hd",), "zeros")
        defs["k_norm"] = ((hd,), ("hd",), "zeros")
    return defs


def cross_attn_defs(cfg) -> dict:
    d, hd, dc = cfg.d_model, cfg.head_dim, (cfg.d_context or cfg.d_model)
    return {
        "wq": ((d, cfg.n_heads, hd), ("embed", "heads", "hd"), "fan_in"),
        "wk": ((dc, cfg.n_kv_heads, hd), ("ctx", "kv", "hd"), "fan_in"),
        "wv": ((dc, cfg.n_kv_heads, hd), ("ctx", "kv", "hd"), "fan_in"),
        "wo": ((cfg.n_heads, hd, d), ("heads", "hd", "embed"), "fan_in"),
        "gate": ((1,), (None,), "zeros"),  # tanh-gated residual (llama-3.2 style)
    }


_CHUNK_THRESHOLD = 8192
_KV_CHUNK = 2048


def _sdpa(cfg, q, k, v, *, causal: bool, q_offset=0):
    """q [B,Sq,H,D], k/v [B,Skv,KV,D] -> [B,Sq,H,D].  Softmax in f32.

    GQA: H query heads grouped over KV heads.  ``q_offset`` is the absolute
    position of q[0] for causal masking against a longer kv (decode).

    Long sequences (Skv > 8k with Sq > 1, i.e. 32k+ prefill) switch to the
    online-softmax KV-chunked path: the dense path would materialise a
    [B,H,Sq,Skv] f32 logits tensor (34 GiB/device at prefill_32k —
    EXPERIMENTS.md §Perf); chunking caps it at [B,H,Sq,chunk].
    """
    sq, skv = q.shape[1], k.shape[1]
    if sq > 1 and skv > _CHUNK_THRESHOLD and skv % _KV_CHUNK == 0:
        return _sdpa_chunked(cfg, q, k, v, causal=causal, q_offset=q_offset)
    return _sdpa_dense(cfg, q, k, v, causal=causal, q_offset=q_offset)


def _chunk_logits(cfg, qg, ks, dh):
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, ks, preferred_element_type=jnp.float32)
    logits *= 1.0 / math.sqrt(dh)
    if cfg.attn_logit_softcap:
        cap = cfg.attn_logit_softcap
        logits = cap * jnp.tanh(logits / cap)
    return logits


def _sdpa_dense(cfg, q, k, v, *, causal: bool, q_offset=0):
    b, sq, h, dh = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    qg = q.reshape(b, sq, kvh, h // kvh, dh)
    logits = _chunk_logits(cfg, qg, k, dh)
    if causal:
        qpos = jnp.arange(sq) + q_offset
        kpos = jnp.arange(skv)
        mask = qpos[:, None] >= kpos[None, :]
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, sq, h, dh)


def _sdpa_chunked(cfg, q, k, v, *, causal: bool, q_offset=0, chunk: int = _KV_CHUNK):
    """Flash-style online softmax over KV chunks (exact, pure jnp)."""
    b, sq, h, dh = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    group = h // kvh
    qg = q.reshape(b, sq, kvh, group, dh)
    qpos = jnp.arange(sq) + q_offset

    acc0 = jnp.zeros((b, kvh, group, sq, dh), jnp.float32)
    mx0 = jnp.full((b, kvh, group, sq), -jnp.inf, jnp.float32)
    den0 = jnp.zeros((b, kvh, group, sq), jnp.float32)

    def body(carry, idx):
        acc, mx, den = carry
        ks = jax.lax.dynamic_slice_in_dim(k, idx * chunk, chunk, 1)
        vs = jax.lax.dynamic_slice_in_dim(v, idx * chunk, chunk, 1)
        logits = _chunk_logits(cfg, qg, ks, dh)                # [b,kv,g,sq,chunk]
        if causal:
            kpos = idx * chunk + jnp.arange(chunk)
            mask = (qpos[:, None] >= kpos[None, :])[None, None, None]
        else:
            mask = jnp.ones((1, 1, 1, sq, chunk), bool)
        chunk_mx = jnp.max(jnp.where(mask, logits, -jnp.inf), axis=-1)
        new_mx = jnp.maximum(mx, chunk_mx)
        safe_mx = jnp.where(jnp.isneginf(new_mx), 0.0, new_mx)  # fully-masked rows
        p = jnp.where(mask, jnp.exp(logits - safe_mx[..., None]), 0.0)
        corr = jnp.where(jnp.isneginf(mx), 0.0, jnp.exp(mx - safe_mx))
        den = den * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgst,btkd->bkgsd", p.astype(v.dtype), vs)
        acc = acc * corr[..., None] + pv.astype(jnp.float32)
        return (acc, new_mx, den), None

    (acc, _, den), _ = jax.lax.scan(body, (acc0, mx0, den0), jnp.arange(skv // chunk))
    out = acc / jnp.maximum(den, 1e-30)[..., None]
    out = jnp.moveaxis(out, 3, 1)                              # [b,sq,kv,g,dh]
    return out.reshape(b, sq, h, dh).astype(q.dtype)


def apply_attn(cfg, p, x, *, positions, cache=None, causal=True):
    """Self-attention.  With ``cache=(k_buf, v_buf, index)`` runs one decode
    step: writes k,v at ``index`` and attends over the whole buffer.
    Returns (out, new_cache).
    """
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    cos, sin = rope_freqs(cfg.head_dim, cfg.rope_theta, positions)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    new_cache = None
    if cache is not None:
        k_buf, v_buf, idx = cache
        k_buf = jax.lax.dynamic_update_slice(k_buf, k.astype(k_buf.dtype), (0, idx, 0, 0))
        v_buf = jax.lax.dynamic_update_slice(v_buf, v.astype(v_buf.dtype), (0, idx, 0, 0))
        new_cache = (k_buf, v_buf, idx + x.shape[1])
        out = _sdpa(cfg, q, k_buf.astype(dt), v_buf.astype(dt), causal=causal, q_offset=idx)
    else:
        out = _sdpa(cfg, q, k, v, causal=causal)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))
    return y, new_cache


def apply_cross_attn(cfg, p, x, *, context_kv):
    """Cross-attention to a precomputed (k, v) of the context (image patches /
    encoder frames).  Tanh-gated residual contribution."""
    dt = x.dtype
    k, v = context_kv
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    out = _sdpa(cfg, q, k.astype(dt), v.astype(dt), causal=False)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))
    return jnp.tanh(p["gate"].astype(jnp.float32)).astype(dt) * y


def context_kv(cfg, p, context):
    """Precompute cross-attention k, v from context embeddings [B, T, d_ctx]."""
    dt = context.dtype
    k = jnp.einsum("btd,dhk->bthk", context, p["wk"].astype(dt))
    v = jnp.einsum("btd,dhk->bthk", context, p["wv"].astype(dt))
    return k, v


# --------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# --------------------------------------------------------------------------


def mlp_defs(cfg) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "wi_gate": ((d, f), ("embed", "mlp"), "fan_in"),
        "wi_up": ((d, f), ("embed", "mlp"), "fan_in"),
        "wo": ((f, d), ("mlp", "embed"), "fan_in"),
    }


def apply_mlp(cfg, p, x):
    dt = x.dtype
    act = jax.nn.silu if cfg.mlp_act == "silu" else jax.nn.gelu
    g = act(x @ p["wi_gate"].astype(dt))
    u = x @ p["wi_up"].astype(dt)
    return (g * u) @ p["wo"].astype(dt)


# --------------------------------------------------------------------------
# Embedding / logits
# --------------------------------------------------------------------------


def embed_defs(cfg) -> dict:
    # The table shards over the vocab ("model" axis) only: sharding d_model as
    # well makes the token gather unpartitionable (SPMD falls back to full
    # rematerialisation — gigabytes of transient per device; EXPERIMENTS §Perf).
    defs = {"embedding": ((cfg.vocab_size, cfg.d_model), ("vocab", None), "fan_in")}
    if not cfg.tie_embeddings:
        defs["lm_head"] = ((cfg.d_model, cfg.vocab_size), (None, "vocab"), "fan_in")
    return defs


def embed_tokens(cfg, p, tokens):
    x = jnp.take(p["embedding"], tokens, axis=0).astype(cfg.dtype)
    return x * math.sqrt(cfg.d_model)


def logits_from_hidden(cfg, p, x):
    dt = x.dtype
    table = p["lm_head"].astype(dt) if "lm_head" in p else p["embedding"].astype(dt).T
    return (x @ table).astype(cfg.logit_dtype)


def norm_defs(cfg, name: str = "scale") -> dict:
    return {name: ((cfg.d_model,), ("embed",), "zeros")}
