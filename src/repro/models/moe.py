"""Mixture-of-Experts channel mixer (qwen3-MoE / Jamba style top-k routing).

Token-choice top-k routing with GShard-style *groups*: each sequence (batch
element) dispatches independently with capacity C = cf·k·S/E.  Grouping is
what makes the op shardable — every tensor is batched over the group axis
(sharded over "data"/"pod") with no cross-group coupling.

Expert parallelism is an explicit shard_map block (`_moe_block`): the
dispatch buffer is group-sharded and expert-replicated; each model-device
slices out its E/TP experts, runs their FFNs, combines its own experts'
outputs back per token, and a single token-sized ``psum`` over the model
axis completes the combine.  Design history (EXPERIMENTS.md §Perf):

  * argsort-based positions -> XLA distributed-sort network
    (u32 [B,S·k,n_dev] all-reduces, 1 GiB/layer at 235B);
  * GSPMD-inferred expert-major reshard -> full-buffer all-gather fallback
    (2.5 GiB f32/layer);
  * all_to_all on an expert-replicated buffer -> 16× redundant compute;
  * THIS design: communication = one [tokens, d] all-reduce per layer
    (the information-theoretic floor for a capacity-slot combine) + ZeRO
    weight gathers.

Position computation is a per-group one-hot running count (local, no sort).
Overflow beyond capacity is dropped (Switch/GShard semantics).  Returns the
Switch load-balance aux loss for the trainer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.compat import get_abstract_mesh


def moe_defs(cfg) -> dict:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    return {
        "router": ((d, e), ("embed", "expert"), "fan_in"),
        "wi_gate": ((e, d, f), ("expert", "embed", "mlp"), "fan_in"),
        "wi_up": ((e, d, f), ("expert", "embed", "mlp"), "fan_in"),
        "wo": ((e, f, d), ("expert", "mlp", "embed"), "fan_in"),
    }


def _group_positions(flat_e: jnp.ndarray, n_experts: int) -> jnp.ndarray:
    """Position of each slot within its expert's queue, per group.

    flat_e [B, S·k] int32 -> pos [B, S·k] via a one-hot running count (NOT
    an argsort: XLA lowers sharded sorts into a distributed sort network)."""
    onehot = jax.nn.one_hot(flat_e, n_experts, dtype=jnp.int32)   # [B, S·k, E]
    running = jnp.cumsum(onehot, axis=1) - 1
    return jnp.take_along_axis(running, flat_e[..., None], axis=-1)[..., 0]


def _expert_ffn(cfg, buf, wg, wu, wo):
    """buf [E?, C, d] batched-expert FFN (pure einsums, MXU-friendly)."""
    dt = buf.dtype
    act = jax.nn.silu if cfg.mlp_act == "silu" else jax.nn.gelu
    g = act(jnp.einsum("...ecd,edf->...ecf", buf, wg.astype(dt)))
    u = jnp.einsum("...ecd,edf->...ecf", buf, wu.astype(dt))
    return jnp.einsum("...ecf,efd->...ecd", g * u, wo.astype(dt))


def _combine_local(out_e, flat_e, safe_pos, w, e_start, e_count, cap):
    """Per-group combine of the locally-owned experts' outputs.

    out_e [G, E_loc, C+1, d]; flat_e/safe_pos/w [G, S·k].  Slots routed to
    foreign experts contribute zero (their psum partner owns them)."""
    local_e = flat_e - e_start
    own = (local_e >= 0) & (local_e < e_count) & (safe_pos < cap)
    idx_e = jnp.clip(local_e, 0, e_count - 1)

    def one_group(og, ie, sp, wk, ok):
        vals = og[ie, sp]                                  # [S·k, d]
        return vals * (wk * ok)[:, None].astype(vals.dtype)

    return jax.vmap(one_group)(out_e, idx_e, safe_pos, w, own)  # [G, S·k, d]


def _moe_block_dense(cfg, buf, params, flat_e, safe_pos, w, cap):
    """Single-device path (smoke tests): all experts local."""
    out = _expert_ffn(cfg, buf, params["wi_gate"], params["wi_up"], params["wo"])
    return _combine_local(out, flat_e, safe_pos, w, 0, cfg.n_experts, cap)


def _moe_block_sharded(cfg, mesh, buf, params, flat_e, safe_pos, w, cap):
    """Expert-parallel path: slice-own-experts + FFN + psum combine."""
    from jax.sharding import PartitionSpec as P

    e = cfg.n_experts
    b_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    tp = mesh.shape["model"]
    e_loc = e // tp
    zero_ax = "data" if "data" in b_axes else None

    def block(buf_l, wg_l, wu_l, wo_l, fe_l, sp_l, w_l):
        j = jax.lax.axis_index("model")
        buf_e = jax.lax.dynamic_slice_in_dim(buf_l, j * e_loc, e_loc, axis=1)
        if zero_ax:
            wg_l = jax.lax.all_gather(wg_l, zero_ax, axis=1, tiled=True)
            wu_l = jax.lax.all_gather(wu_l, zero_ax, axis=1, tiled=True)
            wo_l = jax.lax.all_gather(wo_l, zero_ax, axis=2, tiled=True)
        out_e = _expert_ffn(cfg, buf_e, wg_l, wu_l, wo_l)
        y = _combine_local(out_e, fe_l, sp_l, w_l, j * e_loc, e_loc, cap)
        # Sum the k slots per token BEFORE the psum: the wire then carries
        # [G, S, d] (token-sized) instead of [G, S·k, d] — 8× less at top-8.
        g_loc, sk, dd = y.shape
        y = jnp.sum(y.reshape(g_loc, sk // cfg.top_k, cfg.top_k, dd), axis=2)
        return jax.lax.psum(y, "model")

    bsp = P(b_axes if b_axes else None)
    fn = jax.shard_map(
        block,
        mesh=mesh,
        in_specs=(P(*bsp, None, None, None),
                  P("model", zero_ax, None),
                  P("model", zero_ax, None),
                  P("model", None, zero_ax),
                  P(*bsp, None), P(*bsp, None), P(*bsp, None)),
        out_specs=P(*bsp, None, None),
        check_vma=False,
    )
    return fn(buf, params["wi_gate"], params["wi_up"], params["wo"],
              flat_e, safe_pos, w)


def _moe_dense_tokens(cfg, buf, params, flat_e, safe_pos, w, cap):
    """Dense path wrapper returning token-major [B, S, d]."""
    slots = _moe_block_dense(cfg, buf, params, flat_e, safe_pos, w, cap)
    b, sk, d = slots.shape
    return jnp.sum(slots.reshape(b, sk // cfg.top_k, cfg.top_k, d), axis=2)


def apply_moe(cfg, p, x):
    """x [B, S, d] -> (y [B, S, d], aux_loss scalar f32)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    dt = x.dtype

    # -- routing (f32) ---------------------------------------------------------
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                    # [B,S,E]
    top_p, top_e = jax.lax.top_k(probs, k)                     # [B,S,k]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)     # renormalise (qwen3)

    # -- aux load-balance loss (Switch) -----------------------------------------
    me = jnp.mean(probs, axis=(0, 1))                          # mean router prob [E]
    dispatched = jax.nn.one_hot(top_e, e, dtype=jnp.float32)   # [B,S,k,E]
    ce = jnp.mean(jnp.sum(dispatched, axis=2), axis=(0, 1)) / k
    aux = e * jnp.sum(me * ce)

    # -- per-group dispatch positions --------------------------------------------
    cap = max(1, int(cfg.capacity_factor * k * s // e))
    flat_e = top_e.reshape(b, s * k)
    pos = _group_positions(flat_e, e)                          # [B, S·k]
    keep = pos < cap
    safe_pos = jnp.where(keep, pos, cap)                       # overflow -> scratch row
    tok_idx = jnp.repeat(jnp.arange(s), k)                     # [S·k]

    # -- dispatch: group-local scatter into [B, E, C+1, d] -------------------------
    from repro.parallel.sharding import maybe_shard

    def scatter_group(xg, fe, sp):
        buf = jnp.zeros((e, cap + 1, d), dt)
        return buf.at[fe, sp].set(xg[tok_idx], mode="drop")

    buf = jax.vmap(scatter_group)(x, flat_e, safe_pos)
    buf = maybe_shard(buf, ("pod", "data"), None, None, None)

    # -- expert FFNs + combine -----------------------------------------------------
    w = (top_p.reshape(b, s * k) * keep).astype(dt)
    mesh = get_abstract_mesh()
    usable = mesh is not None and "model" in mesh.axis_names \
        and e % mesh.shape["model"] == 0
    if usable:
        # shard_map needs the group axis to divide the batch mesh axes
        # exactly (long_500k has batch 1; multipod microbatches may not
        # divide pod×data) — those cells use the GSPMD einsum path instead.
        b_div = 1
        for a in ("pod", "data"):
            if a in mesh.axis_names:
                b_div *= mesh.shape[a]
        usable = b % b_div == 0
    if usable:
        y = _moe_block_sharded(cfg, mesh, buf, p, flat_e, safe_pos, w, cap)
    else:
        y = _moe_dense_tokens(cfg, buf, p, flat_e, safe_pos, w, cap)
    return y, aux
