"""Training losses: causal-LM cross entropy with z-loss and MoE aux."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def lm_loss(cfg, logits, labels, *, mask=None, z_loss: float = 1e-4, moe_aux=0.0):
    """Next-token CE.  logits [B, S, V] (f32), labels [B, S] (already shifted
    by the data pipeline).  Returns (loss, metrics dict)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = logz - gold
    if mask is None:
        mask = jnp.ones_like(ce)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    ce_mean = jnp.sum(ce * mask) / denom
    zl = z_loss * jnp.sum((logz * mask) ** 2) / denom
    aux = cfg.router_aux_weight * moe_aux if cfg.n_experts else 0.0
    loss = ce_mean + zl + aux
    metrics = {
        "loss": loss,
        "ce": ce_mean,
        "z_loss": zl,
        "moe_aux": jnp.asarray(moe_aux, jnp.float32),
        "tokens": denom,
    }
    return loss, metrics
