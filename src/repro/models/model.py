"""Model assembly: heterogeneous block units scanned over repeats.

The stack is ``cfg.unit`` (a short pattern of BlockSpecs) repeated
``cfg.n_units`` times.  Parameters for each unit position are stacked over
repeats and the forward pass is a ``lax.scan`` over units, so the compiled
HLO is O(|unit|) regardless of depth (94-layer MoE compiles as fast as a
12-layer dense model).  Heterogeneous patterns (Jamba's mamba/attn
interleave, xLSTM's 7:1, VLM cross-attn insertion, enc-dec) are expressed
purely in the unit pattern.

Three entry points:
  ``forward``      tokens -> logits (+ MoE aux loss)      [train / eval]
  ``prefill``      tokens -> logits, filled cache         [serving]
  ``decode_step``  one token + cache -> logits, cache     [serving]

Caches are pytrees stacked over units, one entry per unit position, so the
decode scan zips (params, cache) leaves.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import layer as reservoir_layer

from . import layers, mamba, moe, xlstm
from .config import ModelConfig

# --------------------------------------------------------------------------
# Param defs per block
# --------------------------------------------------------------------------


def _mixer_defs(cfg, kind: str) -> dict:
    if kind == "attn":
        return layers.attn_defs(cfg)
    if kind == "cross_attn":
        return layers.cross_attn_defs(cfg)
    if kind == "mamba":
        return mamba.mamba_defs(cfg)
    if kind == "mlstm":
        return xlstm.mlstm_defs(cfg)
    if kind == "slstm":
        return xlstm.slstm_defs(cfg)
    if kind == "reservoir":
        return reservoir_layer.reservoir_defs(cfg)
    raise ValueError(kind)


def _mlp_defs(cfg, kind: str) -> dict:
    if kind == "none":
        return {}
    if kind == "dense":
        return layers.mlp_defs(cfg)
    if kind == "moe":
        return moe.moe_defs(cfg)
    raise ValueError(kind)


def _block_defs(cfg, blk) -> dict:
    defs = {"norm_mixer": ((cfg.d_model,), ("embed",), "zeros")}
    defs.update({f"mixer/{k}": v for k, v in _mixer_defs(cfg, blk.mixer).items()})
    if blk.mlp != "none":
        defs["norm_mlp"] = ((cfg.d_model,), ("embed",), "zeros")
        defs.update({f"mlp/{k}": v for k, v in _mlp_defs(cfg, blk.mlp).items()})
    return defs


def _split(params: dict, prefix: str) -> dict:
    plen = len(prefix) + 1
    return {k[plen:]: v for k, v in params.items() if k.startswith(prefix + "/")}


# --------------------------------------------------------------------------
# Init
# --------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key) -> dict:
    keys = jax.random.split(key, 4)
    params: dict[str, Any] = {"embed": layers.init_from_defs(layers.embed_defs(cfg), keys[0])}

    def stacked_unit(key, unit, n_repeats):
        out = []
        for pos, blk in enumerate(unit):
            defs = _block_defs(cfg, blk)
            kpos = jax.random.fold_in(key, pos)
            init_one = lambda k, d=defs: layers.init_from_defs(d, k)
            out.append(jax.vmap(init_one)(jax.random.split(kpos, n_repeats)))
        return tuple(out)

    params["units"] = stacked_unit(keys[1], cfg.unit, cfg.n_units)
    params["final_norm"] = layers.init_from_defs(layers.norm_defs(cfg), keys[2])

    if cfg.n_encoder_layers:
        from .config import BlockSpec

        enc_unit = (BlockSpec("attn", "dense"),)
        params["encoder"] = {
            "units": stacked_unit(keys[3], enc_unit, cfg.n_encoder_layers),
            "final_norm": layers.init_from_defs(
                layers.norm_defs(cfg), jax.random.fold_in(keys[3], 7)),
        }
    return params


def param_logical_axes(cfg: ModelConfig) -> dict:
    """Same pytree structure as init_params, with logical-axis tuples as leaves.

    Stacked unit leaves get a leading ``"layers"`` axis entry (never sharded).
    """
    axes: dict[str, Any] = {"embed": layers.axes_from_defs(layers.embed_defs(cfg))}

    def unit_axes(unit):
        out = []
        for blk in unit:
            defs = _block_defs(cfg, blk)
            out.append({k: ("layers", *a) for k, a in layers.axes_from_defs(defs).items()})
        return tuple(out)

    axes["units"] = unit_axes(cfg.unit)
    axes["final_norm"] = layers.axes_from_defs(layers.norm_defs(cfg))
    if cfg.n_encoder_layers:
        from .config import BlockSpec

        axes["encoder"] = {
            "units": unit_axes((BlockSpec("attn", "dense"),)),
            "final_norm": layers.axes_from_defs(layers.norm_defs(cfg)),
        }
    return axes


# --------------------------------------------------------------------------
# Block application
# --------------------------------------------------------------------------


def _apply_block(cfg, blk, p, x, *, positions, context=None, cache=None):
    """Pre-norm mixer + residual, pre-norm MLP + residual.

    Returns (x, new_cache, aux).  ``cache`` is the mixer state for this block
    (None in pure training).
    """
    aux = jnp.zeros((), jnp.float32)
    h = layers.rmsnorm(x, p["norm_mixer"], cfg.norm_eps)
    mp = _split(p, "mixer")
    new_cache = None
    if blk.mixer == "attn":
        y, new_cache = layers.apply_attn(cfg, mp, h, positions=positions,
                                         cache=cache, causal=cfg.causal)
    elif blk.mixer == "cross_attn":
        if cache is not None:
            ctx_kv = cache  # precomputed at prefill
            new_cache = cache
        else:
            ctx_kv = layers.context_kv(cfg, mp, context)
        y = layers.apply_cross_attn(cfg, mp, h, context_kv=ctx_kv)
    elif blk.mixer == "mamba":
        y, new_cache = mamba.apply_mamba(cfg, mp, h, cache=cache)
    elif blk.mixer == "mlstm":
        y, new_cache = xlstm.apply_mlstm(cfg, mp, h, cache=cache)
    elif blk.mixer == "slstm":
        y, new_cache = xlstm.apply_slstm(cfg, mp, h, cache=cache)
    elif blk.mixer == "reservoir":
        y, new_cache = reservoir_layer.apply_reservoir(cfg, mp, h, cache=cache)
    else:
        raise ValueError(blk.mixer)
    x = x + y

    if blk.mlp != "none":
        h = layers.rmsnorm(x, p["norm_mlp"], cfg.norm_eps)
        if blk.mlp == "dense":
            y = layers.apply_mlp(cfg, _split(p, "mlp"), h)
        else:
            y, aux = moe.apply_moe(cfg, _split(p, "mlp"), h)
        x = x + y
    return x, new_cache, aux


def _shard_activations(x, cfg=None):
    """Anchor [B, S, d] activations: batch over the strategy's data axes."""
    from repro.parallel.sharding import maybe_shard

    axes = ("pod", "data", "model") if cfg is not None and cfg.strategy == "zero3" \
        else ("pod", "data")
    return maybe_shard(x, axes)


def _remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    policy = None
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint(fn, policy=policy)


# --------------------------------------------------------------------------
# Forward (train / eval)
# --------------------------------------------------------------------------


def forward(cfg: ModelConfig, params: dict, tokens, *, context=None):
    """tokens [B, S] -> (logits [B, S, V], moe_aux scalar).

    ``context`` [B, T, d]: image-patch / audio-frame stub embeddings for
    cross-attention families (encoded first if the config has an encoder).
    """
    x = layers.embed_tokens(cfg, params["embed"], tokens)
    x = _shard_activations(x, cfg)
    positions = jnp.arange(tokens.shape[1])[None, :]

    if cfg.n_encoder_layers:
        context = encode(cfg, params, context)

    def unit_step(carry, unit_params):
        x, aux = carry
        for pos, blk in enumerate(cfg.unit):
            x, _, a = _apply_block(cfg, blk, unit_params[pos], x,
                                   positions=positions, context=context)
            aux = aux + a
        return (x, aux), None

    (x, aux), _ = jax.lax.scan(
        _remat(cfg, unit_step), (x, jnp.zeros((), jnp.float32)), params["units"],
        unroll=cfg.analysis_unroll,
    )
    x = layers.rmsnorm(x, params["final_norm"]["scale"], cfg.norm_eps)
    return layers.logits_from_hidden(cfg, params["embed"], x), aux


def encode(cfg: ModelConfig, params: dict, frames):
    """Bidirectional encoder over stub frame embeddings [B, T, d]."""
    from .config import BlockSpec

    enc_cfg = _encoder_view(cfg)
    x = frames.astype(cfg.dtype)
    positions = jnp.arange(frames.shape[1])[None, :]
    blk = BlockSpec("attn", "dense")

    def unit_step(x, unit_params):
        x, _, _ = _apply_block(enc_cfg, blk, unit_params[0], x, positions=positions)
        return x, None

    x, _ = jax.lax.scan(_remat(cfg, unit_step), x, params["encoder"]["units"],
                        unroll=cfg.analysis_unroll)
    return layers.rmsnorm(x, params["encoder"]["final_norm"]["scale"], cfg.norm_eps)


@functools.lru_cache(maxsize=32)
def _encoder_view_cached(cfg: ModelConfig) -> ModelConfig:
    import dataclasses

    return dataclasses.replace(cfg, causal=False, unit=())


def _encoder_view(cfg):
    return _encoder_view_cached(cfg)


# --------------------------------------------------------------------------
# Serving: cache init / prefill / decode
# --------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int, *, context_len: int = 0):
    """Stacked per-unit-position cache pytree (zeros; ``pos`` tracks fill)."""
    u = cfg.n_units
    cache_units = []
    kv_dt = jnp.dtype(cfg.dtype)
    for blk in cfg.unit:
        if blk.mixer == "attn":
            shape = (u, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
            cache_units.append((jnp.zeros(shape, kv_dt), jnp.zeros(shape, kv_dt)))
        elif blk.mixer == "cross_attn":
            shape = (u, batch, context_len, cfg.n_kv_heads, cfg.head_dim)
            cache_units.append((jnp.zeros(shape, kv_dt), jnp.zeros(shape, kv_dt)))
        elif blk.mixer == "mamba":
            c = mamba.init_mamba_cache(cfg, batch)
            cache_units.append(jax.tree.map(lambda a: jnp.broadcast_to(a, (u, *a.shape)), c))
        elif blk.mixer == "mlstm":
            c = xlstm.init_mlstm_cache(cfg, batch)
            cache_units.append(jax.tree.map(lambda a: jnp.broadcast_to(a, (u, *a.shape)), c))
        elif blk.mixer == "slstm":
            c = xlstm.init_slstm_cache(cfg, batch)
            cache_units.append(jax.tree.map(lambda a: jnp.broadcast_to(a, (u, *a.shape)), c))
        elif blk.mixer == "reservoir":
            n, r = cfg.reservoir_nodes, reservoir_layer._n_channels(cfg)
            cache_units.append(
                (
                    jnp.zeros((u, batch, r, n), jnp.float32),
                    jnp.zeros((u, batch, r), jnp.float32),
                )
            )
        else:
            raise ValueError(blk.mixer)
    return {"pos": jnp.zeros((), jnp.int32), "units": tuple(cache_units)}


def _mixer_cache(blk, unit_cache, pos):
    if blk.mixer == "attn":
        k_buf, v_buf = unit_cache
        return (k_buf, v_buf, pos)
    return unit_cache


def _store_cache(blk, new_cache):
    if blk.mixer == "attn":
        k_buf, v_buf, _idx = new_cache
        return (k_buf, v_buf)
    return new_cache


def _forward_cached(cfg, params, cache, tokens, *, context=None):
    """Shared prefill/decode body: runs [B, S] tokens through cached blocks."""
    x = layers.embed_tokens(cfg, params["embed"], tokens)
    x = _shard_activations(x, cfg)
    pos0 = cache["pos"]
    positions = pos0 + jnp.arange(tokens.shape[1])[None, :]
    if cfg.n_encoder_layers and context is not None:
        context = encode(cfg, params, context)

    def unit_step(carry, xs):
        x = carry
        unit_params, unit_cache = xs
        new_unit_cache = []
        for pos, blk in enumerate(cfg.unit):
            blk_cache = _mixer_cache(blk, unit_cache[pos], pos0)
            if blk.mixer == "cross_attn" and context is not None:
                # Prefill: compute the context kv once and store it.
                mp = _split(unit_params[pos], "mixer")
                blk_cache = layers.context_kv(cfg, mp, context)
            x, nc, _ = _apply_block(cfg, blk, unit_params[pos], x,
                                    positions=positions, cache=blk_cache)
            new_unit_cache.append(_store_cache(blk, nc))
        return x, tuple(new_unit_cache)

    x, new_units = jax.lax.scan(unit_step, x, (params["units"], cache["units"]),
                                unroll=cfg.analysis_unroll)
    x = layers.rmsnorm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = layers.logits_from_hidden(cfg, params["embed"], x)
    new_cache = {"pos": pos0 + tokens.shape[1], "units": new_units}
    return logits, new_cache


def prefill(cfg: ModelConfig, params: dict, tokens, *, max_len: int, context=None):
    cache = init_cache(
        cfg,
        tokens.shape[0],
        max_len,
        context_len=(context.shape[1] if context is not None else 0),
    )
    return _forward_cached(cfg, params, cache, tokens, context=context)


def decode_step(cfg: ModelConfig, params: dict, cache, tokens):
    """One decode step: tokens [B, 1] + cache -> (logits [B, 1, V], cache)."""
    return _forward_cached(cfg, params, cache, tokens)
