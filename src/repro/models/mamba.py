"""Mamba (S6 selective-state-space) sequence mixer — Jamba's non-attention
layers [Lieber et al., arXiv:2403.19887; Gu & Dao, arXiv:2312.00752].

Train path: the selective scan h_t = Ā_t·h_{t-1} + B̄_t·x_t is evaluated
with ``jax.lax.associative_scan`` over the sequence axis (elementwise affine
maps compose associatively) — O(log S) depth, TPU-native, no custom kernel
needed since the op is bandwidth-bound elementwise work XLA fuses well.

Decode path: O(1) per token with carried (conv window, h) state.

The expanded inner dim (d_in = expand·d_model) carries the "mlp" logical
axis, so TP shards the scan across devices with no cross-device coupling
(state is diagonal over d_in).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def _dt_rank(cfg) -> int:
    return max(16, cfg.d_model // 16)


def mamba_defs(cfg) -> dict:
    d = cfg.d_model
    d_in = d * cfg.mamba_expand
    n = cfg.mamba_d_state
    r = _dt_rank(cfg)

    def a_log_init(_k, shape):
        # S4D-real initialisation: A = -(1..n) per channel.
        return jnp.log(jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), shape))

    return {
        "in_proj": ((d, 2 * d_in), ("embed", "mlp"), "fan_in"),
        "conv_w": ((cfg.mamba_d_conv, d_in), (None, "mlp"), "fan_in"),
        "conv_b": ((d_in,), ("mlp",), "zeros"),
        "x_proj": ((d_in, r + 2 * n), ("mlp", None), "fan_in"),
        "dt_proj": ((r, d_in), (None, "mlp"), "fan_in"),
        "dt_bias": ((d_in,), ("mlp",), lambda _k, s: jnp.full(s, math.log(math.e - 1) - 2.0)),
        "a_log": ((d_in, n), ("mlp", None), a_log_init),
        "d_skip": ((d_in,), ("mlp",), "ones"),
        "out_proj": ((d_in, d), ("mlp", "embed"), "fan_in"),
    }


def _ssm_inputs(cfg, p, xc):
    """Shared by train/decode: per-step discretised (dA, dBx, C, D·x).

    xc [B, S, d_in] (post-conv, post-silu) -> dA [B,S,d_in,N], dBx same, c [B,S,N].
    """
    n = cfg.mamba_d_state
    r = _dt_rank(cfg)
    proj = xc @ p["x_proj"].astype(xc.dtype)                  # [B,S,r+2N]
    dt_in, b_ssm, c_ssm = jnp.split(proj, [r, r + n], axis=-1)
    dt = jax.nn.softplus(
        (dt_in @ p["dt_proj"].astype(xc.dtype)).astype(jnp.float32)
        + p["dt_bias"]
    )                                                         # [B,S,d_in] f32
    a = -jnp.exp(p["a_log"])                                  # [d_in, N] f32
    da = jnp.exp(dt[..., None] * a)                           # [B,S,d_in,N]
    # dt·x [B,S,d_in] outer-product B̄ [B,S,N] -> [B,S,d_in,N]
    dbx = (dt * xc.astype(jnp.float32))[..., None] * b_ssm.astype(jnp.float32)[..., None, :]
    return da, dbx, c_ssm.astype(jnp.float32)


def apply_mamba(cfg, p, x, *, cache=None):
    """x [B, S, d]; cache=(conv_state [B, d_conv-1, d_in], h [B, d_in, N]).

    Returns (y [B, S, d], new_cache).  cache=None -> train path (full scan,
    no state returned).
    """
    dt_ = x.dtype
    d_in = cfg.d_model * cfg.mamba_expand
    xz = x @ p["in_proj"].astype(dt_)
    xr, z = jnp.split(xz, 2, axis=-1)                         # [B,S,d_in] each

    # -- causal depthwise conv --------------------------------------------------
    kw = cfg.mamba_d_conv
    if cache is None:
        pad = jnp.zeros((x.shape[0], kw - 1, d_in), dt_)
        xp = jnp.concatenate([pad, xr], axis=1)
    else:
        conv_state, h0 = cache
        xp = jnp.concatenate([conv_state.astype(dt_), xr], axis=1)
    windows = [xp[:, i : i + xr.shape[1], :] for i in range(kw)]
    xc = sum(w * p["conv_w"][i].astype(dt_) for i, w in enumerate(windows))
    xc = jax.nn.silu(xc + p["conv_b"].astype(dt_))

    da, dbx, c_ssm = _ssm_inputs(cfg, p, xc)

    # associative scan over S: (a2, b2) ∘ (a1, b1) = (a2·a1, a2·b1 + b2).
    # The first component accumulates ∏da, which folds in the initial state
    # h0 exactly — the same path serves train (h0 = 0), prefill, and S = 1
    # decode.
    def compose(p1, p2):
        a1, b1 = p1
        a2, b2 = p2
        return a2 * a1, a2 * b1 + b2

    cum_a, hs = jax.lax.associative_scan(compose, (da, dbx), axis=1)
    if cache is None:
        new_cache = None
    else:
        hs = hs + cum_a * h0[:, None]
        new_cache = (xp[:, -(kw - 1):, :].astype(cache[0].dtype), hs[:, -1])

    y = jnp.einsum("bsdn,bsn->bsd", hs, c_ssm).astype(dt_)
    y = y + xc * p["d_skip"].astype(dt_)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"].astype(dt_), new_cache


def init_mamba_cache(cfg, batch: int, dtype=jnp.float32):
    d_in = cfg.d_model * cfg.mamba_expand
    return (
        jnp.zeros((batch, cfg.mamba_d_conv - 1, d_in), dtype),
        jnp.zeros((batch, d_in, cfg.mamba_d_state), jnp.float32),
    )
