"""Training driver: checkpoint/restart, straggler watchdog, failure recovery.

The control loop a real cluster job runs (launch/train.py wires it up):

  * **Restart**: on start, restore the newest intact checkpoint (falling
    back through older ones on integrity failure) and resume from its step —
    the data pipeline is deterministic in (seed, step), so the token stream
    continues exactly where it left off.
  * **Step retry**: a step that raises a transient runtime error is retried
    up to ``max_step_retries`` times from the last known-good state —
    covering preempted hosts and flaky interconnect — before surfacing.
  * **Straggler watchdog**: a monitor thread flags steps exceeding
    ``straggler_factor`` × the rolling median step time (the multi-host
    mitigation is re-spawning the slow host; single-process here, so the
    watchdog records and reports — the hook point is ``on_straggler``).
  * **Elastic re-shard**: checkpoints hold global arrays; restarting with a
    different mesh re-lays them out (checkpoint/store.py).
"""

from __future__ import annotations

import collections
import dataclasses
import logging
import time
from typing import Callable

import jax

from repro.checkpoint.store import CheckpointStore
from repro.data.pipeline import DataConfig, Prefetcher

log = logging.getLogger("repro.trainer")


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: str = "checkpoints"
    keep_checkpoints: int = 3
    max_step_retries: int = 2
    straggler_factor: float = 3.0
    log_every: int = 10


class StragglerWatchdog:
    """Rolling-median step-time monitor."""

    def __init__(self, factor: float, window: int = 32):
        self.factor = factor
        self.times: collections.deque = collections.deque(maxlen=window)
        self.flagged: list[tuple[int, float]] = []

    def observe(self, step: int, dt: float, on_straggler: Callable | None = None):
        if len(self.times) >= 8:
            med = sorted(self.times)[len(self.times) // 2]
            if dt > self.factor * med:
                self.flagged.append((step, dt))
                log.warning("straggler: step %d took %.3fs (median %.3fs)", step, dt, med)
                if on_straggler:
                    on_straggler(step, dt, med)
        self.times.append(dt)


def run_training(
    *,
    step_fn,                      # jitted (state, batch) -> (state, metrics)
    init_state_fn,                # () -> state   (fresh init, already sharded)
    data_cfg: DataConfig,
    loop_cfg: TrainLoopConfig,
    state_sharding=None,          # pytree of Shardings for elastic restore
    on_metrics=None,
    on_straggler=None,
):
    store = CheckpointStore(loop_cfg.checkpoint_dir, keep=loop_cfg.keep_checkpoints)
    watchdog = StragglerWatchdog(loop_cfg.straggler_factor)

    state = init_state_fn()
    start_step = 0
    restored_step, restored = store.restore(state, sharding_tree=state_sharding)
    if restored is not None:
        state, start_step = restored, restored_step
        log.info("restored checkpoint at step %d", start_step)

    prefetch = Prefetcher(data_cfg, start_step=start_step)
    history = []
    try:
        step = start_step
        while step < loop_cfg.total_steps:
            data_step, batch = prefetch.next()
            assert data_step == step, (data_step, step)

            t0 = time.time()
            retries = 0
            while True:
                try:
                    new_state, metrics = step_fn(state, batch)
                    # materialise to surface async runtime failures here
                    metrics = jax.tree.map(lambda x: float(x), jax.device_get(metrics))
                    break
                except (jax.errors.JaxRuntimeError, RuntimeError) as e:  # transient
                    retries += 1
                    if retries > loop_cfg.max_step_retries:
                        raise
                    log.warning("step %d failed (%s); retry %d", step, e, retries)
            state = new_state
            dt = time.time() - t0
            watchdog.observe(step, dt, on_straggler)

            metrics["step"] = step
            metrics["step_time_s"] = dt
            history.append(metrics)
            if on_metrics:
                on_metrics(metrics)
            if loop_cfg.log_every and step % loop_cfg.log_every == 0:
                log.info("step %d loss %.4f (%.2fs)", step, metrics.get("loss", float("nan")), dt)

            step += 1
            if loop_cfg.checkpoint_every and step % loop_cfg.checkpoint_every == 0:
                store.save_async(step, state)
        store.wait()
        store.save(loop_cfg.total_steps, state)
    finally:
        prefetch.close()
    return state, history, watchdog
