"""Runtime: step functions and the fault-tolerant training driver."""

from . import steps, trainer

__all__ = ["steps", "trainer"]
