"""Pure step functions: microbatched training step and serving steps.

``train_step`` is one optimizer step: grad accumulation over
``cfg.microbatches`` (a lax.scan, so activations of one microbatch are live
at a time), global-norm clipping, AdamW, loss metrics.  The launchers wrap
these with jit + in/out shardings (launch/dryrun.py, launch/train.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import decode_step as model_decode
from repro.models import forward, lm_loss, prefill as model_prefill
from repro.optim import AdamWConfig, apply_updates, init_opt_state
from repro.parallel.sharding import maybe_shard


def init_train_state(cfg, key) -> dict:
    from repro.models import init_params

    params = init_params(cfg, key)
    return {
        "params": params,
        "opt": init_opt_state(params),
        "step": jnp.zeros((), jnp.int32),
    }


def loss_fn(cfg, params, batch):
    logits, aux = forward(cfg, params, batch["tokens"], context=batch.get("context"))
    return lm_loss(cfg, logits, batch["labels"], moe_aux=aux)


def train_step(cfg, opt_cfg: AdamWConfig, state: dict, batch: dict):
    """One optimizer step with grad accumulation.

    batch: {"tokens" [B,S], "labels" [B,S], "context"? [B,T,d]} with B =
    cfg.microbatches · per-step batch.
    """
    m = cfg.microbatches
    params = state["params"]

    def microbatch(i, batch):
        # Anchor the per-microbatch batch dim on ("pod","data"): without the
        # constraint GSPMD may shard the microbatch *index* dim of the
        # reshape instead, replicating activations (22 GiB/device observed —
        # EXPERIMENTS.md §Perf).
        axes = ("pod", "data", "model") if cfg.strategy == "zero3" else ("pod", "data")

        def slice_one(x):
            mb = x.reshape(m, -1, *x.shape[1:])[i]
            return maybe_shard(mb, axes)

        return jax.tree.map(slice_one, batch)

    grad_fn = jax.value_and_grad(lambda p, b: loss_fn(cfg, p, b), has_aux=True)

    def accum(carry, i):
        grads, metrics_sum = carry
        (loss, metrics), g = grad_fn(params, microbatch(i, batch))
        grads = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), grads, g)
        metrics_sum = jax.tree.map(lambda a, b: a + b, metrics_sum, metrics)
        return (grads, metrics_sum), None

    zero_grads = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    zero_metrics = {
        "loss": jnp.zeros((), jnp.float32),
        "ce": jnp.zeros((), jnp.float32),
        "z_loss": jnp.zeros((), jnp.float32),
        "moe_aux": jnp.zeros((), jnp.float32),
        "tokens": jnp.zeros((), jnp.float32),
    }
    (grads, metrics), _ = jax.lax.scan(accum, (zero_grads, zero_metrics), jnp.arange(m),
                                       unroll=cfg.analysis_unroll)
    grads = jax.tree.map(lambda g: g / m, grads)
    metrics = jax.tree.map(lambda x: x / m, metrics)
    metrics["tokens"] = metrics["tokens"] * m

    params, opt, opt_metrics = apply_updates(opt_cfg, params, state["opt"], grads, state["step"])
    metrics.update(opt_metrics)
    new_state = {"params": params, "opt": opt, "step": state["step"] + 1}
    return new_state, metrics


def serve_prefill(cfg, params, tokens, context=None, *, max_len: int | None = None):
    """Prefill: returns (last-position logits [B, V], cache)."""
    max_len = max_len or tokens.shape[1]
    logits, cache = model_prefill(cfg, params, tokens, max_len=max_len, context=context)
    return logits[:, -1, :], cache


def serve_decode(cfg, params, cache, tokens):
    """One decode step: (logits [B, V], new cache)."""
    logits, cache = model_decode(cfg, params, cache, tokens)
    return logits[:, -1, :], cache
