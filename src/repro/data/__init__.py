"""Data: deterministic host-sharded synthetic streams + the paper's tasks."""

from .pipeline import DataConfig, Prefetcher, host_batch

__all__ = ["DataConfig", "Prefetcher", "host_batch"]
