"""Deterministic, host-sharded synthetic data pipeline.

Production framing: every host generates only its own shard of the global
batch (``host_slice``), deterministically from (seed, step), so a restarted
or re-sharded job regenerates identical batches with zero coordination —
the same property a tfds/grain pipeline provides via per-step index files.
A background prefetch thread keeps ``depth`` batches ready.

Two sources:
  * ``lm_synthetic``  — structured pseudo-text: a mixture of Zipfian unigrams
    and a repeated-ngram process, so models have learnable signal (loss
    decreases) without any external corpus.
  * ``dfrc_tasks``    — the paper's time-series tasks, re-exported from
    repro.core.tasks for the reservoir examples.
"""

from __future__ import annotations

import dataclasses
import queue
import threading

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3
    ngram_repeat: float = 0.7   # prob of copying from `lag` tokens back
    lag: int = 64
    n_hosts: int = 1
    host_id: int = 0


def _batch_rng(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence(entropy=(cfg.seed, step, cfg.host_id))
    )


def host_batch(cfg: DataConfig, step: int) -> dict:
    """Generate this host's slice of batch ``step``: {tokens, labels}.

    Labels are next-token targets (shift-by-one of the same stream); the
    trainer's loss needs no extra shifting.
    """
    if cfg.global_batch % cfg.n_hosts:
        raise ValueError("global_batch must divide evenly across hosts")
    b_local = cfg.global_batch // cfg.n_hosts
    rng = _batch_rng(cfg, step)
    s = cfg.seq_len + 1

    # Zipfian unigrams (clipped to vocab), then ngram-copy persistence.
    toks = rng.zipf(cfg.zipf_a, size=(b_local, s)) % cfg.vocab_size
    copy = rng.random((b_local, s)) < cfg.ngram_repeat
    copy[:, : cfg.lag] = False
    shifted = np.roll(toks, cfg.lag, axis=1)
    toks = np.where(copy, shifted, toks).astype(np.int32)

    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class Prefetcher:
    """Background thread producing host batches ``depth`` steps ahead."""

    def __init__(self, cfg: DataConfig, start_step: int = 0, depth: int = 2):
        self.cfg = cfg
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = host_batch(self.cfg, step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self) -> tuple[int, dict]:
        return self._q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2.0)
