"""Entry-point registry: every compiled hot path, with its contract set.

Each entry point is a builder that constructs the callable on *tiny* shapes
(tracing cost only — nothing executes) and declares the rules the program
must satisfy.  `python -m repro.analysis` traces them all; tests and CI
treat a violation as a broken structural claim, the same way a failing
parity test is a broken numerical claim.

Registering a new entry point (DESIGN.md §11): write a builder that closes
over static config and returns ``(Program, rules)``, decorate it with
``@register(name, description)``.  Keep shapes minimal — the walker scales
with program size, and the properties being checked are shape-generic.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .rules import (DonationHonored, MaxPallasCalls, MaxScans, NoDtypeAbove,
                    NoHostCallback, NoSilentUpcast, NoStateTensor, Program,
                    VmemBudget)

# Tiny trace shapes shared by the pipeline entries.
_B, _N, _T_TR, _T_TE, _CHUNK, _W0 = 2, 16, 96, 64, 32, 16
_LAMS = (1e-6, 1e-4)


@dataclasses.dataclass(frozen=True)
class EntryPoint:
    name: str
    description: str
    build: object          # () -> (Program, tuple[Rule, ...])


ENTRY_POINTS = {}


def register(name: str, description: str):
    def deco(fn):
        ENTRY_POINTS[name] = EntryPoint(name, description, fn)
        return fn
    return deco


def _padded_f(n_nodes: int) -> int:
    """Feature count padded to the Gram kernel's 128-lane tile."""
    return -(-(n_nodes + 1) // 128) * 128


def _experiment_setup(**cfg_kw):
    from repro.pipeline import Experiment, ExperimentConfig
    from repro.core import SiliconMR
    base = dict(model=SiliconMR(), n_nodes=_N, washout=_W0, ridge_l2=_LAMS,
                state_noise_rel=0.0)
    base.update(cfg_kw)
    cfg = ExperimentConfig(**base)
    mask = Experiment(cfg).mask
    args = (jnp.zeros((_B, _T_TR), jnp.float32),
            jnp.zeros((_B, _T_TR), jnp.float32),
            jnp.zeros((_B, _T_TE), jnp.float32),
            jnp.zeros((_B, _T_TE), jnp.float32))
    return cfg, mask, args


def _pipeline_program(name, **cfg_kw):
    from repro.pipeline.experiment import _run_pipeline
    cfg, mask, args = _experiment_setup(**cfg_kw)
    return Program(lambda a, b, c, d: _run_pipeline(cfg, mask, a, b, c, d),
                   args, name=name)


@register("experiment_ref",
          "Experiment pipeline, reference reservoir, jnp readout")
def _experiment_ref():
    prog = _pipeline_program("experiment_ref", state_method="ref",
                             readout_use_kernel=False)
    return prog, (NoHostCallback(), NoDtypeAbove("float32"),
                  MaxPallasCalls(0))


@register("experiment_fast",
          "Experiment pipeline, vectorised jnp reservoir, jnp readout")
def _experiment_fast():
    prog = _pipeline_program("experiment_fast", state_method="fast",
                             readout_use_kernel=False)
    return prog, (NoHostCallback(), NoDtypeAbove("float32"),
                  MaxPallasCalls(0))


@register("experiment_kernel",
          "Experiment pipeline, materialized Pallas path (dfr_scan + Gram)")
def _experiment_kernel():
    prog = _pipeline_program("experiment_kernel", state_method="kernel",
                             readout_use_kernel=True)
    # train dfr_scan + test dfr_scan + one batched Gram launch
    return prog, (NoHostCallback(), NoDtypeAbove("float32"),
                  MaxPallasCalls(3), VmemBudget())


@register("experiment_streaming",
          "Experiment pipeline, streamed fit + eval (no [B,T,N] tensor)")
def _experiment_streaming():
    prog = _pipeline_program("experiment_streaming", state_method="kernel",
                             readout_use_kernel=True, stream_chunk_k=_CHUNK)
    rules = (NoHostCallback(), NoDtypeAbove("float32"),
             MaxScans(2),               # one fit scan + one eval scan
             VmemBudget(),
             NoStateTensor(_T_TR, _B * _T_TR * _N, what="train state tensor"),
             NoStateTensor(_T_TE, _B * _T_TE * _N, what="test state tensor"))
    return prog, rules


def _streaming_fit_program(name, *, wdm=False, state_dtype=None):
    from repro.core import SiliconMR, make_mask
    from repro.pipeline import fit_ridge_streaming, fit_ridge_streaming_wdm
    model = SiliconMR()
    kw = dict(washout=_W0, chunk_k=_CHUNK, lambdas=_LAMS,
              state_method="kernel", use_kernel=True)
    if state_dtype is not None:
        kw["state_dtype"] = state_dtype
    j = jnp.zeros((_B, _T_TR), jnp.float32)
    y = jnp.zeros((_B, _T_TR), jnp.float32)
    if wdm:
        masks = jnp.stack([make_mask(_N, seed=30 + i) for i in range(_B)])
        fn = lambda jj, yy: fit_ridge_streaming_wdm(model, masks, jj, yy, **kw)
    else:
        mask = make_mask(_N, seed=1)
        fn = lambda jj, yy: fit_ridge_streaming(model, mask, jj, yy, **kw)
    return Program(fn, (j, y), name=name)


def _streaming_fit_rules():
    return (NoHostCallback(), NoDtypeAbove("float32"),
            MaxScans(1), MaxPallasCalls(2),        # dfr_scan + Gram per chunk
            VmemBudget(),
            NoStateTensor(_T_TR, _B * _T_TR * _N, what="full-stream tensor"),
            DonationHonored(min_pallas_aliases=2))  # accumulate-into Gram


@register("fit_ridge_streaming",
          "Streamed ridge fit: ONE chunk scan, accumulate-into Gram")
def _fit_ridge_streaming():
    return (_streaming_fit_program("fit_ridge_streaming"),
            _streaming_fit_rules())


@register("fit_ridge_streaming_bf16",
          "Streamed ridge fit with bf16 state chunks (no silent f32 chunk)")
def _fit_ridge_streaming_bf16():
    from repro.kernels.dfr_scan import padded_lanes
    prog = _streaming_fit_program("fit_ridge_streaming_bf16",
                                  state_dtype="bfloat16")
    # The f32 final-state carry [B, N] and the lane-padded input chunk
    # (O(B_pad·chunk), no node axis) are legitimate; a wide block at
    # state-chunk scale — padded-batch × chunk × nodes — is not.
    floor = padded_lanes(_B) * _CHUNK * _N
    rules = _streaming_fit_rules() + (
        NoSilentUpcast(_CHUNK, floor),)
    return prog, rules


@register("fit_ridge_streaming_wdm",
          "WDM streamed fit: all channels in ONE launch pair per chunk")
def _fit_ridge_streaming_wdm():
    return (_streaming_fit_program("fit_ridge_streaming_wdm", wdm=True),
            _streaming_fit_rules())


# Device-physics entries (DESIGN.md §14): the CMT cavity's sub-stepped tick
# integration must hold the SAME structural contracts as the closed-form
# models — the substeps unroll inside the node update, so every rule that
# held for SiliconMR must hold verbatim with MRCavityCMT swapped in.
def _cmt_model():
    from repro.core import SiliconMR
    from repro.devices import calibrated_twin
    return calibrated_twin(SiliconMR(), power_mw=1.0)


@register("experiment_cmt_kernel",
          "CMT-cavity pipeline through the Pallas dfr_scan (substeps in-tile)")
def _experiment_cmt_kernel():
    prog = _pipeline_program("experiment_cmt_kernel", model=_cmt_model(),
                             state_method="kernel", readout_use_kernel=True)
    # identical launch budget to experiment_kernel: the substep loop unrolls
    # inside the node update — richer physics may not add launches
    return prog, (NoHostCallback(), NoDtypeAbove("float32"),
                  MaxPallasCalls(3), VmemBudget())


def _device_sweep_program(name, *, state_dtype="float32", use_kernel=False):
    from repro.devices import CMTSweepParams
    from repro.pipeline.experiment import _run_pipeline
    cfg, mask, args = _experiment_setup(
        model=_cmt_model(), state_method="fast", stream_chunk_k=_CHUNK,
        stream_state_dtype=state_dtype, readout_use_kernel=use_kernel)
    lanes = (jnp.zeros((_B,), jnp.float32),    # detune
             jnp.ones((_B,), jnp.float32),     # loss_scale
             jnp.ones((_B,), jnp.float32))     # power
    fn = lambda a, b, c, d, pd, pl, pp: _run_pipeline(
        cfg, mask, a, b, c, d, dev_params=CMTSweepParams(pd, pl, pp))
    return Program(fn, args + lanes, name=name)


# The swept map runs on the jnp fast path (the kernel keeps static models),
# so the scan budget is its true nesting: fit and eval each run the chunk
# scan -> per-chunk period scan -> in-period node chain scan.
_SWEEP_SCANS = 6


@register("device_sweep",
          "Swept-params CMT robustness map: grid as lanes, ONE streamed trace")
def _device_sweep():
    prog = _device_sweep_program("device_sweep")
    rules = (NoHostCallback(), NoDtypeAbove("float32"),
             MaxScans(_SWEEP_SCANS),
             MaxPallasCalls(0),         # jnp state + einsum Gram throughout
             NoStateTensor(_T_TR, _B * _T_TR * _N, what="train state tensor"),
             NoStateTensor(_T_TE, _B * _T_TE * _N, what="test state tensor"))
    return prog, rules


@register("device_sweep_bf16",
          "Swept CMT map, bf16 state chunks (no silent f32 chunk upcast)")
def _device_sweep_bf16():
    prog = _device_sweep_program("device_sweep_bf16", state_dtype="bfloat16",
                                 use_kernel=True)
    # In-scan state *compute* is f32 by design on the jnp path (only the
    # emitted chunk narrows — generate_states docstring), so the [B, chunk,
    # N] block and its period-scan transpose are declared benign.  Anything
    # else wide at chunk scale — e.g. a silently re-promoted [B, chunk, N+1]
    # feature block downstream of the bf16 chunk — still trips.
    benign = ((_B, _CHUNK, _N), (_CHUNK, _B, _N))
    rules = (NoHostCallback(), NoDtypeAbove("float32"),
             MaxScans(_SWEEP_SCANS), VmemBudget(),
             NoStateTensor(_T_TR, _B * _T_TR * _N, what="train state tensor"),
             NoStateTensor(_T_TE, _B * _T_TE * _N, what="test state tensor"),
             NoSilentUpcast(_CHUNK, _B * _CHUNK * _N, benign_shapes=benign))
    return prog, rules


# Composed-graph trace shapes: a depth-3 chain whose smallest stage sets the
# NoStateTensor floor — ANY stage materializing its full-T [B·L, T, N] block
# (the smallest is _B·_T_TR·8 elements) trips the rule, while the O(B·T)
# input/target streams stay well under it.
def _trace_graph(depth: int):
    from repro.core import ReservoirStage, SiliconMR, chain
    stages = [ReservoirStage(model=SiliconMR(), n_nodes=_N, loops=2,
                             mask_seed=1),
              ReservoirStage(model=SiliconMR(), n_nodes=_N, mask_seed=7),
              ReservoirStage(model=SiliconMR(), n_nodes=8, mask_seed=13,
                             link="sin2")]
    return chain(*stages[-depth:])


@register("fit_ridge_streaming_composed",
          "Composed depth-3 streamed fit: stage chain per chunk, ONE scan")
def _fit_ridge_streaming_composed():
    from repro.core import build_stage_masks
    from repro.pipeline import fit_ridge_streaming_composed
    graph = _trace_graph(3)
    masks = build_stage_masks(graph)
    kw = dict(washout=_W0, chunk_k=_CHUNK, lambdas=_LAMS,
              state_method="kernel", use_kernel=True)
    j = jnp.zeros((_B, _T_TR), jnp.float32)
    prog = Program(lambda jj, yy: fit_ridge_streaming_composed(
        graph, masks, jj, yy, **kw), (j, j),
        name="fit_ridge_streaming_composed")
    w_min = min(st.n_nodes for st in graph.stages)
    rules = (NoHostCallback(), NoDtypeAbove("float32"),
             MaxScans(1),                          # the whole chain, one scan
             MaxPallasCalls(graph.depth + 1),      # one dfr_scan/stage + Gram
             VmemBudget(),
             NoStateTensor(_T_TR, _B * _T_TR * w_min,
                           what="full-stream stage tensor"),
             DonationHonored(min_pallas_aliases=2))
    return prog, rules


@register("fit_ridge_streaming_shared",
          "Shared-readout WDM fit: one cross-channel Gram, ONE launch pair")
def _fit_ridge_streaming_shared():
    from repro.core import SiliconMR, make_mask
    from repro.pipeline import fit_ridge_streaming_shared
    model = SiliconMR()
    masks = jnp.stack([make_mask(_N, seed=40 + i) for i in range(_B)])
    kw = dict(washout=_W0, chunk_k=_CHUNK, lambdas=_LAMS,
              state_method="kernel", use_kernel=True)
    j = jnp.zeros((_B, _T_TR), jnp.float32)
    y = jnp.zeros((_T_TR,), jnp.float32)
    prog = Program(lambda jj, yy: fit_ridge_streaming_shared(
        model, masks, jj, yy, **kw), (j, y),
        name="fit_ridge_streaming_shared")
    return prog, _streaming_fit_rules()


@register("experiment_composed",
          "Depth-2 composed Experiment: streamed fit + eval, no stage tensor")
def _experiment_composed():
    graph = _trace_graph(2)
    prog = _pipeline_program("experiment_composed", state_method="kernel",
                             readout_use_kernel=True, stream_chunk_k=_CHUNK,
                             topology=graph)
    w_min = min(st.n_nodes for st in graph.stages)
    rules = (NoHostCallback(), NoDtypeAbove("float32"),
             MaxScans(2),                          # one fit scan + one eval scan
             # fit: one dfr_scan per stage + Gram; eval: one dfr_scan per stage
             MaxPallasCalls(2 * graph.depth + 1),
             VmemBudget(),
             NoStateTensor(_T_TR, _B * _T_TR * w_min,
                           what="train stage tensor"),
             NoStateTensor(_T_TE, _B * _T_TE * w_min,
                           what="test stage tensor"))
    return prog, rules


def _session_program(name, *, refresh, donate=False, **cfg_kw):
    from repro.core import make_mask
    from repro.pipeline.session import (SessionConfig, _session_step,
                                        session_init)
    cfg = SessionConfig(n_nodes=_N, chunk_k=_CHUNK, **cfg_kw)
    mask = make_mask(cfg.n_nodes, seed=0)
    state = session_init(cfg, _B)
    z = jnp.zeros((_B, _CHUNK), jnp.float32)
    fn = lambda st, jc, yc: _session_step(cfg, mask, st, jc, yc,
                                          refresh=refresh)
    return Program(fn, (state, z, z), name=name,
                   donate_argnums=(0,) if donate else ())


_SESSION_RULES = (NoHostCallback(), NoDtypeAbove("float32"),
                  NoStateTensor(4096, _B * 4096 * _N,
                                what="full-stream tensor"))


@register("session_step", "Online session tick (carry + Gram fold)")
def _session_step_entry():
    return _session_program("session_step", refresh=False), _SESSION_RULES


@register("session_step_refresh",
          "Online session tick with in-graph weight refresh (GCV solve)")
def _session_step_refresh():
    return (_session_program("session_step_refresh", refresh=True),
            _SESSION_RULES)


@register("session_step_kernel",
          "Online session tick on the Pallas path (one launch pair)")
def _session_step_kernel():
    prog = _session_program("session_step_kernel", refresh=False,
                            state_method="kernel", use_kernel=True)
    return prog, _SESSION_RULES + (MaxPallasCalls(2), VmemBudget(),
                                   DonationHonored(min_pallas_aliases=2))


@register("serve_dfr_step",
          "DFRServer donated step: the SessionState slab updates in place")
def _serve_dfr_step():
    prog = _session_program("serve_dfr_step", refresh=True, donate=True,
                            forgetting=0.99)
    # All 10 SessionState leaves (incl. the quarantined/poison health
    # bookkeeping) must come back donated in the lowered program — a
    # silently dropped donation doubles the serving slab.
    return prog, _SESSION_RULES + (DonationHonored(),)


def _faulted_program(name, *, refresh, donate=False, **cfg_kw):
    from repro.core import make_mask
    from repro.pipeline.session import SessionConfig, session_init
    from repro.robustness.faults import faulty_session_step, no_faults
    cfg = SessionConfig(n_nodes=_N, chunk_k=_CHUNK, **cfg_kw)
    mask = make_mask(cfg.n_nodes, seed=0)
    state = session_init(cfg, _B)
    spec = no_faults(_B)
    z = jnp.zeros((_B, _CHUNK), jnp.float32)
    tick = jnp.int32(0)
    fn = lambda sp, st, jc, yc, t: faulty_session_step(
        cfg, mask, sp, st, jc, yc, t, refresh=refresh)
    return Program(fn, (spec, state, z, z, tick), name=name,
                   donate_argnums=(1,) if donate else ())


@register("session_step_faulted",
          "Fault-injected session tick: injections + quarantine in-graph")
def _session_step_faulted():
    # Same contract set as the clean tick: fault models are traced operand
    # transforms (repro.robustness), never host callbacks or new tensors.
    # The uint32 PRNG key is integer data — NoDtypeAbove only constrains
    # inexact dtypes.
    return (_faulted_program("session_step_faulted", refresh=True),
            _SESSION_RULES)


@register("session_step_faulted_kernel",
          "Fault-injected session tick, Pallas path (still one launch pair)")
def _session_step_faulted_kernel():
    prog = _faulted_program("session_step_faulted_kernel", refresh=False,
                            donate=True, state_method="kernel",
                            use_kernel=True)
    return prog, _SESSION_RULES + (MaxPallasCalls(2), VmemBudget(),
                                   DonationHonored(min_pallas_aliases=2))


@register("reservoir_lm_train_step",
          "reservoir_lm train step (grad-accum scan, donated TrainState)")
def _reservoir_lm_train_step():
    from repro.configs import smoke_config
    from repro.optim import AdamWConfig
    from repro.runtime.steps import init_train_state, train_step
    cfg = smoke_config("reservoir_lm")
    opt = AdamWConfig()
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    b, s = 2 * max(1, cfg.microbatches), 16
    batch = {"tokens": jnp.zeros((b, s), jnp.int32),
             "labels": jnp.zeros((b, s), jnp.int32)}
    prog = Program(lambda st, bt: train_step(cfg, opt, st, bt),
                   (state, batch), name="reservoir_lm_train_step",
                   donate_argnums=(0,))
    return prog, (NoHostCallback(), NoDtypeAbove("float32"),
                  MaxPallasCalls(0), DonationHonored())


def seeded_violation_entry() -> EntryPoint:
    """A deliberately violating entry (materialized [B, T, N] state tensor
    under `NoStateTensor`) — CI runs it to prove the gate exits nonzero."""
    def build():
        from repro.core import SiliconMR, make_mask
        from repro.core.reservoir import generate_states
        from repro.pipeline import fit_ridge_batched
        model = SiliconMR()
        mask = make_mask(_N, seed=1)

        def fit(j, y):
            st = generate_states(model, j, mask, method="fast")
            return fit_ridge_batched(st[:, _W0:], y[:, _W0:], lambdas=_LAMS,
                                     use_kernel=False)

        j = jnp.zeros((_B, _T_TR), jnp.float32)
        prog = Program(fit, (j, j), name="seeded_violation")
        return prog, (NoStateTensor(_T_TR, _B * _T_TR * _N),)
    return EntryPoint("seeded_violation",
                      "Deliberate NoStateTensor violation (gate self-test)",
                      build)


def entry_point_names() -> list:
    return sorted(ENTRY_POINTS)


def get_entry_points(names=None, *, include_seeded=False) -> list:
    """Resolve ``names`` (None = all registered) to EntryPoint objects."""
    eps = dict(ENTRY_POINTS)
    if include_seeded:
        seeded = seeded_violation_entry()
        eps[seeded.name] = seeded
    if names is None:
        return [eps[n] for n in sorted(eps)]
    missing = [n for n in names if n not in eps]
    if missing:
        raise KeyError(f"unknown entry point(s) {missing}; "
                       f"known: {sorted(eps)}")
    return [eps[n] for n in names]
