"""Declarative program contracts over traced jaxprs (DESIGN.md §11).

A `Program` wraps one entry point — a callable over array-only positional
arguments — and lazily produces the artifacts the rules inspect: the traced
``ClosedJaxpr``, the flat `Intermediate` records with provenance, and (for
donation checks) the lowered StableHLO text.  A `Rule` looks at a Program
and returns `Violation`s; an empty list means the contract holds.  Rules
never execute the program: everything is static, which is what makes the
checks trustworthy on the CPU/interpret-mode dev loop — they pin properties
of the *lowered program*, not of one backend's runtime behaviour.

The built-in catalog covers the repo's load-bearing claims:

- `NoStateTensor`   — the streaming paths never materialize [B, T, N]
- `MaxScans` / `MaxPallasCalls` — one chunk scan, one launch pair per chunk
- `NoDtypeAbove`    — no accidental f64 promotion in a hot path
- `NoSilentUpcast`  — bf16 chunk paths don't re-materialize f32 chunks
- `DonationHonored` — donate_argnums / input_output_aliases survive lowering
- `NoHostCallback`  — no host round-trips inside jitted hot paths
- `VmemBudget`      — per-pallas_call VMEM estimate + tile-alignment check
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .walker import (Intermediate, count_pallas_calls, count_scans, eqn_paths,
                     intermediate_records, pallas_eqns, state_tensor_records,
                     trace_jaxpr, walk_eqns_with_path)

VMEM_BYTES = 16 * 2 ** 20      # v4/v5 VMEM per core; override per rule

# Primitives that round-trip through the host from inside a jitted program.
CALLBACK_PRIMS = frozenset({
    "pure_callback", "io_callback", "debug_callback", "debug_print",
    "host_callback_call", "outside_call",
})


@dataclasses.dataclass(frozen=True)
class Violation:
    """One broken contract, with enough provenance to find the culprit."""

    rule: str
    message: str
    path: tuple = ()            # enclosing primitive names, outermost first
    shape: tuple = None
    dtype: str = None

    def as_dict(self) -> dict:
        d = {"rule": self.rule, "message": self.message,
             "path": list(self.path)}
        if self.shape is not None:
            d["shape"] = [int(s) for s in self.shape]
        if self.dtype is not None:
            d["dtype"] = self.dtype
        return d

    def __str__(self) -> str:
        where = "/".join(self.path) or "<top>"
        return f"[{self.rule}] {self.message} (at {where})"


def _rec_violation(rule: str, message: str, rec: Intermediate) -> Violation:
    return Violation(rule=rule, message=message, path=rec.path + (rec.prim,),
                     shape=rec.shape, dtype=rec.dtype)


class Program:
    """One analyzable entry point: a callable + example (array) arguments.

    ``fn`` must take array-only positional arguments — registry builders
    close over static configuration (configs, masks, flags) so the traced
    signature is purely arrays.  ``donate_argnums`` mirrors how the serving /
    training code jits the same callable; `DonationHonored` lowers with it
    and checks the aliasing actually survives into StableHLO.
    """

    def __init__(self, fn, args, *, donate_argnums=(), name: str = ""):
        self.fn = fn
        self.args = tuple(args)
        self.donate_argnums = tuple(donate_argnums)
        self.name = name
        self._closed_jaxpr = None
        self._records = None
        self._lowered_text = None

    @property
    def closed_jaxpr(self):
        if self._closed_jaxpr is None:
            self._closed_jaxpr = trace_jaxpr(self.fn, *self.args)
        return self._closed_jaxpr

    @property
    def records(self) -> list:
        if self._records is None:
            self._records = intermediate_records(self.closed_jaxpr)
        return self._records

    @property
    def lowered_text(self) -> str:
        """StableHLO of ``jit(fn, donate_argnums=...)`` — donation metadata
        (``tf.aliasing_output`` argument attributes) is only visible here,
        never in the jaxpr.  ``keep_unused=True``: jit otherwise prunes
        donated-but-unused leaves (e.g. a SessionState field the refresh
        path recomputes) from the lowered signature, which would make the
        aliasing count undercount legitimately-donated buffers."""
        if self._lowered_text is None:
            jitted = jax.jit(self.fn, donate_argnums=self.donate_argnums,
                             keep_unused=True)
            self._lowered_text = jitted.lower(*self.args).as_text()
        return self._lowered_text

    def donated_leaf_count(self) -> int:
        return sum(len(jax.tree_util.tree_leaves(self.args[i]))
                   for i in self.donate_argnums)


class Rule:
    """Base contract: ``check(program)`` returns a list of `Violation`s."""

    name = "Rule"

    def check(self, program: Program) -> list:
        raise NotImplementedError

    def describe(self) -> str:
        return self.name


class NoStateTensor(Rule):
    """No intermediate carries the stream axis at state-tensor scale.

    ``t_len`` is the stream length; ``min_elems`` the element floor that
    separates a state tensor from the O(B·T) input streams; ``benign_shapes``
    are dim-multiset templates for structurally-known blocks whose axes
    *happen* to equal ``t_len`` (walker.state_tensor_records).  ``max_bytes``
    turns the rule from "must not exist" (0, the default) into a budget —
    used for the peak live chunk block of streamed programs.
    """

    name = "NoStateTensor"

    def __init__(self, t_len: int, min_elems: int, *, benign_shapes=(),
                 max_bytes: int = 0, what: str = "state tensor"):
        self.t_len = int(t_len)
        self.min_elems = int(min_elems)
        self.benign_shapes = tuple(tuple(s) for s in benign_shapes)
        self.max_bytes = int(max_bytes)
        self.what = what

    def describe(self) -> str:
        bound = f"<= {self.max_bytes}B" if self.max_bytes else "none"
        return (f"{self.name}(t_len={self.t_len}, "
                f"min_elems={self.min_elems}, {bound})")

    def check(self, program: Program) -> list:
        out = []
        for rec in state_tensor_records(program.closed_jaxpr, self.t_len,
                                        self.min_elems,
                                        benign_shapes=self.benign_shapes):
            if rec.nbytes > self.max_bytes:
                out.append(_rec_violation(
                    self.name,
                    f"{self.what} {rec.shape} {rec.dtype} = {rec.nbytes}B "
                    f"carries the t_len={self.t_len} axis above "
                    f"{self.max_bytes}B", rec))
        return out


class _MaxPrim(Rule):
    prim = ""

    def __init__(self, limit: int):
        self.limit = int(limit)

    def describe(self) -> str:
        return f"{self.name}({self.limit})"

    def check(self, program: Program) -> list:
        paths = eqn_paths(program.closed_jaxpr, self.prim)
        if len(paths) <= self.limit:
            return []
        listing = ", ".join("/".join(p) for p in paths)
        return [Violation(self.name,
                          f"{len(paths)} {self.prim} eqns > limit "
                          f"{self.limit}: {listing}")]


class MaxScans(_MaxPrim):
    """At most N ``lax.scan`` equations (the streaming paths pin ONE)."""

    name = "MaxScans"
    prim = "scan"


class MaxPallasCalls(_MaxPrim):
    """At most N ``pallas_call`` launches (DESIGN.md §9: one dfr_scan + one
    Gram launch per program, no per-channel or per-chunk fan-out)."""

    name = "MaxPallasCalls"
    prim = "pallas_call"


class NoDtypeAbove(Rule):
    """No floating/complex intermediate wider than ``limit`` — catches the
    accidental f64 promotion an x64-enabled host or stray float64 literal
    drags into a hot path."""

    name = "NoDtypeAbove"

    def __init__(self, limit="float32"):
        self.limit = jnp.dtype(limit)

    def describe(self) -> str:
        return f"{self.name}({self.limit.name})"

    def check(self, program: Program) -> list:
        out = []
        for rec in program.records:
            try:
                dt = jnp.dtype(rec.dtype)
            except TypeError:
                # extended dtypes (e.g. the PRNG ``key<fry>`` of a traced
                # fault-injection seed) are opaque integer data, never a
                # float-width promotion — out of scope for this rule
                continue
            if (jnp.issubdtype(dt, jnp.inexact)
                    and dt.itemsize > self.limit.itemsize):
                out.append(_rec_violation(
                    self.name, f"{rec.dtype} intermediate {rec.shape} wider "
                    f"than {self.limit.name}", rec))
        return out


class NoSilentUpcast(Rule):
    """A bf16-chunk program must not re-materialize >= f32 arrays at chunk
    scale: the HBM-traffic halving (DESIGN.md §9) is void if a wide copy of
    each chunk exists anyway.  Same shape grammar as `NoStateTensor`, but
    filtering on *wide* dtypes only."""

    name = "NoSilentUpcast"

    def __init__(self, chunk_len: int, min_elems: int, *, benign_shapes=(),
                 wide="float32"):
        self.chunk_len = int(chunk_len)
        self.min_elems = int(min_elems)
        self.benign_shapes = tuple(tuple(s) for s in benign_shapes)
        self.wide = jnp.dtype(wide)

    def describe(self) -> str:
        return (f"{self.name}(chunk_len={self.chunk_len}, "
                f"min_elems={self.min_elems}, wide>={self.wide.name})")

    def check(self, program: Program) -> list:
        out = []
        for rec in state_tensor_records(program.closed_jaxpr, self.chunk_len,
                                        self.min_elems,
                                        benign_shapes=self.benign_shapes):
            dt = jnp.dtype(rec.dtype)
            if (jnp.issubdtype(dt, jnp.floating)
                    and dt.itemsize >= self.wide.itemsize):
                out.append(_rec_violation(
                    self.name, f"chunk-scale {rec.dtype} block {rec.shape} "
                    f"in a narrow-chunk program", rec))
        return out


class NoHostCallback(Rule):
    """No host-callback primitives (pure/io/debug callbacks) inside the
    program — a serving or training hot path must never round-trip through
    Python per step."""

    name = "NoHostCallback"

    def check(self, program: Program) -> list:
        out = []
        for eqn, path in walk_eqns_with_path(program.closed_jaxpr.jaxpr):
            if eqn.primitive.name in CALLBACK_PRIMS:
                out.append(Violation(
                    self.name, f"host callback `{eqn.primitive.name}` in "
                    f"jitted program", path=path + (eqn.primitive.name,)))
        return out


class DonationHonored(Rule):
    """Declared aliasing survives into the lowered program.

    Two layers: (a) if the Program declares ``donate_argnums``, every donated
    leaf must appear as a ``tf.aliasing_output`` argument attribute in the
    StableHLO — XLA silently drops donation on shape/dtype mismatch, which
    would double the serving slab's footprint without failing any test;
    (b) ``min_pallas_aliases`` pins pallas-level ``input_output_aliases``
    pairs (the accumulate-into Gram kernels), which a refactor could drop by
    calling the non-aliased kernel variant.
    """

    name = "DonationHonored"

    def __init__(self, *, min_donated: int = None, min_pallas_aliases: int = 0):
        self.min_donated = min_donated
        self.min_pallas_aliases = int(min_pallas_aliases)

    def describe(self) -> str:
        return (f"{self.name}(donated>={self.min_donated}, "
                f"pallas_aliases>={self.min_pallas_aliases})")

    def check(self, program: Program) -> list:
        out = []
        if program.donate_argnums or self.min_donated is not None:
            expect = (self.min_donated if self.min_donated is not None
                      else program.donated_leaf_count())
            got = program.lowered_text.count("tf.aliasing_output")
            if got < expect:
                out.append(Violation(
                    self.name, f"{got} aliased buffers in lowered program, "
                    f"expected >= {expect} (donate_argnums="
                    f"{program.donate_argnums})"))
        if self.min_pallas_aliases:
            got = sum(len(tuple(eqn.params.get("input_output_aliases") or ()))
                      for eqn, _ in pallas_eqns(program.closed_jaxpr))
            if got < self.min_pallas_aliases:
                out.append(Violation(
                    self.name, f"{got} pallas input_output_aliases pairs, "
                    f"expected >= {self.min_pallas_aliases} (accumulate-into "
                    f"kernel dropped?)"))
        return out


class VmemBudget(Rule):
    """Every ``pallas_call`` fits in VMEM and its blocks are tile-aligned.

    The VMEM estimate is static, from the kernel's own refs: in/out blocks
    are counted twice (Mosaic double-buffers the grid pipeline) plus scratch
    once.  The alignment check generalizes the guard ``dfr_scan`` enforces
    for its own blocks (dfr_scan.py): a *multi-tile* block of a sub-f32
    dtype must start on a (min_sublanes(dtype), 128) boundary — interpret
    mode happily computes misaligned blocks that real Mosaic rejects, so
    this is exactly the class of bug that survives CPU-only CI.  Single-tile
    blocks (block spans the whole axis) are exempt; f32 sublane layout is
    left to Mosaic relayout, matching the kernel's own policy.
    """

    name = "VmemBudget"

    def __init__(self, limit_bytes: int = VMEM_BYTES, *,
                 check_alignment: bool = True):
        self.limit_bytes = int(limit_bytes)
        self.check_alignment = check_alignment

    def describe(self) -> str:
        return f"{self.name}({self.limit_bytes}B)"

    @staticmethod
    def estimate_bytes(eqn) -> int:
        """Static VMEM footprint of one pallas_call eqn: 2× each in/out
        block (double buffering) + scratch."""
        gm = eqn.params["grid_mapping"]
        refs = list(eqn.params["jaxpr"].invars)
        n_idx = getattr(gm, "num_index_operands", 0)
        n_scratch = getattr(gm, "num_scratch_operands", 0)
        body = refs[n_idx:len(refs) - n_scratch]
        scratch = refs[len(refs) - n_scratch:] if n_scratch else []

        def ref_bytes(var):
            aval = var.aval
            size = 1
            for d in aval.shape:
                size *= int(d)
            return size * jnp.dtype(aval.dtype).itemsize

        return (2 * sum(ref_bytes(v) for v in body)
                + sum(ref_bytes(v) for v in scratch))

    @staticmethod
    def _aligned(block_shape, full_shape, dtype):
        """None if OK, else a human-readable misalignment description."""
        from repro.kernels.dfr_scan import min_sublanes
        if len(block_shape) < 2 or len(full_shape) < len(block_shape):
            return None
        full = full_shape[len(full_shape) - len(block_shape):]
        b_lane, f_lane = int(block_shape[-1]), int(full[-1])
        if b_lane < f_lane and b_lane % 128:
            return f"lane dim {b_lane} of multi-tile block not 128-aligned"
        b_sub, f_sub = int(block_shape[-2]), int(full[-2])
        dt = jnp.dtype(dtype)
        min_sub = min_sublanes(dt)
        if b_sub < f_sub and dt.itemsize < 4 and b_sub % min_sub:
            return (f"sublane dim {b_sub} of multi-tile {dt.name} block not "
                    f"a multiple of {min_sub}")
        return None

    def check(self, program: Program) -> list:
        out = []
        for eqn, path in pallas_eqns(program.closed_jaxpr):
            kname = eqn.params.get("name_and_src_info", "")
            kname = getattr(kname, "name", str(kname))
            est = self.estimate_bytes(eqn)
            if est > self.limit_bytes:
                out.append(Violation(
                    self.name, f"pallas_call `{kname}` needs ~{est}B VMEM "
                    f"> budget {self.limit_bytes}B",
                    path=path + ("pallas_call",)))
            if not self.check_alignment:
                continue
            gm = eqn.params["grid_mapping"]
            try:
                fulls = [jax.ShapeDtypeStruct(s.shape, s.dtype)
                         for s in tuple(gm.in_shapes) + tuple(gm.out_shapes)]
                blocks = [tuple(bm.block_shape) for bm in gm.block_mappings]
            except Exception:      # unknown jax internals: skip, don't crash
                continue
            for block, full in zip(blocks, fulls):
                msg = self._aligned(block, full.shape, full.dtype)
                if msg:
                    out.append(Violation(
                        self.name, f"pallas_call `{kname}` block {block} of "
                        f"{tuple(full.shape)}: {msg}",
                        path=path + ("pallas_call",),
                        shape=block, dtype=jnp.dtype(full.dtype).name))
        return out


def check_rules(program: Program, rules) -> list:
    """Evaluate ``rules`` against ``program``; flat list of violations."""
    out = []
    for rule in rules:
        out.extend(rule.check(program))
    return out


__all__ = [
    "CALLBACK_PRIMS", "VMEM_BYTES", "Violation", "Program", "Rule",
    "NoStateTensor", "MaxScans", "MaxPallasCalls", "NoDtypeAbove",
    "NoSilentUpcast", "NoHostCallback", "DonationHonored", "VmemBudget",
    "check_rules", "count_scans", "count_pallas_calls",
]
