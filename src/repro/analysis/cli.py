"""``python -m repro.analysis``: trace every registered entry point, evaluate
its contract set, write ANALYSIS_report.json, exit nonzero on violation.

Runs trace-only (tiny shapes, no execution), so it is cheap enough to gate
every CI run.  ``--entry-point`` filters the registry (the latest-jax canary
uses it to probe specific paths); ``--seed-violation`` adds a deliberately
broken entry so CI can assert the gate actually fails.
"""

from __future__ import annotations

import argparse
import json
import sys
import traceback

import jax

from .registry import entry_point_names, get_entry_points


def analyze_entry(entry) -> dict:
    """Build + check one entry point; never raises (a trace failure is
    itself a reportable violation of the "this entry point traces" contract)."""
    try:
        program, rules = entry.build()
        rule_results = []
        n_viol = 0
        for rule in rules:
            viols = rule.check(program)
            n_viol += len(viols)
            rule_results.append({
                "rule": rule.describe(),
                "ok": not viols,
                "violations": [v.as_dict() for v in viols],
            })
        return {"name": entry.name, "description": entry.description,
                "ok": n_viol == 0, "n_violations": n_viol,
                "rules": rule_results}
    except Exception:
        return {"name": entry.name, "description": entry.description,
                "ok": False, "n_violations": 1, "rules": [],
                "error": traceback.format_exc(limit=8)}


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static program-contract checker (DESIGN.md §11).")
    parser.add_argument("--entry-point", action="append", default=None,
                        metavar="NAME",
                        help="check only NAME (repeatable; default: all)")
    parser.add_argument("--out", default="ANALYSIS_report.json",
                        help="report path (default: %(default)s)")
    parser.add_argument("--list", action="store_true",
                        help="list registered entry points and exit")
    parser.add_argument("--seed-violation", action="store_true",
                        help="add a deliberately violating entry point "
                             "(gate self-test: exit must be nonzero)")
    args = parser.parse_args(argv)

    if args.list:
        for name in entry_point_names():
            print(name)
        return 0

    entries = get_entry_points(args.entry_point,
                               include_seeded=args.seed_violation)
    results = []
    for entry in entries:
        res = analyze_entry(entry)
        results.append(res)
        status = "ok" if res["ok"] else "FAIL"
        print(f"[{status}] {res['name']}: {len(res['rules'])} rules, "
              f"{res['n_violations']} violation(s)")
        if "error" in res:
            print(f"    trace error:\n{res['error']}")
        for rr in res["rules"]:
            for v in rr["violations"]:
                where = "/".join(v.get("path", [])) or "<top>"
                print(f"    {v['rule']}: {v['message']}  [at {where}]")

    n_viol = sum(r["n_violations"] for r in results)
    report = {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "ok": n_viol == 0,
        "n_violations": n_viol,
        "entry_points": results,
    }
    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2)
        fh.write("\n")
    print(f"{len(results)} entry point(s), {n_viol} violation(s) "
          f"-> {args.out}")
    return 1 if n_viol else 0


if __name__ == "__main__":
    sys.exit(main())
