"""Hardened jaxpr walker: intermediate-tensor accounting with provenance.

The streaming fused path (DESIGN.md §8) exists to keep the full [B, T, N]
state tensor out of HBM; these helpers make that property *checkable* by
walking a traced jaxpr and collecting the abstract value every equation
produces, together with the **path of enclosing primitives** that leads to
it (e.g. ``("scan", "pjit")`` = inside a jit called from a chunk-scan body).
The path is what turns "a [4, 256, 24] tensor exists" into "the scan body
re-materializes the stream" — and what lets `state_tensor_bytes` separate a
true state tensor from an unrelated array whose axis is numerically equal
to ``t_len`` (DESIGN.md §11).

Descent is exhaustive: any ``Jaxpr``/``ClosedJaxpr`` reachable through an
equation's params is entered, however it is nested (tuples of branches,
dicts, ``closed_call``/``custom_jvp_call``/``custom_vjp_call`` wrappers,
``while``/``cond``/``scan``/``pjit``/``remat`` bodies).  The pre-hardening
walker flattened only one tuple level and so went blind behind primitives
that stash their jaxpr deeper; `tests/test_analysis.py` pins the fixed
behaviour per primitive.

Equations inside a ``pallas_call`` body are skipped by default: a kernel's
jaxpr describes per-*block* VMEM compute, not HBM-resident arrays, and in
interpret mode it contains emulation loops that are not real scans.  The
`VmemBudget` rule (rules.py) is the one consumer that inspects kernel
internals, and it does so through the pallas eqn params, not this walk.
"""

from __future__ import annotations

import dataclasses

import jax

try:  # jax >= 0.4.14
    from jax.extend import core as jax_core
except ImportError:  # pragma: no cover - older jax
    from jax import core as jax_core


def _sub_jaxprs(params):
    """Yield every Jaxpr/ClosedJaxpr nested anywhere in an eqn's params.

    Recurses through tuples/lists/dicts so ``cond`` branches, paired
    ``while`` jaxprs, and any deeper container a primitive uses are all
    found — the old single-level flatten is the blind spot ISSUE 7 fixes.
    """
    def visit(value):
        if isinstance(value, jax_core.ClosedJaxpr):
            yield value.jaxpr
        elif isinstance(value, jax_core.Jaxpr):
            yield value
        elif isinstance(value, (tuple, list)):
            for leaf in value:
                yield from visit(leaf)
        elif isinstance(value, dict):
            for leaf in value.values():
                yield from visit(leaf)

    for value in params.values():
        yield from visit(value)


def walk_eqns_with_path(jaxpr, *, skip_pallas: bool = True, _path=()):
    """Depth-first ``(eqn, path)`` pairs over all equations, entering
    sub-jaxprs.  ``path`` is the tuple of enclosing primitive names, outermost
    first; top-level equations have ``path == ()``."""
    for eqn in jaxpr.eqns:
        yield eqn, _path
        if skip_pallas and eqn.primitive.name == "pallas_call":
            continue
        sub_path = _path + (eqn.primitive.name,)
        for sub in _sub_jaxprs(eqn.params):
            yield from walk_eqns_with_path(sub, skip_pallas=skip_pallas,
                                           _path=sub_path)


def walk_eqns(jaxpr, *, skip_pallas: bool = True):
    """Depth-first iterator over all equations, entering sub-jaxprs."""
    for eqn, _ in walk_eqns_with_path(jaxpr, skip_pallas=skip_pallas):
        yield eqn


def trace_jaxpr(fn, *args, **kwargs):
    """ClosedJaxpr of ``fn(*args, **kwargs)`` (no execution)."""
    return jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args)


@dataclasses.dataclass(frozen=True)
class Intermediate:
    """One array named by the traced program, with provenance."""

    shape: tuple
    dtype: str
    nbytes: int
    prim: str               # primitive of the producing equation
    path: tuple             # enclosing primitive names, outermost first

    @property
    def elems(self) -> int:
        n = 1
        for d in self.shape:
            n *= int(d)
        return n

    def where(self) -> str:
        return "/".join(self.path + (self.prim,))


def intermediate_records(closed_jaxpr) -> list:
    """Every `Intermediate` produced by equations in the program.

    Covers every intermediate array the traced computation names —
    sub-jaxpr (scan body, pjit, cond branch, custom-derivative wrapper)
    outputs included, pallas kernel-internal VMEM blocks excluded.
    """
    out = []
    for eqn, path in walk_eqns_with_path(closed_jaxpr.jaxpr):
        for var in eqn.outvars:
            aval = var.aval
            if hasattr(aval, "shape") and hasattr(aval, "dtype"):
                nbytes = int(aval.size) * aval.dtype.itemsize
                out.append(Intermediate(tuple(aval.shape), str(aval.dtype),
                                        nbytes, eqn.primitive.name, path))
    return out


def intermediate_shapes(closed_jaxpr) -> list:
    """All (shape, nbytes) pairs produced by equations in the program."""
    return [(r.shape, r.nbytes) for r in intermediate_records(closed_jaxpr)]


def max_intermediate_bytes(closed_jaxpr) -> int:
    """Largest single intermediate array in the program, in bytes."""
    return max((r.nbytes for r in intermediate_records(closed_jaxpr)),
               default=0)


def _dims_match_template(shape, template) -> bool:
    """True if ``shape``'s dims are a permutation of ``template``'s."""
    return sorted(int(d) for d in shape) == sorted(int(d) for d in template)


def state_tensor_records(closed_jaxpr, t_len: int, min_elems: int, *,
                         benign_shapes=()) -> list:
    """All "state-like" intermediates: carry the stream axis (a dim ==
    ``t_len``) at state-tensor scale (>= ``min_elems`` elements), and match
    none of the ``benign_shapes`` templates.

    ``benign_shapes`` disambiguates the collision case where an *unrelated*
    axis is numerically equal to ``t_len`` — e.g. a [B, Fq, Fq] Gram when
    the padded feature count Fq happens to equal the chunk length, or a
    padded batch equal to K.  Each template is a dim multiset (order
    ignored): an intermediate whose dims are a permutation of a template is
    exempt.  Templates name *structurally known* blocks (Gram [B, Fq, Fq],
    chunk state [B, chunk_padded, Fq], ...) — a genuine [B, t_len, N] state
    tensor matches none of them and is still flagged.  The returned records
    carry provenance (`Intermediate.where()`) so a report shows *where* the
    offending tensor lives.
    """
    out = []
    for rec in intermediate_records(closed_jaxpr):
        if t_len not in rec.shape or rec.elems < min_elems:
            continue
        if any(_dims_match_template(rec.shape, t) for t in benign_shapes):
            continue
        out.append(rec)
    return out


def state_tensor_bytes(closed_jaxpr, t_len: int, min_elems: int, *,
                       benign_shapes=()) -> int:
    """Largest "state-like" intermediate, in bytes (0 = property holds).

    The element floor is what separates a state tensor from the O(B·T)
    input/target streams that legitimately carry the T axis: pass
    ``B·t_len·N`` (full-stream check; 0 == the streaming property holds) or
    ``B·chunk·N`` with ``t_len=chunk`` (the streamed path's peak live state
    block — lane/feature padding of the kernel layouts is included in the
    measured tensor, so compare against a padded budget).  See
    `state_tensor_records` for ``benign_shapes``.
    """
    return max((r.nbytes for r in state_tensor_records(
        closed_jaxpr, t_len, min_elems, benign_shapes=benign_shapes)),
        default=0)


def eqn_paths(closed_jaxpr, prim_name: str) -> list:
    """Provenance paths (incl. the primitive itself) of every ``prim_name``
    equation in the program — pallas kernel bodies excluded."""
    return [path + (prim_name,)
            for eqn, path in walk_eqns_with_path(closed_jaxpr.jaxpr)
            if eqn.primitive.name == prim_name]


def count_scans(closed_jaxpr) -> int:
    """Number of ``lax.scan`` equations (pallas kernel bodies excluded)."""
    return len(eqn_paths(closed_jaxpr, "scan"))


def count_pallas_calls(closed_jaxpr) -> int:
    """Number of ``pallas_call`` equations anywhere in the program.

    The WDM streaming guard uses this to pin the per-lane-mask claim
    (DESIGN.md §9): all R wavelength channels run as ONE dfr_scan launch
    plus ONE accumulate-into Gram launch per chunk-scan body — a program
    that vmapped ``pallas_call`` per channel would show R× the count.
    """
    return len(eqn_paths(closed_jaxpr, "pallas_call"))


def pallas_eqns(closed_jaxpr) -> list:
    """All ``(eqn, path)`` pairs for pallas_call equations in the program."""
    return [(eqn, path)
            for eqn, path in walk_eqns_with_path(closed_jaxpr.jaxpr)
            if eqn.primitive.name == "pallas_call"]
