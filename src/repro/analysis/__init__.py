"""repro.analysis — static program-contract checker (DESIGN.md §11).

The paper's headline claims survive in this repro as *structural program
properties* (no materialized [B, T, N] state tensor, one Pallas launch pair
per chunk, donated serving slabs, no silent dtype widening).  This package
turns each property into a declarative `Rule` evaluated against traced
jaxprs / lowered StableHLO — no execution — and registers every compiled
entry point with its contract set.  ``python -m repro.analysis`` checks them
all and writes ANALYSIS_report.json.

`walker` is the provenance-carrying jaxpr walker (the promoted successor of
``repro.pipeline.introspect``, which re-exports from here), `rules` the
contract catalog, `registry` the entry points, `cli` the gate.
"""

from .rules import (CALLBACK_PRIMS, VMEM_BYTES, DonationHonored,
                    MaxPallasCalls, MaxScans, NoDtypeAbove, NoHostCallback,
                    NoSilentUpcast, NoStateTensor, Program, Rule, Violation,
                    VmemBudget, check_rules)
from .walker import (Intermediate, count_pallas_calls, count_scans,
                     eqn_paths, intermediate_records, intermediate_shapes,
                     max_intermediate_bytes, pallas_eqns,
                     state_tensor_bytes, state_tensor_records, trace_jaxpr,
                     walk_eqns, walk_eqns_with_path)

__all__ = [
    "CALLBACK_PRIMS", "VMEM_BYTES", "DonationHonored", "Intermediate",
    "MaxPallasCalls", "MaxScans", "NoDtypeAbove", "NoHostCallback",
    "NoSilentUpcast", "NoStateTensor", "Program", "Rule", "Violation",
    "VmemBudget", "check_rules", "count_pallas_calls", "count_scans",
    "eqn_paths", "intermediate_records", "intermediate_shapes",
    "max_intermediate_bytes", "pallas_eqns", "state_tensor_bytes",
    "state_tensor_records", "trace_jaxpr", "walk_eqns",
    "walk_eqns_with_path",
]
