"""Checkpointing: atomic, async, integrity-checked, keep-k, elastic."""

from .store import CheckpointStore

__all__ = ["CheckpointStore"]
