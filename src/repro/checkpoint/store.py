"""Fault-tolerant checkpointing: atomic, async, integrity-checked, keep-k.

Design (DESIGN.md §3):
  * **Atomic**: write to ``step_<n>.tmp/`` then ``os.replace`` to
    ``step_<n>/`` — a crash mid-write never corrupts the latest checkpoint.
  * **Async**: ``save_async`` snapshots the pytree to host memory
    (device_get) synchronously — the step loop stalls only for the copy —
    then serialises on a background thread.
  * **Integrity**: every leaf file carries a SHA-256 in ``manifest.json``;
    ``restore`` verifies before deserialising and falls back to the previous
    checkpoint on mismatch (torn writes, bit rot).
  * **Keep-k**: old checkpoints garbage-collected after a successful write.
  * **Elastic re-shard**: checkpoints store the *global* (unsharded) arrays;
    ``restore(..., sharding_tree=...)`` re-lays them out for whatever mesh
    the restarted job has — restart on 256 chips from a 512-chip run works.
"""

from __future__ import annotations

import hashlib
import json
import os
import pathlib
import shutil
import threading

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _leaf_name(i: int) -> str:
    return f"leaf_{i:05d}.npy"


class CheckpointStore:
    def __init__(self, directory: str | os.PathLike, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    # -- save -----------------------------------------------------------------
    def save(self, step: int, tree) -> None:
        host_leaves = [np.asarray(jax.device_get(x)) for x in _flatten(tree)[0]]
        self._write(step, host_leaves)

    def save_async(self, step: int, tree) -> None:
        """Snapshot to host now; serialise in the background."""
        self.wait()
        if self._error:
            raise self._error
        host_leaves = [np.asarray(jax.device_get(x)) for x in _flatten(tree)[0]]
        self._thread = threading.Thread(target=self._write_guarded, args=(step, host_leaves))
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write_guarded(self, step: int, leaves) -> None:
        try:
            self._write(step, leaves)
        except Exception as e:  # noqa: BLE001 — surfaced on next save/wait
            self._error = e

    def _write(self, step: int, leaves) -> None:
        tmp = self.dir / f"step_{step:010d}.tmp"
        final = self.dir / f"step_{step:010d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "leaves": []}
        for i, leaf in enumerate(leaves):
            name = _leaf_name(i)
            path = tmp / name
            with open(path, "wb") as f:
                np.save(f, leaf, allow_pickle=False)
            digest = hashlib.sha256(path.read_bytes()).hexdigest()
            manifest["leaves"].append(
                {"name": name, "sha256": digest, "shape": list(leaf.shape),
                 "dtype": str(leaf.dtype)}
            )
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not p.is_dir():
                continue
            try:
                out.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _verify(self, step: int) -> list[np.ndarray] | None:
        d = self.dir / f"step_{step:010d}"
        try:
            manifest = json.loads((d / "manifest.json").read_text())
        except (OSError, json.JSONDecodeError):
            return None
        leaves = []
        for entry in manifest["leaves"]:
            path = d / entry["name"]
            if not path.exists():
                return None
            if hashlib.sha256(path.read_bytes()).hexdigest() != entry["sha256"]:
                return None
            leaves.append(np.load(path, allow_pickle=False))
        return leaves

    def restore(self, tree_like, *, step: int | None = None, sharding_tree=None):
        """Restore into the structure of ``tree_like``.

        Walks back through older checkpoints if the newest fails integrity.
        ``sharding_tree``: optional pytree of Shardings — arrays are placed
        sharded for the *current* mesh (elastic re-shard on restart).
        Returns (step, tree) or (None, None) when nothing restorable exists.
        """
        candidates = [step] if step is not None else list(reversed(self.all_steps()))
        _, treedef = _flatten(tree_like)
        for s in candidates:
            leaves = self._verify(s)
            if leaves is None:
                continue
            if sharding_tree is not None:
                sh_leaves = _flatten(sharding_tree)[0]
                leaves = [jax.device_put(lf, sh)
                          for lf, sh in zip(leaves, sh_leaves)]
            return s, jax.tree_util.tree_unflatten(treedef, leaves)
        return None, None
