"""Design-space exploration: a (detuning × loss × power) robustness map
as ONE compiled program (DESIGN.md §14).

The naive sweep — one ``Experiment`` per grid point — retraces and
recompiles per point, because device models are frozen-dataclass jit
*statics*.  This module folds the grid into **batch lanes** instead: the
grid's G = D·L·P points become G rows of a ``CMTSweepParams`` pytree whose
leaves are ``[G]`` *operands*, the task's train/test series are broadcast
over the same G lanes, and the whole robustness map runs through one
``Experiment.run(…, dev_params=…)`` call — one trace, one XLA program, no
full-stream state tensor (the streaming path), every lane vectorised over
the batch axis exactly like the paper's seed/SNR sweeps.

``repro.analysis`` gates the structure (``device_sweep*`` entry points), and
``pipeline_cache_size()`` exposes the jit cache counter the benchmark uses
to prove a second sweep with NEW grid values compiles nothing.

>>> grid = SweepGrid(detune=(-1.0, 0.0, 1.0), loss_scale=(0.5, 1.0),
...                  power=(0.0, 1.0))
>>> res = run_device_sweep(model, grid, tasks.narma10(1200))
>>> res.nrmse.shape                      # (3, 2, 2) — the folded map
>>> res.stable_region(nrmse_max=0.4)     # boolean map + flagged summary
"""

from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp
import numpy as np

from .cmt import CMTSweepParams, MRCavityCMT


@dataclasses.dataclass(frozen=True)
class SweepGrid:
    """A (detune × loss_scale × power) parameter box, axis values as tuples."""

    detune: tuple[float, ...]
    loss_scale: tuple[float, ...]
    power: tuple[float, ...]

    def __post_init__(self):
        for f in ("detune", "loss_scale", "power"):
            if not isinstance(getattr(self, f), tuple):
                object.__setattr__(self, f, tuple(float(v)
                                                  for v in getattr(self, f)))
            if not getattr(self, f):
                raise ValueError(f"grid axis {f!r} is empty")

    @property
    def shape(self) -> tuple[int, int, int]:
        return (len(self.detune), len(self.loss_scale), len(self.power))

    @property
    def size(self) -> int:
        return math.prod(self.shape)

    def lanes(self) -> CMTSweepParams:
        """The grid raveled into per-lane ``[G]`` leaves (row-major: detune
        slowest, power fastest — ``fold`` is the inverse)."""
        d, l, p = jnp.meshgrid(jnp.asarray(self.detune, jnp.float32),
                               jnp.asarray(self.loss_scale, jnp.float32),
                               jnp.asarray(self.power, jnp.float32),
                               indexing="ij")
        return CMTSweepParams(detune=d.ravel(), loss_scale=l.ravel(),
                              power=p.ravel())

    def fold(self, values) -> np.ndarray:
        """Per-lane ``[G]`` results back into the ``(D, L, P)`` map."""
        return np.asarray(values).reshape(self.shape)

    def point(self, idx: tuple[int, int, int]) -> dict:
        return {"detune": self.detune[idx[0]],
                "loss_scale": self.loss_scale[idx[1]],
                "power": self.power[idx[2]]}


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """The folded robustness map: one cell per grid point, numpy on host."""

    grid: SweepGrid
    nrmse: np.ndarray      # [D, L, P]
    ser: np.ndarray        # [D, L, P]
    lam: np.ndarray        # [D, L, P] — GCV-selected ridge λ per point

    def stable_region(self, *, nrmse_max: float = 0.4) -> dict:
        """Flag the stable operating region: finite NRMSE under the bound.

        Returns the boolean map plus a JSON-ready summary (fraction stable,
        the best point, and the stable bounding box per axis) — what the
        benchmark artifact records and a DSE user reads first.
        """
        ok = np.isfinite(self.nrmse) & (self.nrmse <= nrmse_max)
        summary = {"nrmse_max": nrmse_max,
                   "n_stable": int(ok.sum()), "n_total": int(ok.size),
                   "stable_fraction": round(float(ok.mean()), 4)}
        if ok.any():
            masked = np.where(ok, self.nrmse, np.inf)
            best = np.unravel_index(int(np.argmin(masked)), ok.shape)
            summary["best_point"] = {**self.grid.point(best),
                                     "nrmse": round(float(self.nrmse[best]), 4),
                                     "ser": round(float(self.ser[best]), 4)}
            axes = ("detune", "loss_scale", "power")
            for ax, name in enumerate(axes):
                hit = ok.any(axis=tuple(i for i in range(3) if i != ax))
                vals = [getattr(self.grid, name)[i]
                        for i in np.flatnonzero(hit)]
                summary[f"stable_{name}"] = [min(vals), max(vals)]
        return {"map": ok, "summary": summary}


def _tile(x, g: int) -> jnp.ndarray:
    x = jnp.asarray(x, jnp.float32)
    return jnp.broadcast_to(x[None, :], (g,) + x.shape)


def run_device_sweep(model: MRCavityCMT, grid: SweepGrid, dataset, *,
                     n_nodes: int = 50, washout: int = 50,
                     stream_chunk_k: int | None = 256,
                     ridge_l2: tuple[float, ...] = (1e-8, 1e-6, 1e-4),
                     state_method: str = "fast",
                     mask_seed: int = 1) -> SweepResult:
    """The whole robustness map from ONE compiled vmapped Experiment.

    ``dataset`` is a ``core.tasks`` Dataset (one task instance); its series
    are broadcast over the G grid lanes, so every lane sees the *same* data
    and the map isolates the device physics.  ``stream_chunk_k`` keeps the
    run on the streaming path (no [G, T, N] state tensor — the jaxpr-gated
    contract); ``None`` falls back to the materialized path for short tasks.

    Swept parameters ride the batch lanes as operands, so calling this again
    with a same-shape grid of different VALUES reuses the compiled program
    (``pipeline_cache_size()`` proves it).
    """
    # lazy import: repro.pipeline imports repro.core, which must finish
    # initialising before the devices package pulls the pipeline in
    from repro.pipeline import Experiment, ExperimentConfig

    cfg = ExperimentConfig(model=model, n_nodes=n_nodes, washout=washout,
                           ridge_l2=ridge_l2, state_method=state_method,
                           stream_chunk_k=stream_chunk_k,
                           state_noise_rel=0.0, collect_y_pred=False)
    g = grid.size
    res = Experiment(cfg).run(
        _tile(dataset.inputs_train, g), _tile(dataset.targets_train, g),
        _tile(dataset.inputs_test, g), _tile(dataset.targets_test, g),
        dev_params=grid.lanes())
    return SweepResult(grid=grid, nrmse=grid.fold(res.nrmse),
                       ser=grid.fold(res.ser), lam=grid.fold(res.lam))


def pipeline_cache_size() -> int:
    """Compiled-program count of the pipeline entry — the no-retrace proof:
    two sweeps with different same-shape grids must leave this unchanged."""
    from repro.pipeline.experiment import _run_pipeline
    return int(_run_pipeline._cache_size())
