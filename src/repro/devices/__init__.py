"""Physics-fidelity device subsystem (DESIGN.md §14).

Richer, swappable microring device models behind the same
``node_update``/``period_update`` contract as ``core/nonlinear.py``, plus
the small-signal calibration that anchors them to the paper's model and the
batched design-space-exploration sweep that maps their robustness:

* :mod:`~repro.devices.cmt`       — :class:`MRCavityCMT`, a coupled-mode-
  theory cavity (intracavity energy + free carriers + temperature,
  sub-stepped inside each virtual-node tick) with TPA, free-carrier
  absorption/dispersion, thermal dispersion and linear loss;
  :class:`CMTSweepParams`, the traced per-lane operating-point pytree.
* :mod:`~repro.devices.calibrate` — ``calibrated_twin`` (the CMT whose
  zero-power limit IS ``SiliconMR``'s tick map), small-signal gain
  measurement, per-tick parity bounds.
* :mod:`~repro.devices.sweep`     — ``SweepGrid``/``run_device_sweep``:
  a (detuning × loss × power) grid folded into batch lanes of ONE compiled
  vmapped Experiment (no per-point retrace; jaxpr-gated).

Importing this package registers ``MRCavityCMT`` in
``core.nonlinear.MODEL_REGISTRY`` under ``"mr_cavity_cmt"``.
"""

from repro.core.nonlinear import register_model

from .calibrate import (calibrated_twin, calibration_report, node_parity,
                        small_signal_gains)
from .cmt import CMTSweepParams, MRCavityCMT
from .sweep import SweepGrid, SweepResult, pipeline_cache_size, run_device_sweep

register_model("mr_cavity_cmt", MRCavityCMT)

__all__ = [
    "CMTSweepParams",
    "MRCavityCMT",
    "SweepGrid",
    "SweepResult",
    "calibrated_twin",
    "calibration_report",
    "node_parity",
    "pipeline_cache_size",
    "run_device_sweep",
    "small_signal_gains",
]
