"""Small-signal calibration: recover ``SiliconMR`` from the CMT cavity.

The paper's model (:class:`~repro.core.nonlinear.SiliconMR`, θ-corrected
Eq. 6-7) is the *zero-power small-signal limit* of the CMT cavity: with all
nonlinear mechanisms off, one tick of either branch is an affine map

    charge    (u > s(t−θ)):  s' = α·P + E₀
    discharge (u ≤ s(t−θ)):  s' = α·P + (1−α)·E₀,    P = u + γ·s(t−τ),

with α = 1 − exp(−θ/τ_ph).  :func:`calibrated_twin` builds the
:class:`~repro.devices.cmt.MRCavityCMT` whose auto-calibrated pump couplings
reproduce that map exactly (any substep count — the exponential integrator
telescopes; cmt.py module docstring), so the CMT low-power limit matches the
paper model to float rounding per tick and within seed spread at NRMSE level
(the ISSUE 10 acceptance gate, benchmarks/device_sweep.py).

:func:`small_signal_gains` measures the per-branch (∂s'/∂P, ∂s'/∂E₀) pair of
ANY contract model by exact finite differences (the branch maps are affine,
so differences at branch-safe probe points are not approximations), and
:func:`node_parity` bounds the worst-case per-tick deviation between two
models over the [0, 1]³ operating box — the quantities the calibration
report and the parity tests gate on.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.nonlinear import SiliconMR

from .cmt import MRCavityCMT


def calibrated_twin(mr: SiliconMR, *, n_substeps: int = 4,
                    **overrides) -> MRCavityCMT:
    """The MRCavityCMT whose zero-power limit IS ``mr``'s tick map.

    Maps τ_ph → τ_L (same photon-lifetime role), copies θ and γ, sits on
    resonance (δ = 0) at unit loss with ``power_mw = 0``, and leaves the
    pump couplings on auto-calibration.  ``overrides`` then move single
    fields off the calibrated point (e.g. ``power_mw=1.0`` to switch the
    nonlinear mechanisms on while keeping the calibrated κ anchor).

    Requires ``mr.beta_tpa == 0`` — the paper's headline operating point;
    a drive-saturating β_tpa is a different nonlinearity than the cavity's
    energy-dependent TPA loss and has no small-signal equivalent here.
    """
    if mr.beta_tpa:
        raise ValueError(
            f"calibrated_twin requires beta_tpa == 0 (the paper's headline "
            f"configs); got beta_tpa={mr.beta_tpa}")
    kw = dict(theta_ps=mr.theta_ps, tau_l_ps=mr.tau_ph_ps, gamma=mr.gamma,
              detune=0.0, loss_scale=1.0, power_mw=0.0,
              n_substeps=n_substeps)
    kw.update(overrides)
    return MRCavityCMT(**kw)


def small_signal_gains(model, *, charging: bool, h: float = 2 ** -12) -> dict:
    """Per-branch one-tick response gains of a contract model.

    Returns ``{"drive": ∂s'/∂P, "state": ∂s'/∂E₀}`` for the requested branch,
    measured by finite differences at branch-safe probe points (probes keep
    ``u > s_prev`` resp. ``u ≤ s_prev`` on both sides of the difference, and
    ``s_tau = 0`` so the drive is ``u`` alone).  For affine branch maps —
    both models at zero power — the differences are exact up to rounding;
    ``h`` is a power of two so the probe arithmetic itself is exact.
    """
    if charging:
        u0, sp = 0.75, 0.125
    else:
        u0, sp = 0.125, 0.75

    def f(u, s_tau, s_prev):
        return float(model.node_update(jnp.float32(u), jnp.float32(s_tau),
                                       jnp.float32(s_prev)))

    g_drive = (f(u0 + h, 0.0, sp) - f(u0, 0.0, sp)) / h
    g_state = (f(u0, 0.0, sp + h) - f(u0, 0.0, sp)) / h
    return {"drive": g_drive, "state": g_state}


def calibration_report(mr: SiliconMR, cmt: MRCavityCMT) -> dict:
    """Per-branch gain deltas between ``mr`` and ``cmt`` (floats, JSON-ready).

    The deltas are ~1e-4-exact for a calibrated twin at zero power (finite
    differences on f32); the benchmark records them and the parity test
    bounds them.
    """
    out = {}
    for branch in ("charge", "discharge"):
        gm = small_signal_gains(mr, charging=branch == "charge")
        gc = small_signal_gains(cmt, charging=branch == "charge")
        out[branch] = {
            "mr_drive": gm["drive"], "cmt_drive": gc["drive"],
            "mr_state": gm["state"], "cmt_state": gc["state"],
            "max_abs_delta": max(abs(gm["drive"] - gc["drive"]),
                                 abs(gm["state"] - gc["state"])),
        }
    return out


def node_parity(a, b, *, n: int = 9, lo: float = 0.0, hi: float = 1.0) -> float:
    """Worst-case |a.node_update − b.node_update| over an (u, s_τ, s_θ) grid.

    The operating box defaults to [0, 1]³ — the normalised drive range the
    pipeline's input layer produces and the device models are tuned on.
    """
    g = jnp.linspace(lo, hi, n, dtype=jnp.float32)
    u, st, sp = jnp.meshgrid(g, g, g, indexing="ij")
    return float(jnp.max(jnp.abs(a.node_update(u, st, sp)
                                 - b.node_update(u, st, sp))))
