"""Coupled-mode-theory (CMT) microring cavity model (DESIGN.md §14).

The paper's :class:`~repro.core.nonlinear.SiliconMR` is one fixed closed-form
per-tick map.  "Effects of cavity nonlinearities and linear losses on silicon
microring-based reservoir computing" (arXiv:2310.09433) shows that the
physics *behind* that map — two-photon absorption (TPA), free-carrier
absorption/dispersion, thermal dispersion, linear loss — changes RC
performance materially across the (detuning, loss, input-power) box.  This
module models those mechanisms explicitly:

:class:`MRCavityCMT` integrates three coupled cavity variables *inside* each
virtual-node tick (length θ), with ``n_substeps`` exact-exponential substeps:

    E  — intracavity energy (the value carried between virtual nodes; the
         reservoir contract's scalar state),
    N  — free-carrier density, generated ∝ (power·E)² (TPA pairs), relaxing
         with lifetime τ_fc,
    T  — mode temperature, driven ∝ power·E (absorbed-power heating),
         relaxing with lifetime τ_th.

Per substep of length dt = θ/n_substeps:

    δ_eff = δ − fcd·N + th_shift·T               (carrier blue / thermal red)
    L(δ)  = 1 / (1 + δ_eff²)                     (Lorentzian line shape)
    r     = r_lin·[discharging] + tpa·pw·E + fca·N   (total loss rate)
    E    ←  E·e^{−r·dt} + κ·L(δ)·P·dt·φ1(r·dt)   (exact exponential step)
    N    ←  N + (1 − e^{−dt/τ_fc})·(fc_gain·(pw·E)² − N)
    T    ←  T + (1 − e^{−dt/τ_th})·(th_gain·pw·E − T)

with P = max(u + γ·s(t−τ), 0) the pumped drive, φ1(x) = (1 − e^{−x})/x the
exponential-integrator weight, and the paper's charge/discharge asymmetry
(Eq. 6-7) modeled as carrier-injection gain cancelling the linear loss on the
charging branch (u > s(t−θ)) plus a branch-dependent coupling κ_c / κ_d.
N and T are closed adiabatically at tick start from the carried energy
(N₀ = fc_gain·(pw·E₀)², T₀ = th_gain·pw·E₀) — the scalar reservoir carry
stays one f32 per node, so every existing execution path (ref / fast /
Pallas ``kernels/dfr_scan`` tile loop, ``stream_chunk_k`` streaming,
``ReservoirGraph`` stages) accepts the model unchanged.

Exactness of the zero-power limit: at ``power_mw = 0`` the nonlinear terms
vanish, r and the pump are substep-constant, and the exponential step
telescopes exactly over any number of substeps — the auto-calibrated κ
(below) then reproduce ``SiliconMR``'s θ-corrected Eq. (6-7) to float
rounding for ANY ``n_substeps`` (devices/calibrate.py proves it).

Design-space sweeps: the (detuning, loss, power) operating point exists
twice — as frozen dataclass floats (hashable jit statics; the legacy
contract) and as a :class:`CMTSweepParams` *traced* pytree accepted by the
``*_p`` method variants, whose leaves may be per-batch-lane ``[B]`` arrays.
That is what lets ``devices/sweep.py`` fold a whole parameter grid into
batch lanes of ONE compiled program instead of retracing per point.
"""

from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


class CMTSweepParams(NamedTuple):
    """Traced operating-point parameters for design-space sweeps.

    Leaves are scalars or per-lane ``[B]`` arrays (one grid point per batch
    lane).  This is the ``dev_params`` pytree ``generate_states`` /
    ``fit_ridge_streaming`` / ``Experiment.run`` thread down to
    ``MRCavityCMT.node_update_p`` — an *operand*, so sweeping it never
    retraces the program.
    """

    detune: object = 0.0       # normalised detuning δ = 2(ω_p − ω_0)/Δω_FWHM
    loss_scale: object = 1.0   # linear loss multiplier on 1/τ_L
    power: object = 0.0        # input power scale (mW) — drives all NL terms


def _bparam(x, like):
    """Broadcast a sweep-parameter leaf against an elementwise operand.

    A scalar passes through; a ``[B]`` leaf gains trailing singleton dims to
    ride the leading batch axis of ``like`` (``[B]``, ``[B, N]``, …)."""
    x = jnp.asarray(x, like.dtype)
    if x.ndim == 0 or x.ndim >= like.ndim:
        return x
    return x.reshape(x.shape + (1,) * (like.ndim - x.ndim))


def _phi1(x):
    """φ1(x) = (1 − e^{−x})/x — the exact exponential-integrator pump weight.

    Guarded at x → 0 (the charging branch at zero power has r = 0 exactly):
    the Taylor limit 1 − x/2 takes over below 1e-6, where −expm1(−x)/x would
    divide rounding noise by rounding noise.
    """
    small = x <= 1e-6
    safe = jnp.where(small, jnp.ones_like(x), x)
    return jnp.where(small, 1.0 - 0.5 * x, -jnp.expm1(-safe) / safe)


@dataclasses.dataclass(frozen=True)
class MRCavityCMT:
    """CMT microring cavity neuron — physics-fidelity device model.

    Fields are Python floats (frozen dataclass: a hashable jit static, like
    every ``core/nonlinear.py`` model).  Geometry/operating point:

    * ``theta_ps``      — virtual-node tick θ (one integration window),
    * ``tau_l_ps``      — linear (photon-lifetime) loss time τ_L,
    * ``gamma``         — delayed-feedback strength (drive P = u + γ·s(t−τ)),
    * ``detune``        — normalised pump detuning δ at the operating point,
    * ``loss_scale``    — linear loss multiplier (waveguide/coupler excess),
    * ``power_mw``      — input power scale; 0 switches every nonlinear
      mechanism off (the calibrated-``SiliconMR`` small-signal limit),
    * ``n_substeps``    — fixed substeps per tick (static: the Pallas kernel
      unrolls them inside its VMEM tile loop).

    Nonlinear coefficients (normalised repro units, rates per ps): ``tpa``
    (two-photon absorption loss per mW·E), ``fca``/``fcd`` (free-carrier
    absorption / blue-shift per carrier), ``th_shift`` (thermal red-shift per
    unit ΔT), ``fc_gain``/``th_gain`` (carrier generation / self-heating
    drive), ``tau_fc_ps``/``tau_th_ps`` (carrier / thermal lifetimes).

    ``kappa_charge``/``kappa_discharge`` override the pump couplings; the
    default ``None`` auto-calibrates them at the dataclass operating point so
    the zero-power tick map IS ``SiliconMR``'s θ-corrected Eq. (6-7):

        κ_d = loss_scale·(1 + δ²)/τ_L        (discharge: α·P + (1−α)·E₀)
        κ_c = α·(1 + δ²)/θ                   (charge:    α·P + E₀)

    with α = 1 − exp(−θ·loss_scale/τ_L).  The κ stay anchored at the
    calibration detuning when ``CMTSweepParams`` sweeps δ — moving the pump
    off resonance *loses* Lorentzian coupling, which is the robustness
    physics the sweep exists to measure.
    """

    theta_ps: float = 50.0
    tau_l_ps: float = 50.0
    gamma: float = 0.9
    detune: float = 0.0
    loss_scale: float = 1.0
    power_mw: float = 1.0
    n_substeps: int = 4
    kappa_charge: float | None = None
    kappa_discharge: float | None = None
    tpa: float = 0.01
    fca: float = 0.05
    fcd: float = 4.0
    th_shift: float = 0.4
    fc_gain: float = 0.2
    th_gain: float = 0.5
    tau_fc_ps: float = 1000.0
    tau_th_ps: float = 10000.0

    name: str = dataclasses.field(default="MR cavity (CMT)", repr=False)

    def __post_init__(self):
        if self.n_substeps < 1:
            raise ValueError(f"n_substeps must be >= 1, got {self.n_substeps}")
        for f in ("theta_ps", "tau_l_ps", "tau_fc_ps", "tau_th_ps"):
            if getattr(self, f) <= 0.0:
                raise ValueError(f"{f} must be positive, got {getattr(self, f)}")
        if self.loss_scale < 0.0 or self.power_mw < 0.0:
            raise ValueError("loss_scale and power_mw must be non-negative")

    # -- calibrated small-signal quantities (Python floats: jit statics) -----
    @property
    def alpha(self) -> float:
        """Zero-power per-tick linear response 1 − exp(−θ·loss_scale/τ_L)."""
        return 1.0 - math.exp(-self.theta_ps * self.loss_scale / self.tau_l_ps)

    @property
    def kappa_d(self) -> float:
        if self.kappa_discharge is not None:
            return self.kappa_discharge
        return (1.0 + self.detune ** 2) * self.loss_scale / self.tau_l_ps

    @property
    def kappa_c(self) -> float:
        if self.kappa_charge is not None:
            return self.kappa_charge
        return self.alpha * (1.0 + self.detune ** 2) / self.theta_ps

    def sweep_point(self) -> CMTSweepParams:
        """The dataclass operating point as a (float-leaf) sweep pytree —
        the unswept contract methods evaluate exactly this point."""
        return CMTSweepParams(detune=self.detune, loss_scale=self.loss_scale,
                              power=self.power_mw)

    # -- swept-parameter tick integration ------------------------------------
    def node_update_p(self, p: CMTSweepParams, u, s_tau, s_prev_node):
        """One virtual-node tick at traced operating point ``p``.

        Elementwise over any leading shape (the ref path's ``[B]`` slices,
        the Pallas kernel's ``[S, L]`` VMEM tiles); ``p`` leaves broadcast
        against the leading batch axis.  The substep loop is a Python loop —
        ``n_substeps`` is static, so the kernel unrolls it in-register.
        """
        dt = self.theta_ps / self.n_substeps
        det = _bparam(p.detune, u)
        lin = _bparam(p.loss_scale, u) * jnp.asarray(1.0 / self.tau_l_ps, u.dtype)
        pw = _bparam(p.power, u)

        drive = jnp.maximum(u + self.gamma * s_tau, 0.0)
        charging = u > s_prev_node
        kap = jnp.where(charging, jnp.asarray(self.kappa_c, u.dtype),
                        jnp.asarray(self.kappa_d, u.dtype))
        # carrier-injection gain cancels the linear loss while charging
        lin_eff = jnp.where(charging, jnp.zeros_like(lin), lin)

        e = jnp.maximum(s_prev_node, 0.0)
        # slow states closed adiabatically at tick start from the carried E₀
        n_fc = self.fc_gain * (pw * e) ** 2
        t_th = self.th_gain * (pw * e)
        g_fc = -math.expm1(-dt / self.tau_fc_ps)
        g_th = -math.expm1(-dt / self.tau_th_ps)
        for _ in range(self.n_substeps):
            delta = det - self.fcd * n_fc + self.th_shift * t_th
            lor = 1.0 / (1.0 + delta * delta)
            r = lin_eff + self.tpa * (pw * e) + self.fca * n_fc
            x = r * dt
            e = e * jnp.exp(-x) + (kap * lor * drive) * (dt * _phi1(x))
            n_fc = n_fc + g_fc * (self.fc_gain * (pw * e) ** 2 - n_fc)
            t_th = t_th + g_th * (self.th_gain * (pw * e) - t_th)
        return e

    def period_update_p(self, p: CMTSweepParams, u_k, s_prev, s_last):
        """Whole-period chain at traced point ``p`` — sequential over nodes
        (the realised energy feeds the next node's branch, like SiliconMR)."""

        def node(s_pn, xs):
            u_i, s_tau_i = xs
            s_i = self.node_update_p(p, u_i, s_tau_i, s_pn)
            return s_i, s_i

        xs = (jnp.moveaxis(u_k, -1, 0), jnp.moveaxis(s_prev, -1, 0))
        _, s_nodes = jax.lax.scan(node, s_last, xs)
        return jnp.moveaxis(s_nodes, 0, -1)

    # -- the core/nonlinear.py model contract --------------------------------
    def node_update(self, u, s_tau, s_prev_node):
        return self.node_update_p(self.sweep_point(), u, s_tau, s_prev_node)

    def period_update(self, u_k, s_prev, s_last):
        return self.period_update_p(self.sweep_point(), u_k, s_prev, s_last)
