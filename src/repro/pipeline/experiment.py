"""Jit-end-to-end DFRC experiment pipeline.

One compiled function runs the paper's whole claims path — input layer
(normalise + sample-and-hold + MLS mask), reservoir layer (``ref`` / ``fast``
/ ``kernel`` state generation), output layer (streaming-Gram ridge readout
with GCV λ selection) and the evaluation metrics — *batched over task
instances*.  Where the host-side ``DFRCAccelerator`` runs one accelerator on
one series with numpy in the loop, ``Experiment.run`` takes ``[B, T]`` input
stacks (B independent task instances: seeds, SNR points, hyperparameter
draws, WDM channels) and produces per-instance predictions and metrics from
a single jit call, so a sweep compiles once and runs as one XLA program.

Scaling hooks:

* the instance axis is constrained over the ("pod", "data") mesh axes via
  parallel/sharding.maybe_shard — under an active mesh (compat.use_mesh) the
  sweep shards across devices with no code change;
* the Gram accumulation inside the readout fit can run through the
  kernels/ridge_gram Pallas kernel (``readout_use_kernel=True``), and the
  reservoir through kernels/dfr_scan (``state_method="kernel"``);
* ``stream_chunk_k`` switches the whole run onto the streaming fused path
  (DESIGN.md §8): train fit and test evaluation scan over K-chunks with the
  reservoir state carried between chunks and per-chunk states folded into
  running Gram / error accumulators, so peak device memory for the run is
  O(B·chunk·N) instead of O(B·T·N);
* ``channel_states`` evaluates per-channel (mask, input) pairs for
  WDM-multiplexed reservoir ensembles (examples/wdm_scaling.py) — on the
  kernel path via the per-lane mask tiling, still one Pallas launch.

Numerics note: the readout solve is f32 on device (eigh of the Gram matrix),
versus the host trainer's float64 SVD; on the paper's tasks the resulting
NRMSE/SER differences are within the run-to-run seed spread, and the
regression tests (tests/test_pipeline.py) pin thresholds on this path.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import ReservoirGraph, ReservoirStage, build_stage_masks
from repro.core.masking import make_mask, sample_and_hold
from repro.core.metrics import VAR_EPS
from repro.core.nonlinear import NLModel, SiliconMR
from repro.core.reservoir import generate_channel_states, generate_states
from repro.core.tasks import SYMBOLS
from repro.parallel.sharding import maybe_shard

from .ridge import (apply_readout, composed_chunk_states_fn, fit_ridge_batched,
                    fit_ridge_streaming, fit_ridge_streaming_composed,
                    fit_ridge_streaming_shared, fit_ridge_streaming_wdm,
                    with_bias)

_SYMBOLS = tuple(float(s) for s in SYMBOLS)


@dataclasses.dataclass(frozen=True)
class ExperimentConfig:
    """Static (hashable) configuration of one batched DFRC experiment.

    Field semantics mirror core/accelerator.DFRCConfig — see there for the
    physics rationale of each knob; differences are noted inline.
    """

    model: NLModel = dataclasses.field(default_factory=SiliconMR)
    n_nodes: int = 900
    mask_levels: tuple[float, float] = (0.0, 1.0)
    mask_seed: int = 1
    input_gain: float = 1.0
    normalize_input: bool = True   # per-instance affine map to [0, 1]
    washout: int = 50
    ridge_l2: tuple[float, ...] = (1e-6,)   # always a tuple here (GCV-selected)
    state_noise_rel: float = 0.003
    noise_seed: int = 0
    state_method: str = "fast"     # "fast" | "ref" | "kernel"
    readout_use_kernel: bool = False
    quantize: bool = False
    # Streaming fused path (DESIGN.md §8): a chunk length in periods switches
    # the whole run onto pipeline/ridge.fit_ridge_streaming + chunked test
    # evaluation — the full [B, T, N] state tensor never exists in HBM; peak
    # state memory is O(B·stream_chunk_k·N).  NOTE the readout solve is then
    # always the Gram/eigh route (G is all a streaming fit ever has —
    # SVD-of-X needs X resident), regardless of ``readout_use_kernel``,
    # which only picks HOW G accumulates (Pallas kernel vs einsum).  Parity
    # is therefore stated vs the materialized *Gram* path; vs the unfused
    # SVD default the last decade of λ-conditioning can differ (ridge.py
    # ``solve_gcv_svd`` note).  ``state_noise_mode`` picks how digitiser
    # noise enters the readout fit:
    #   "sampled"  — materialize state noise and add it (unfused route only;
    #                needs the state tensor, so incompatible with streaming),
    #   "diagonal" — add the expected Gram of the noise, σ²·T_fit·I, to the
    #                state block of G (single-pass; the streaming route).
    stream_chunk_k: int | None = None
    state_noise_mode: str = "sampled"
    # Streaming state-chunk dtype (DESIGN.md §9): "bfloat16" halves the HBM
    # round-trip of every [B, chunk, N] state block on both streaming scans
    # (fit and eval).  The chunk-to-chunk carry, targets and Gram
    # accumulators stay f32, so the scan itself resumes exactly; the emitted
    # chunks are rounded, which makes parity vs f32 chunks looser (documented
    # bounds, tests/benchmark) and rounds the train -> test carry too when
    # the train length is not chunk-aligned (ridge.fit_ridge_streaming note).
    stream_state_dtype: str = "float32"
    # collect_y_pred=False switches the evaluation to metrics-only: the
    # per-chunk predictions are never stacked back into a [B, T_test, C]
    # block, so a long streamed test set costs O(B·chunk) instead of O(B·T)
    # — ExperimentResult.y_pred is then None.  Default True for API compat.
    collect_y_pred: bool = True
    # Pallas tiling knobs (only read by the kernel paths):
    #   kernel_block_s — dfr_scan sublane tile; None = smallest of {1, 2, 4, 8}
    #     covering the batch (a B ≤ 128 sweep pads to 128 lanes, not 1024).
    #   readout_block_t — ridge_gram T tile (sublane-aligned internally).
    kernel_block_s: int | None = None
    readout_block_t: int = 512
    # Composed reservoir graph (DESIGN.md §13): a core.graph.ReservoirGraph
    # (or a single ReservoirStage, auto-chained) replaces the single delay
    # loop — deep/cascaded stages and multi-loop stages run as a per-chunk
    # stage chain inside the streaming scans, readout features the
    # concatenation of every stage's nodes (width = topology.width).  The
    # composed path is streaming-ONLY (requires ``stream_chunk_k``): chunk
    # chaining is what keeps every stage at O(B·chunk·L·N) instead of a
    # full-T block per stage, and the materialized fallback would defeat
    # exactly that.  ``n_nodes``/``mask_seed``/``mask_levels`` are ignored in
    # favour of the per-stage settings; a depth-1/loops-1 topology reproduces
    # the legacy single-reservoir fit bit for bit.
    topology: ReservoirGraph | None = None

    def __post_init__(self):
        if not isinstance(self.ridge_l2, tuple):
            object.__setattr__(self, "ridge_l2", _as_tuple(self.ridge_l2))
        if isinstance(self.topology, ReservoirStage):
            object.__setattr__(self, "topology",
                               ReservoirGraph(stages=(self.topology,)))
        if self.topology is not None:
            if not isinstance(self.topology, ReservoirGraph):
                raise TypeError(f"topology must be a ReservoirGraph or "
                                f"ReservoirStage, got {self.topology!r}")
            if self.stream_chunk_k is None:
                raise ValueError(
                    "a composed topology runs streaming-only (per-chunk stage "
                    "chaining is its memory contract); set stream_chunk_k")
        if self.state_noise_mode not in ("sampled", "diagonal"):
            raise ValueError(f"unknown state_noise_mode {self.state_noise_mode!r}")
        if self.stream_state_dtype not in ("float32", "bfloat16"):
            raise ValueError(
                f"unknown stream_state_dtype {self.stream_state_dtype!r} "
                "(expected 'float32' or 'bfloat16')")
        if self.stream_state_dtype != "float32" and self.stream_chunk_k is None:
            raise ValueError(
                "stream_state_dtype narrows the *streaming* state chunks; "
                "set stream_chunk_k (the materialized path keeps f32 states)")
        if self.state_noise_rel:
            if self.stream_chunk_k is not None and self.state_noise_mode != "diagonal":
                raise ValueError(
                    "the streaming path cannot materialize sampled state noise; "
                    "set state_noise_mode='diagonal' (noise as its expected "
                    "Tikhonov diagonal) or state_noise_rel=0")
            if self.stream_chunk_k is None and self.state_noise_mode == "diagonal":
                raise ValueError(
                    "state_noise_mode='diagonal' is the streaming-path noise "
                    "model (set stream_chunk_k); the unfused route keeps the "
                    "sampled-noise path")

    @property
    def _stream_state_dtype_arg(self) -> str | None:
        """stream_state_dtype as the kernels' ``state_dtype`` argument."""
        return None if self.stream_state_dtype == "float32" else self.stream_state_dtype

    @classmethod
    def from_dfrc(cls, cfg) -> "ExperimentConfig":
        """Lift a core DFRCConfig onto the batched pipeline.

        The pipeline's readout is always the ridge/GCV path (the paper's
        pinv is the λ→0 limit; core/readout.py keeps the exact pinv for the
        faithfulness benchmarks).
        """
        return cls(
            model=cfg.model,
            n_nodes=cfg.n_nodes,
            mask_levels=tuple(cfg.mask_levels),
            mask_seed=cfg.mask_seed,
            input_gain=cfg.input_gain,
            normalize_input=cfg.normalize_input,
            washout=cfg.washout,
            ridge_l2=_as_tuple(cfg.ridge_l2),
            state_noise_rel=cfg.state_noise_rel,
            noise_seed=cfg.noise_seed,
            state_method=cfg.state_method,
            quantize=cfg.quantize,
        )


def _as_tuple(l2) -> tuple[float, ...]:
    return tuple(float(v) for v in l2) if isinstance(l2, (tuple, list)) else (float(l2),)


@dataclasses.dataclass(frozen=True)
class ExperimentResult:
    """Per-instance outputs of one Experiment.run call (host numpy arrays).

    Single-channel targets (the common case) keep the historical 2-D shapes;
    C > 1 output channels add a trailing channel axis instead of being
    silently dropped.  ``y_pred`` is None when the run was metrics-only
    (``collect_y_pred=False``): the streamed evaluation then never stacks
    the per-chunk predictions back into a [B, T_test, C] block.
    """

    y_pred: np.ndarray | None  # [B, T_test] (or [B, T_test, C]); quantized iff cfg.quantize
    nrmse: np.ndarray       # [B]  (mean of per-channel NRMSEs for C > 1)
    ser: np.ndarray         # [B]  (vs 4-PAM quantized predictions)
    lam: np.ndarray         # [B]  selected ridge λ per instance
    readout_w: np.ndarray   # [B, N + 1] (or [B, N + 1, C])

    @property
    def batch(self) -> int:
        return self.nrmse.shape[0]


def _canon_batch(x, name: str) -> jnp.ndarray:
    x = jnp.asarray(x, jnp.float32)
    if x.ndim == 1:
        return x[None, :]
    if x.ndim == 2:
        return x
    raise ValueError(f"{name} must be [T] or [B, T], got {x.shape}")


def _canon_targets(x, name: str, inputs: jnp.ndarray) -> jnp.ndarray:
    """Targets matching ``inputs`` [B, T]: returns [B, T] or [B, T, C].

    A trailing channel axis is kept only for C > 1 ([B, T, 1] squeezes to
    [B, T]), so single-channel results keep their historical shapes.
    """
    x = jnp.asarray(x, jnp.float32)
    b, t = inputs.shape
    if x.ndim == 1:
        x = x[None, :]
    elif x.ndim == 2 and b == 1 and x.shape != (b, t) and x.shape[0] == t:
        x = x[None, :, :]            # [T, C] with 1-D inputs
    if x.ndim == 3 and x.shape[-1] == 1:
        x = x[..., 0]
    if x.shape[:2] != (b, t):
        raise ValueError(f"{name} shape {x.shape} does not match inputs ({b}, {t})")
    return x


def _quantize(y: jnp.ndarray) -> jnp.ndarray:
    sym = jnp.asarray(_SYMBOLS, y.dtype)
    return sym[jnp.argmin(jnp.abs(y[..., None] - sym), axis=-1)]


def _gen_states(cfg: ExperimentConfig, mask, j, *, wdm: bool, s0=None,
                return_final: bool = False, state_dtype=None,
                dev_params=None):
    """State generation for both workloads: ``mask`` is [N] broadcast over B
    task instances (the paper's sweep) or, with ``wdm=True``, [R, N] per-lane
    masks (one wavelength channel per batch row — DESIGN.md §9).

    ``dev_params`` threads traced per-lane device parameters into the model
    (device design-space sweeps, DESIGN.md §14) — single-mask workloads only;
    the WDM per-channel-mask path keeps the static-model contract."""
    if wdm:
        if dev_params is not None:
            raise NotImplementedError(
                "dev_params sweeps use the single-mask workload; per-channel "
                "WDM masks with per-lane device parameters are not supported")
        gen = generate_channel_states
        return gen(cfg.model, j, mask, s0=s0, method=cfg.state_method,
                   block_s=cfg.kernel_block_s, return_final=return_final,
                   state_dtype=state_dtype)
    return generate_states(cfg.model, j, mask, s0=s0, method=cfg.state_method,
                           block_s=cfg.kernel_block_s,
                           return_final=return_final,
                           state_dtype=state_dtype, dev_params=dev_params)


def _eval_streaming(cfg: ExperimentConfig, mask, j_te, te_tg3, w_fit, s0, *,
                    wdm: bool = False, states_fn=None, dev_params=None):
    """Chunked test evaluation: states per chunk, running error accumulators.

    ``te_tg3`` [B, T, C].  Returns (y_raw [B, T, C] or None, acc) where acc
    packs the running error statistics (err2 = Σ_t (ŷ − y)², the 4-PAM
    symbol-mismatch count, and target Σy/Σy² for the variance), all
    accumulated inside the chunk scan so neither a [B, T, N] state block nor
    any other full-stream reduction is resident (DESIGN.md §8) — the target
    variance in particular is derived from the in-scan moments, not a
    ``jnp.var`` pass over the full target block.  With
    ``cfg.collect_y_pred=False`` the per-chunk predictions are consumed by
    the accumulators and dropped — the scan stacks nothing, so the O(B·T·C)
    prediction block never exists either (metrics-only mode).

    ``states_fn`` overrides the per-chunk state producer (a ``(j_chunk,
    carry) -> (features, carry')`` transformer; ``s0`` then a matching carry
    pytree) — the composed-graph and shared-readout paths pass theirs so
    test evaluation traces the exact stage ops the fit traced; ``None``
    keeps the legacy mask/``wdm`` path with identical traced ops.
    """
    from .ridge import _chunk_axis, _chunk_layout

    b, t_total = j_te.shape[0], j_te.shape[1]
    c_cols = te_tg3.shape[-1]
    chunk_k = cfg.stream_chunk_k
    n_chunks, t_padded = _chunk_layout(t_total, chunk_k)
    jp = jnp.pad(j_te, ((0, 0), (0, t_padded - t_total))
                 + ((0, 0),) * (j_te.ndim - 2))
    yp = jnp.pad(te_tg3, ((0, 0), (0, t_padded - t_total), (0, 0)))

    # Variance accumulators are *shifted* by the stream's first sample: the
    # single-pass E[y²] − E[y]² identity cancels catastrophically in f32
    # when |mean| ≫ std (e.g. a narrow signal riding a large offset), but
    # applied to d = y − y[0] the cancellation is against ~std², not mean².
    # y[0] is one [B, C] gather, not a full-stream pass.
    shift = te_tg3[:, 0, :]                          # [B, C]
    carry0 = (jax.tree.map(lambda x: jnp.asarray(x, jnp.float32), s0),
              jnp.zeros((b, c_cols), jnp.float32),   # Σ (ŷ − y)²
              jnp.zeros((b,), jnp.float32),          # symbol mismatches
              jnp.zeros((b, c_cols), jnp.float32),   # Σ (y − y₀)
              jnp.zeros((b, c_cols), jnp.float32))   # Σ (y − y₀)²
    xs = (_chunk_axis(jp, n_chunks, chunk_k),
          _chunk_axis(yp, n_chunks, chunk_k),
          jnp.arange(n_chunks, dtype=jnp.int32) * chunk_k)

    def body(carry, chunk):
        s, err2, ser_cnt, y_sum, y_sq = carry
        j_c, y_c, t_start = chunk
        if states_fn is not None:
            states, s = states_fn(j_c, s)
        else:
            states, s = _gen_states(cfg, mask, j_c, wdm=wdm, s0=s,
                                    return_final=True,
                                    state_dtype=cfg._stream_state_dtype_arg,
                                    dev_params=dev_params)
        y_hat = jnp.einsum("btf,bfc->btc", with_bias(states), w_fit,
                           preferred_element_type=jnp.float32)
        tidx = t_start + jnp.arange(chunk_k, dtype=jnp.int32)
        valid = (tidx < t_total).astype(jnp.float32)[None, :, None]
        err = (y_hat - y_c) * valid
        err2 = err2 + jnp.sum(err * err, axis=1)
        mism = (_quantize(y_hat) != _quantize(y_c)) & (valid > 0)
        ser_cnt = ser_cnt + jnp.sum(mism.astype(jnp.float32), axis=(1, 2))
        yv = (y_c - shift[:, None, :]) * valid
        y_sum = y_sum + jnp.sum(yv, axis=1)
        y_sq = y_sq + jnp.sum(yv * yv, axis=1)
        return (s, err2, ser_cnt, y_sum, y_sq), (
            y_hat if cfg.collect_y_pred else None)

    (_, *acc), y_chunks = jax.lax.scan(body, carry0, xs)
    if not cfg.collect_y_pred:
        return None, acc
    y_raw = jnp.moveaxis(y_chunks, 0, 1).reshape(b, t_padded, c_cols)[:, :t_total]
    return y_raw, acc


def _streaming_metrics(acc, t_test: int, *, channel_axis: bool):
    """NRMSE/SER from the running accumulators — same conventions as the
    materialized path: per-channel NRMSE (that channel's variance, computed
    from the in-scan shifted Σ(y−y₀)/Σ(y−y₀)² moments — variance is
    shift-invariant) then channel-mean; SER over quantized-vs-quantized
    symbols."""
    err2, ser_cnt, y_sum, y_sq = acc
    mean = y_sum / t_test
    var = jnp.maximum(y_sq / t_test - mean * mean, 0.0)   # [B, C]
    nrmse_ch = jnp.sqrt((err2 / t_test) / (var + VAR_EPS))
    nrmse = jnp.mean(nrmse_ch, axis=-1) if channel_axis else nrmse_ch[:, 0]
    ser = ser_cnt / (t_test * err2.shape[-1])
    return nrmse, ser


@functools.partial(jax.jit, static_argnames=("cfg", "wdm", "shared"))
def _run_pipeline(cfg: ExperimentConfig, mask, tr_in, tr_tg, te_in, te_tg,
                  wdm: bool = False, shared: bool = False, dev_params=None):
    """The whole experiment as one XLA program.  All arrays [B, T*].

    ``dev_params`` (an *operand* pytree, e.g. ``devices.cmt.CMTSweepParams``
    with per-lane [B] leaves) sweeps the device operating point across batch
    lanes without retracing: same cfg + same shapes + new parameter VALUES
    reuse the compiled program (DESIGN.md §14).  Single-mask workloads only
    (``wdm``/``shared``/``topology`` keep the static-model contract); the
    ``None`` default adds no operands, so legacy call sites trace the exact
    program they always did.

    ``wdm=True`` runs the WDM ensemble workload: the batch axis is R
    wavelength channels and ``mask`` is a per-channel [R, N] stack — state
    generation swaps to the per-lane-mask path (``generate_channel_states``,
    one Pallas launch for all channels) and the streamed fit to
    ``fit_ridge_streaming_wdm``; everything else (input layer, readout
    solve, metrics) is the same program.

    ``shared=True`` (with ``wdm=True``) is the shared-readout WDM mode:
    ONE readout over the concatenation of all R channels' states
    (``fit_ridge_streaming_shared``), targets [1, K(, C)] — one task for
    the ensemble.  ``cfg.topology`` switches the streaming branch onto the
    composed stage-chain fit/eval (``mask`` then the per-stage mask-stack
    tuple); both are streaming-only (enforced at config construction).
    """
    # -- input layer: per-instance normalisation + sample-and-hold + gain ----
    if cfg.normalize_input:
        lo = jnp.min(tr_in, axis=1, keepdims=True)
        scale = 1.0 / (jnp.max(tr_in, axis=1, keepdims=True) - lo + 1e-12)
    else:
        lo, scale = 0.0, 1.0
    j_tr = sample_and_hold((tr_in - lo) * scale * cfg.input_gain)
    j_te = sample_and_hold((te_in - lo) * scale * cfg.input_gain)
    j_tr = maybe_shard(j_tr, ("pod", "data"))
    j_te = maybe_shard(j_te, ("pod", "data"))

    if cfg.stream_chunk_k is not None:
        # -- streaming fused path (DESIGN.md §8/§9/§13): reservoir chunks
        # feed the accumulate-into Gram kernel inside ONE lax.scan; test
        # evaluation streams too.  The [B, T, N] state tensor never exists.
        noise_rel = (cfg.state_noise_rel
                     if cfg.state_noise_mode == "diagonal" else 0.0)
        kw = dict(washout=cfg.washout, chunk_k=cfg.stream_chunk_k,
                  lambdas=cfg.ridge_l2, state_method=cfg.state_method,
                  block_s=cfg.kernel_block_s,
                  use_kernel=cfg.readout_use_kernel,
                  block_t=cfg.readout_block_t,
                  state_dtype=cfg._stream_state_dtype_arg,
                  noise_rel=noise_rel)
        te_tg3 = te_tg[..., None] if te_tg.ndim == 2 else te_tg
        if cfg.topology is not None:
            # composed stage chain: fit and eval share ONE per-chunk
            # transformer, so test states trace the exact stage ops the
            # Gram accumulation saw (pipeline/ridge.composed_chunk_states_fn)
            w_fit, lam_idx, s_carry = fit_ridge_streaming_composed(
                cfg.topology, mask, j_tr, tr_tg, **kw)
            eval_fn = composed_chunk_states_fn(
                cfg.topology, mask, state_method=cfg.state_method,
                block_s=cfg.kernel_block_s,
                state_dtype=cfg._stream_state_dtype_arg)
            y_raw3, acc = _eval_streaming(cfg, mask, j_te, te_tg3,
                                          w_fit, s_carry, states_fn=eval_fn)
        elif shared:
            # shared-readout WDM: one [R·N + 1] readout, channel axis rides
            # the chunk scan as a trailing input dim (B = 1 for the Gram)
            r, n_nodes = mask.shape
            w_1, lam_1, s_1 = fit_ridge_streaming_shared(
                cfg.model, mask, j_tr, tr_tg[0], **kw)
            w_fit, lam_idx = w_1[None], lam_1[None]

            def eval_fn(j_c, carries):         # j_c [1, chunk, R]
                states, s_next = _gen_states(
                    cfg, mask, j_c[0].T, wdm=True, s0=carries[0][0],
                    return_final=True,
                    state_dtype=cfg._stream_state_dtype_arg)
                feats = jnp.moveaxis(states, 0, 1).reshape(
                    j_c.shape[1], r * n_nodes)[None]
                return feats, (s_next[None],)

            y_raw3, acc = _eval_streaming(
                cfg, mask, jnp.moveaxis(j_te, 0, 1)[None], te_tg3,
                w_fit, (s_1[None],), states_fn=eval_fn)
        elif dev_params is not None:
            w_fit, lam_idx, s_carry = fit_ridge_streaming(
                cfg.model, mask, j_tr, tr_tg, dev_params=dev_params, **kw)
            y_raw3, acc = _eval_streaming(cfg, mask, j_te, te_tg3,
                                          w_fit, s_carry,
                                          dev_params=dev_params)
        else:
            fit = fit_ridge_streaming_wdm if wdm else fit_ridge_streaming
            w_fit, lam_idx, s_carry = fit(cfg.model, mask, j_tr, tr_tg, **kw)
            y_raw3, acc = _eval_streaming(cfg, mask, j_te, te_tg3,
                                          w_fit, s_carry, wdm=wdm)
        nrmse, ser = _streaming_metrics(acc, te_tg3.shape[1],
                                        channel_axis=te_tg.ndim == 3)
        lam = jnp.asarray(cfg.ridge_l2, jnp.float32)[lam_idx]
        if y_raw3 is None:
            return None, nrmse, ser, lam, w_fit
        y_raw = y_raw3 if te_tg.ndim == 3 else y_raw3[..., 0]
        y_out = _quantize(y_raw) if cfg.quantize else y_raw
        return y_out, nrmse, ser, lam, w_fit

    # -- reservoir layer: batched state generation, carry train -> test ------
    st_tr, s_carry = _gen_states(cfg, mask, j_tr, wdm=wdm, return_final=True,
                                 dev_params=dev_params)
    st_te = _gen_states(cfg, mask, j_te, wdm=wdm, s0=s_carry,
                        dev_params=dev_params)
    st_tr = maybe_shard(st_tr, ("pod", "data"))
    st_te = maybe_shard(st_te, ("pod", "data"))

    # -- output layer: digitiser noise + per-instance ridge/GCV fit ----------
    w = cfg.washout
    st_fit = st_tr[:, w:]
    y_fit = tr_tg[:, w:]
    if cfg.state_noise_rel:
        sigma = cfg.state_noise_rel * jnp.std(st_fit, axis=(1, 2), keepdims=True)
        noise = jax.random.normal(jax.random.PRNGKey(cfg.noise_seed), st_fit.shape,
                                  st_fit.dtype)
        st_fit = st_fit + sigma * noise

    # Kernel path: ONE batch-gridded pallas_call over the instance stack
    # (ridge.fit_ridge_batched); jnp path: vmapped SVD solve.
    w_fit, lam_idx = fit_ridge_batched(
        st_fit, y_fit, lambdas=cfg.ridge_l2,
        use_kernel=cfg.readout_use_kernel, block_t=cfg.readout_block_t)

    # -- evaluation -----------------------------------------------------------
    y_raw = jax.vmap(apply_readout)(st_te, w_fit)      # [B, T_test(, C)]
    y_sym = _quantize(y_raw)
    inst_axes = tuple(range(1, y_raw.ndim))            # all but the batch axis
    err = y_raw - te_tg
    # NRMSE per channel (normalised by that channel's variance, reduced over
    # T only), then channel-mean — a pooled T×C reduction would let a
    # high-variance channel mask total failure on a low-variance one.
    var = jnp.var(te_tg, axis=1)                       # [B(, C)]
    nrmse_ch = jnp.sqrt(jnp.mean(err * err, axis=1) / (var + VAR_EPS))
    nrmse = nrmse_ch if nrmse_ch.ndim == 1 else jnp.mean(nrmse_ch, axis=-1)
    # SER on quantized-vs-quantized symbols: targets that round-tripped
    # through a wider dtype (f64 task gen -> f32 canon) may sit eps off the
    # nominal 4-PAM levels; raw float equality would count those as errors.
    ser = jnp.mean((y_sym != _quantize(te_tg)).astype(jnp.float32), axis=inst_axes)
    lam = jnp.asarray(cfg.ridge_l2, jnp.float32)[lam_idx]
    y_out = y_sym if cfg.quantize else y_raw
    if not cfg.collect_y_pred:
        return None, nrmse, ser, lam, w_fit
    return y_out, nrmse, ser, lam, w_fit


def _pack_result(y, nrmse, ser, lam, w) -> ExperimentResult:
    """Device outputs -> host ExperimentResult (shared by both experiments)."""
    # w is [B, N + 1, C]; drop the channel axis only when there is a
    # single output channel (C > 1 used to be silently truncated here).
    w = np.asarray(w)
    if w.shape[-1] == 1:
        w = w[..., 0]
    return ExperimentResult(
        y_pred=None if y is None else np.asarray(y),
        nrmse=np.asarray(nrmse), ser=np.asarray(ser),
        lam=np.asarray(lam), readout_w=w)


class Experiment:
    """Batched DFRC experiment: one jit call for fit + predict + metrics.

    >>> exp = Experiment(ExperimentConfig(model=SiliconMR(), n_nodes=200))
    >>> res = exp.run(tr_in, tr_tg, te_in, te_tg)   # arrays [B, T] (or [T])
    >>> res.nrmse                                    # [B]

    The compiled program is cached per (config, input shapes) by jax.jit;
    re-running with new data of the same shape does not recompile.
    """

    def __init__(self, config: ExperimentConfig):
        self.config = config
        if config.topology is not None:
            # per-stage mask stacks (tuple of [L, N]) replace the single mask
            self.mask = build_stage_masks(config.topology)
        else:
            self.mask = make_mask(config.n_nodes, levels=config.mask_levels,
                                  seed=config.mask_seed)

    def run(self, inputs_train, targets_train, inputs_test, targets_test,
            *, dev_params=None) -> ExperimentResult:
        """Fit readouts and evaluate, one task instance per batch row.

        Inputs are [B, T] (or [T], treated as B = 1); targets may carry a
        trailing channel axis ([B, T, C]) for multi-output readouts.
        Train/test lengths may differ; all instances in a batch share shapes
        (stack equal-length series; pad/trim upstream otherwise).

        ``dev_params`` sweeps the device operating point across the batch
        lanes (a traced pytree, e.g. ``devices.cmt.CMTSweepParams``; leaves
        scalar or [B]) — the design-space-exploration hook (DESIGN.md §14):
        every lane runs the same compiled program at its own device point,
        and re-running with new parameter values recompiles nothing.
        """
        tr_in = _canon_batch(inputs_train, "inputs_train")
        te_in = _canon_batch(inputs_test, "inputs_test")
        tr_tg = _canon_targets(targets_train, "targets_train", tr_in)
        te_tg = _canon_targets(targets_test, "targets_test", te_in)
        if tr_in.shape[0] != te_in.shape[0] or tr_tg.ndim != te_tg.ndim or (
                tr_tg.ndim == 3 and tr_tg.shape[-1] != te_tg.shape[-1]):
            raise ValueError(
                f"inconsistent batch shapes: train {tr_in.shape}/{tr_tg.shape}, "
                f"test {te_in.shape}/{te_tg.shape}")
        if dev_params is not None:
            if self.config.topology is not None:
                raise ValueError(
                    "dev_params with a composed topology is not supported; "
                    "sweep the single-loop workload")
            if self.config.state_method == "kernel":
                raise ValueError(
                    "dev_params rides the jnp state paths; set "
                    "state_method='fast' or 'ref' (ROADMAP: swept-params "
                    "kernel tiles)")
            b = tr_in.shape[0]
            for leaf in jax.tree.leaves(dev_params):
                arr = jnp.asarray(leaf)
                if arr.ndim > 1 or (arr.ndim == 1 and arr.shape[0] != b):
                    raise ValueError(
                        f"dev_params leaves must be scalars or [{b}] "
                        f"(one value per batch lane), got shape {arr.shape}")
        y, nrmse, ser, lam, w = _run_pipeline(
            self.config, self.mask, tr_in, tr_tg, te_in, te_tg,
            dev_params=dev_params)
        return _pack_result(y, nrmse, ser, lam, w)

    def run_dataset(self, ds) -> ExperimentResult:
        """Convenience for a core.tasks Dataset (single instance, B = 1)."""
        return self.run(ds.inputs_train, ds.targets_train,
                        ds.inputs_test, ds.targets_test)


@functools.partial(jax.jit, static_argnames=("model", "method", "block_s",
                                             "return_final", "state_dtype"))
def channel_states(model: NLModel, j: jnp.ndarray, masks: jnp.ndarray, *,
                   s0: jnp.ndarray | None = None, method: str = "fast",
                   block_s: int | None = None, return_final: bool = False,
                   state_dtype=None):
    """WDM ensemble states: per-channel masks over per-channel inputs.

    ``j`` [R, K] (one series per wavelength channel), ``masks`` [R, N] ->
    states [R, K, N].  ``s0`` [R, N] carries each channel's reservoir state
    across calls (train -> test).  One program evaluates all R channels in
    parallel — the software analogue of R wavelengths sharing the physical
    ring.

    Jitted wrapper over ``core.reservoir.generate_channel_states`` with full
    ``generate_states`` knob parity (DESIGN.md §9): ``return_final=True``
    adds the [R, N] carry (on the kernel path the VMEM-flush output, so a
    chunked caller never keeps the full [R, K, N] block alive just to
    resume), ``state_dtype`` narrows the emitted state tensor (bf16 chunks).

    ``method="kernel"`` rides the Pallas scan's per-lane mask path: each
    wavelength channel is a batch lane with its own [N] mask tile resident
    in VMEM (kernels/dfr_scan per-lane BlockSpec), so all R channels still
    run as ONE kernel launch — no per-channel vmap over ``pallas_call``.
    The jnp paths ("fast"/"ref") vmap over channels as before.
    """
    return generate_channel_states(model, j, masks, s0=s0, method=method,
                                   block_s=block_s, return_final=return_final,
                                   state_dtype=state_dtype)


class WDMExperiment:
    """WDM ensemble experiment: R wavelength channels, one delay loop.

    The chip-scale scaling scenario of the paper (Section VI): R microring
    wavelength channels share one physical delay loop, each carrying an
    independent input stream against its own MLS mask, each with its own
    readout — R× the throughput of one accelerator at constant optical
    hardware.  Software-side this is ``Experiment`` with the batch axis
    reinterpreted as channels and a per-channel [R, N] mask stack
    (DESIGN.md §9); with ``config.stream_chunk_k`` set, the run streams:
    the fit is ``fit_ridge_streaming_wdm`` (ONE chunk scan, per-channel
    Gram stacks, no [R, K, N] state tensor ever resident) and the test
    evaluation runs chunked with running NRMSE/SER accumulators — long WDM
    streams (K ≫ chunk) no longer fall back to O(R·K·N) memory.

    >>> cfg = ExperimentConfig(n_nodes=100, stream_chunk_k=512)
    >>> res = WDMExperiment(cfg, n_channels=16).run(tr_in, tr_tg, te_in, te_tg)
    >>> res.nrmse                                    # [R] — per channel

    Channel masks default to ``make_mask(n_nodes, seed=mask_seed + r)``;
    pass ``masks`` [R, N] to override.

    ``shared_readout=True`` switches to the shared-readout mode (DESIGN.md
    §13): the R channels observe ONE task (targets [K(, C)], one stream for
    the ensemble, inputs still [R, K] — e.g. R delayed/transformed views of
    one signal) and the fit trains a single [R·N + 1] readout over the
    concatenation of every channel's states, whose Gram carries the
    cross-channel correlation blocks the per-channel fits discard
    (``fit_ridge_streaming_shared``).  Result arrays are then ensemble-level
    (B = 1): ``nrmse``/``ser``/``lam`` [1], ``readout_w`` [1, R·N + 1(, C)].
    Streaming-only, like every composed mode.

    ``config.topology`` (per-channel composed graphs) builds per-stage
    [R, L, N] mask stacks — channel r, loop l seeded ``mask_seed + r·L + l``
    — and runs the composed streaming fit with channels as instances.
    """

    def __init__(self, config: ExperimentConfig, n_channels: int, *,
                 masks: jnp.ndarray | None = None,
                 shared_readout: bool = False):
        if n_channels < 1:
            raise ValueError(f"n_channels must be >= 1, got {n_channels}")
        self.config = config
        self.n_channels = n_channels
        self.shared_readout = shared_readout
        if shared_readout and config.stream_chunk_k is None:
            raise ValueError(
                "shared_readout accumulates ONE cross-channel Gram on the "
                "streaming path; set stream_chunk_k")
        if shared_readout and config.topology is not None:
            raise ValueError(
                "shared_readout with a composed topology is not supported; "
                "pick one readout generalisation per run")
        if config.topology is not None:
            if masks is not None:
                raise ValueError("with config.topology the per-stage mask "
                                 "stacks are derived; masks= is not accepted")
            self.masks = build_stage_masks(config.topology,
                                           channels=n_channels)
            return
        if masks is None:
            masks = jnp.stack([
                make_mask(config.n_nodes, levels=config.mask_levels,
                          seed=config.mask_seed + r)
                for r in range(n_channels)])
        else:
            masks = jnp.asarray(masks, jnp.float32)
        if masks.shape != (n_channels, config.n_nodes):
            raise ValueError(
                f"masks {masks.shape} do not match (R, N) = "
                f"({n_channels}, {config.n_nodes})")
        self.masks = masks

    def run(self, inputs_train, targets_train, inputs_test, targets_test) -> ExperimentResult:
        """Fit per-channel readouts and evaluate, one channel per batch row.

        Inputs are [R, K] (R = ``n_channels``); targets may carry a trailing
        output-channel axis ([R, K, C]).  Result arrays are per wavelength
        channel: ``nrmse``/``ser``/``lam`` [R], ``readout_w`` [R, N + 1(, C)].
        With ``shared_readout=True`` targets are ONE stream ([K] or [K, C])
        and results are ensemble-level (see class docstring).
        """
        tr_in = _canon_batch(inputs_train, "inputs_train")
        te_in = _canon_batch(inputs_test, "inputs_test")
        if tr_in.shape[0] != self.n_channels or te_in.shape[0] != self.n_channels:
            raise ValueError(
                f"expected {self.n_channels} channel rows, got train "
                f"{tr_in.shape} / test {te_in.shape}")
        if self.shared_readout:
            # one target stream for the whole ensemble -> canon against a
            # B = 1 view of the stream length
            tr_tg = _canon_targets(targets_train, "targets_train", tr_in[:1])
            te_tg = _canon_targets(targets_test, "targets_test", te_in[:1])
        else:
            tr_tg = _canon_targets(targets_train, "targets_train", tr_in)
            te_tg = _canon_targets(targets_test, "targets_test", te_in)
        if tr_tg.ndim != te_tg.ndim or (
                tr_tg.ndim == 3 and tr_tg.shape[-1] != te_tg.shape[-1]):
            raise ValueError(
                f"inconsistent target shapes: train {tr_tg.shape}, "
                f"test {te_tg.shape}")
        y, nrmse, ser, lam, w = _run_pipeline(
            self.config, self.masks, tr_in, tr_tg, te_in, te_tg, wdm=True,
            shared=self.shared_readout)
        return _pack_result(y, nrmse, ser, lam, w)
