"""Online-learning DFR sessions: per-stream adaptive readouts (DESIGN.md §10).

The paper's "98% faster training" pitch rests on the readout being a tiny
linear solve ([F, C] per stream) over a shared photonic reservoir — exactly
the shape where *per-user adaptive* readouts are nearly free at serving
scale.  This module packages the streaming-fit machinery (pipeline/ridge:
``dfr_scan`` ``s0``/``return_final`` carry + accumulate-into Gram folds) as
an online-update engine:

* ``SessionState`` — one pytree holding everything a live stream needs to
  resume mid-flight: the reservoir carry ``s`` (the DFR analogue of a KV
  cache), the running (optionally λ-decayed) Gram/moment statistics, the
  current readout, and the per-session period counter that tracks the
  washout phase.  All leaves carry a leading batch axis, so one state
  object IS a continuously-batched slab of B independent sessions.
* ``session_init / session_update / session_predict / session_step`` — pure,
  jit-once step functions over that pytree.  ``session_step`` is the serving
  tick: ONE reservoir pass per chunk shared by predict (with the readout
  solved from *earlier* data) and update (fold the chunk into the Gram,
  optionally re-solve).  Because they are pure pytree -> pytree maps they
  compose with ``jax.vmap``/``jax.jit``/donation, and the batch axis shards
  over the ("pod", "data") mesh axes like every other pipeline batch.
* **RLS forgetting** (``SessionConfig.forgetting`` = λ < 1) — the carried
  Gram is scaled by λ per chunk before the chunk accumulates, so the readout
  tracks link/device drift instead of averaging over the whole session
  history; λ = 1.0 folds bit-identically to ``fit_ridge_streaming``.
* **Amortised solves** (``refresh_every``) — folding a chunk is one Gram
  accumulate (cheap, streaming); *solving* is an eigh + GCV grid (the
  expensive part).  The ``refresh`` flag of ``session_update``/
  ``session_step`` is static, so a server re-solves every ``refresh_every``
  ticks and pays the eigh 1/refresh_every as often, with exactly two
  compiled step variants (fold-only, fold+solve).
* **In-graph health masking** (``SessionConfig.guard``, DESIGN.md §12) —
  one non-finite tick would otherwise poison a slot *permanently*: NaN in
  the reservoir carry propagates to every later chunk, NaN in the Gram
  survives every later fold (λ·NaN + X = NaN).  The serving tick therefore
  ends with a per-row finite check over everything the row carries forward
  (carry, Gram/moments, readout, prediction); rows that fail are reset
  in-graph (the quarantine), flagged in ``SessionState.quarantined`` and
  counted in ``SessionState.poison`` — all traced ops, no host round-trip,
  still exactly two compiled step variants.  The GCV solve additionally
  falls back to the row's last-good readout when the fresh solve comes
  back non-finite (``pipeline/ridge.guard_readout``).  For healthy rows
  every guard is a ``select`` of the identical value, so the guarded step
  stays *bitwise* equal to the unguarded one on clean data.

The serving loop built on top lives in ``launch/serve_dfr.py``; the fault
models the guards are validated against live in ``repro.robustness``.  The
invariants (λ = 1.0 bitwise parity with the one-shot streaming fit,
chunk-split independence, quarantine isolation) are pinned by
tests/test_serving.py, tests/test_robustness.py and the hypothesis property
suite (tests/test_properties.py).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.nonlinear import NLModel, SiliconMR
from repro.core.reservoir import generate_states

from .ridge import _fold_chunk, _plan_fold, guard_readout, solve_gcv, with_bias


@dataclasses.dataclass(frozen=True)
class SessionConfig:
    """Static (hashable) configuration of an online-learning session batch.

    Mirrors the streaming knobs of ``ExperimentConfig`` (washout, λ grid,
    state method, kernel tiling) plus the online-only ones: ``chunk_k`` is
    the periods-per-tick granularity (static — one compiled step program),
    ``forgetting`` the RLS decay per chunk, ``refresh_every`` the re-solve
    cadence a server should drive (the session functions themselves take the
    decision as the static ``refresh`` flag; this field is the policy knob
    ``launch/serve_dfr.py`` and the benchmark read).
    """

    model: NLModel = dataclasses.field(default_factory=SiliconMR)
    n_nodes: int = 100
    n_channels: int = 1            # C output channels of the readout
    washout: int = 50
    ridge_l2: tuple[float, ...] = (1e-6,)
    chunk_k: int = 32
    forgetting: float = 1.0
    refresh_every: int = 1
    state_method: str = "fast"     # "fast" | "ref" | "kernel"
    use_kernel: bool = False       # Gram fold via the Pallas kernel
    block_s: int | None = None
    block_t: int = 512
    block_f: int = 128
    state_dtype: str | None = None  # sub-f32 emitted state chunks (DESIGN.md §9)
    guard: bool = True             # in-graph health masking (DESIGN.md §12)

    def __post_init__(self):
        if not isinstance(self.ridge_l2, tuple):
            object.__setattr__(self, "ridge_l2",
                               tuple(float(v) for v in self.ridge_l2))
        if not 0.0 < self.forgetting <= 1.0:
            raise ValueError(f"forgetting must be in (0, 1], got {self.forgetting}")
        if self.chunk_k < 1:
            raise ValueError(f"chunk_k must be >= 1, got {self.chunk_k}")
        if self.refresh_every < 1:
            raise ValueError(
                f"refresh_every must be >= 1, got {self.refresh_every}")

    @property
    def features(self) -> int:
        """Readout features F = N + 1 (bias folded)."""
        return self.n_nodes + 1

    @property
    def fold_plan(self):
        return _plan_fold(self.features, self.chunk_k,
                          use_kernel=self.use_kernel, block_t=self.block_t,
                          block_f=self.block_f, state_dtype=self.state_dtype)


class SessionState(NamedTuple):
    """Everything a batch of B live DFR streams needs to resume mid-flight.

    A NamedTuple, hence a pytree: jit/vmap/donate/shard-transparent.  The
    Gram block is carried feature-padded ([B, Fq, Fq], Fq = F rounded to the
    kernel's block_f tile) for the same reason ``fit_ridge_streaming``
    carries it padded — the accumulate-into kernel then never pads or
    slices G per chunk (DESIGN.md §8/§10).  The health leaves
    (``quarantined``/``poison``, DESIGN.md §12) are [B] bookkeeping only —
    no per-period axis ever enters the state, so the serving memory
    contracts are unchanged by the guards.
    """

    s: jnp.ndarray         # [B, N]  f32 — reservoir carry (resume point)
    g: jnp.ndarray         # [B, Fq, Fq] f32 — running (λ-decayed) Gram
    c: jnp.ndarray         # [B, Fq, C] f32 — running Xᵀy moment
    y2: jnp.ndarray        # [B] f32 — running (λ-decayed) ‖y‖²
    tcnt: jnp.ndarray      # [B] f32 — effective (λ-decayed) sample count
    w: jnp.ndarray         # [B, F, C] f32 — current readout (zeros until solved)
    lam_idx: jnp.ndarray   # [B] i32 — GCV-selected λ index of that readout
    step: jnp.ndarray      # [B] i32 — periods consumed (washout phase tracker)
    quarantined: jnp.ndarray  # [B] bool — row reset by the health guard THIS tick
    poison: jnp.ndarray    # [B] i32 — quarantine events since the slot was reset

    @property
    def batch(self) -> int:
        return self.s.shape[0]


@functools.partial(jax.jit, static_argnames=("cfg", "batch"))
def session_init(cfg: SessionConfig, batch: int) -> SessionState:
    """Fresh (dark-reservoir, empty-statistics) state for ``batch`` streams."""
    f, fq, c = cfg.features, cfg.fold_plan.fq, cfg.n_channels
    return SessionState(
        s=jnp.zeros((batch, cfg.n_nodes), jnp.float32),
        g=jnp.zeros((batch, fq, fq), jnp.float32),
        c=jnp.zeros((batch, fq, c), jnp.float32),
        y2=jnp.zeros((batch,), jnp.float32),
        tcnt=jnp.zeros((batch,), jnp.float32),
        w=jnp.zeros((batch, f, c), jnp.float32),
        lam_idx=jnp.zeros((batch,), jnp.int32),
        step=jnp.zeros((batch,), jnp.int32),
        quarantined=jnp.zeros((batch,), bool),
        poison=jnp.zeros((batch,), jnp.int32),
    )


def session_reset(state: SessionState, rows: jnp.ndarray) -> SessionState:
    """Zero the per-session leaves where ``rows`` [B] is True.

    The continuous-batching primitive: a finished stream's slot is handed to
    a newly arrived request by resetting that row in-graph — no host-side
    state surgery, no recompilation (``rows`` is a traced operand).
    """
    rows = jnp.asarray(rows, bool)

    def zero_rows(leaf):
        mask = rows.reshape((-1,) + (1,) * (leaf.ndim - 1))
        return jnp.where(mask, jnp.zeros_like(leaf), leaf)

    return SessionState(*(zero_rows(leaf) for leaf in state))


def _rows_finite(*arrays) -> jnp.ndarray:
    """[B] bool — True where every entry of every array's row is finite."""
    ok = None
    for a in arrays:
        fin = jnp.all(jnp.isfinite(a.reshape(a.shape[0], -1)), axis=1)
        ok = fin if ok is None else ok & fin
    return ok


def session_health(state: SessionState,
                   y_hat: jnp.ndarray | None = None) -> jnp.ndarray:
    """[B] bool — per-row finite check of everything a row carries forward.

    A row is healthy iff its reservoir carry, Gram/moment statistics, and
    readout are all finite (plus this tick's prediction when given).  One
    NaN/Inf anywhere marks the row: NaN in the carry re-poisons every later
    chunk, NaN in G survives every later fold, NaN in w corrupts every
    later prediction — so the check is over the *persisted* leaves, which
    is both necessary and sufficient to catch a poisoned slot at the tick
    it happens.
    """
    arrays = [state.s, state.g, state.c, state.y2, state.w]
    if y_hat is not None:
        arrays.append(y_hat)
    return _rows_finite(*arrays)


def _quarantine(state: SessionState, y_hat: jnp.ndarray):
    """In-graph slot quarantine (DESIGN.md §12).

    Rows whose post-fold state or prediction went non-finite are reset to
    the dark-reservoir/empty-statistics state *inside the compiled step*
    (``jnp.where`` per leaf — the same mechanism as ``session_reset``), so
    one poisoned stream never contaminates its slab neighbours or any later
    tick of its own slot.  The reset restarts the row's period counter, so
    washout re-applies and the slot re-converges from clean data.  The
    row's prediction is zeroed (never emit NaN to the host); the event is
    flagged in ``quarantined`` and counted in ``poison``.  Healthy rows
    pass through as selects of the identical value — bitwise a no-op.
    """
    bad = ~session_health(state, y_hat)

    def scrub(leaf):
        m = bad.reshape((-1,) + (1,) * (leaf.ndim - 1))
        return jnp.where(m, jnp.zeros_like(leaf), leaf)

    state = SessionState(
        s=scrub(state.s), g=scrub(state.g), c=scrub(state.c),
        y2=scrub(state.y2), tcnt=scrub(state.tcnt), w=scrub(state.w),
        lam_idx=scrub(state.lam_idx), step=scrub(state.step),
        quarantined=bad,
        poison=state.poison + bad.astype(jnp.int32))
    y_hat = jnp.where(bad[:, None, None], jnp.zeros_like(y_hat), y_hat)
    return y_hat, state


def _valid_mask(cfg: SessionConfig, step: jnp.ndarray,
                n_valid: jnp.ndarray | None) -> jnp.ndarray:
    """[B, chunk] f32 fit mask: past washout AND inside the valid prefix."""
    tidx = step[:, None] + jnp.arange(cfg.chunk_k, dtype=jnp.int32)[None, :]
    vfit = tidx >= cfg.washout
    if n_valid is not None:
        local = jnp.arange(cfg.chunk_k, dtype=jnp.int32)[None, :]
        vfit = vfit & (local < jnp.asarray(n_valid, jnp.int32)[:, None])
    return vfit.astype(jnp.float32)


def _canon_chunk_targets(cfg: SessionConfig, y_chunk: jnp.ndarray) -> jnp.ndarray:
    y = jnp.asarray(y_chunk, jnp.float32)
    if y.ndim == 2:
        y = y[..., None]
    if y.shape[-1] != cfg.n_channels:
        raise ValueError(
            f"targets carry {y.shape[-1]} channels, config says {cfg.n_channels}")
    return y


def _gen_chunk(cfg: SessionConfig, mask, j_chunk, s):
    return generate_states(cfg.model, j_chunk, mask, s0=s,
                           method=cfg.state_method, block_s=cfg.block_s,
                           return_final=True, state_dtype=cfg.state_dtype)


def _fold(cfg: SessionConfig, state: SessionState, states, y3, vfit,
          s_next) -> SessionState:
    """Fold one chunk of states into the running statistics (no solve)."""
    x = jnp.concatenate(
        [states, jnp.ones((*states.shape[:2], 1), states.dtype)], axis=-1)
    x = x * vfit.astype(x.dtype)[:, :, None]
    yv = y3 * vfit[:, :, None]
    lam = cfg.forgetting
    tcnt = state.tcnt + jnp.sum(vfit, axis=1) if lam == 1.0 else (
        state.tcnt * jnp.float32(lam) + jnp.sum(vfit, axis=1))
    g, cvec, y2 = _fold_chunk(cfg.fold_plan, state.g, state.c, state.y2,
                              x, yv, forgetting=lam)
    return state._replace(s=s_next, g=g, c=cvec, y2=y2, tcnt=tcnt,
                          step=state.step + jnp.int32(cfg.chunk_k))


def _solve(cfg: SessionConfig, state: SessionState) -> SessionState:
    """Re-solve the readout from the current statistics (the eigh+GCV pass).

    Under ``cfg.guard`` a row whose fresh solve comes back non-finite keeps
    its last-good readout (``pipeline/ridge.guard_readout``) — the running
    statistics are untouched, so the next refresh retries; rows whose
    *statistics* are poisoned are handled upstream by the quarantine.
    """
    f = cfg.features
    g = state.g[:, :f, :f]
    cvec = state.c[:, :f]
    lams = cfg.ridge_l2
    w, idx = jax.vmap(lambda gb, cb, y2b, nb: solve_gcv(
        gb, cb, y2b, nb, lams))(g, cvec, state.y2, state.tcnt)
    idx = idx.astype(jnp.int32)
    if cfg.guard:
        w, idx = guard_readout(w, idx, state.w, state.lam_idx)
    return state._replace(w=w, lam_idx=idx)


@functools.partial(jax.jit, static_argnames=("cfg", "refresh"))
def session_update(cfg: SessionConfig, mask: jnp.ndarray, state: SessionState,
                   j_chunk: jnp.ndarray, y_chunk: jnp.ndarray, *,
                   refresh: bool = False,
                   n_valid: jnp.ndarray | None = None) -> SessionState:
    """Advance B sessions by one chunk of observed (input, target) pairs.

    ``j_chunk`` [B, chunk_k], ``y_chunk`` [B, chunk_k] or [B, chunk_k, C].
    Runs the reservoir from each session's carry, masks washout rows (per
    session, via the ``step`` counter) and rows past ``n_valid`` (ragged
    stream tails), folds the chunk into the λ-decayed Gram statistics, and —
    when ``refresh`` (static) is True — re-solves the readout.  With
    ``forgetting=1.0`` and aligned chunks the folded statistics and solved
    readout are bit-identical to ``fit_ridge_streaming`` over the
    concatenated stream (tests/test_serving.py pins this).
    """
    y3 = _canon_chunk_targets(cfg, y_chunk)
    states, s_next = _gen_chunk(cfg, mask, j_chunk, state.s)
    vfit = _valid_mask(cfg, state.step, n_valid)
    state = _fold(cfg, state, states, y3, vfit, s_next)
    return _solve(cfg, state) if refresh else state


@functools.partial(jax.jit, static_argnames=("cfg",))
def session_predict(cfg: SessionConfig, mask: jnp.ndarray, state: SessionState,
                    j_chunk: jnp.ndarray):
    """Inference-only chunk: advance the reservoir, apply the current readout.

    Returns (y_hat [B, chunk_k, C], state') — the Gram statistics are left
    untouched (nothing is learned), but the reservoir carry and period
    counter advance so a later ``session_update`` resumes correctly.
    """
    states, s_next = _gen_chunk(cfg, mask, j_chunk, state.s)
    y_hat = jnp.einsum("btf,bfc->btc", with_bias(states), state.w,
                       preferred_element_type=jnp.float32)
    return y_hat, state._replace(s=s_next,
                                 step=state.step + jnp.int32(cfg.chunk_k))


def _session_step(cfg: SessionConfig, mask: jnp.ndarray, state: SessionState,
                  j_chunk: jnp.ndarray, y_chunk: jnp.ndarray, *,
                  refresh: bool = False,
                  n_valid: jnp.ndarray | None = None,
                  reset: jnp.ndarray | None = None):
    """The serving tick: predict-then-update with ONE reservoir pass.

    Optionally resets the rows flagged in ``reset`` [B] first (slots handed
    to newly arrived requests), then evaluates the chunk's states once and
    uses them for both the prediction (with the readout solved from earlier
    data — honest online inference) and the Gram fold.  ``refresh`` is
    static: a server calls the fold+solve variant every
    ``cfg.refresh_every``-th tick and the fold-only variant otherwise, so
    exactly two step programs are ever compiled — the health guard is part
    of both, not a third variant.

    Under ``cfg.guard`` (default) the tick ends with the in-graph
    quarantine: rows whose carry/Gram/readout/prediction went non-finite
    are reset in place, their prediction zeroed, ``quarantined`` flagged
    and ``poison`` incremented (DESIGN.md §12).  On clean data the guard
    is bitwise invisible.

    Returns (y_hat [B, chunk_k, C], new state).
    """
    if reset is not None:
        state = session_reset(state, reset)
    y3 = _canon_chunk_targets(cfg, y_chunk)
    states, s_next = _gen_chunk(cfg, mask, j_chunk, state.s)
    y_hat = jnp.einsum("btf,bfc->btc", with_bias(states), state.w,
                       preferred_element_type=jnp.float32)
    vfit = _valid_mask(cfg, state.step, n_valid)
    state = _fold(cfg, state, states, y3, vfit, s_next)
    if refresh:
        state = _solve(cfg, state)
    if cfg.guard:
        y_hat, state = _quarantine(state, y_hat)
    return y_hat, state


# The public step is jit-per-(cfg, refresh); ``_session_step`` stays
# importable for callers that re-jit with their own options — the serving
# loop (launch/serve_dfr.py) wraps it with donate_argnums so the session
# slab is updated in place across ticks.
session_step = functools.partial(jax.jit,
                                 static_argnames=("cfg", "refresh"))(_session_step)


@functools.partial(jax.jit, static_argnames=("cfg",))
def session_solve(cfg: SessionConfig, state: SessionState) -> SessionState:
    """Re-solve the readout now, regardless of cadence."""
    return _solve(cfg, state)
