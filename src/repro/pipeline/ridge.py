"""In-graph ridge readout: streaming Gram accumulation + GCV λ selection.

The host-side trainer (core/readout.py) solves the readout in float64 with a
numpy SVD — fine for one accelerator, useless for a jit/vmap sweep.  This
module is the pure-jax equivalent built on the *Gram* statistics

    G = XᵀX  [F, F],    c = Xᵀy  [F, C],    y2 = ‖y‖²

which are (a) streamable — the T×N state matrix never has to be resident,
(b) accumulable with the kernels/ridge_gram Pallas kernel, and (c) shardable:
``gram`` constrains the sample axis over the ("pod", "data") mesh axes via
parallel/sharding.maybe_shard, so under an active mesh each device reduces
its local shard of the state stream and GSPMD inserts the psum.

λ selection matches core/readout.py: generalised cross-validation

    GCV(λ) = T·‖y − ŷ_λ‖² / (T − dof(λ))²,   dof(λ) = Σ λᵢ/(λᵢ + λ′)

evaluated from the eigendecomposition G = QΛQᵀ (the λᵢ are the squared
singular values of X, so dof agrees with the host SVD path), with
λ′ = λ·tr(G)/F.  Everything — residual, dof, the winning weight vector — is
a function of (G, c, y2, T) only.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.parallel.sharding import maybe_shard


def with_bias(states: jnp.ndarray) -> jnp.ndarray:
    """Append the constant-1 bias feature: [..., T, N] -> [..., T, N + 1]."""
    ones = jnp.ones((*states.shape[:-1], 1), dtype=states.dtype)
    return jnp.concatenate([states, ones], axis=-1)


def gram(x: jnp.ndarray, y: jnp.ndarray, *, use_kernel: bool = False):
    """(G = XᵀX [F, F], c = Xᵀy [F, C]) in f32 from X [T, F], y [T, C].

    ``use_kernel=True`` accumulates with the Pallas streaming kernel
    (interpret mode off-TPU); the jnp path shards the sample axis.
    """
    if use_kernel:
        from repro.kernels.ridge_gram import ops as gram_ops

        return gram_ops.gram_accumulate(x, y)
    x32 = maybe_shard(x.astype(jnp.float32), ("pod", "data"))
    y32 = maybe_shard(y.astype(jnp.float32), ("pod", "data"))
    return x32.T @ x32, x32.T @ y32


def solve_gcv(
    g: jnp.ndarray,        # [F, F]
    c: jnp.ndarray,        # [F, C]
    y2: jnp.ndarray,       # scalar ‖y‖²
    n_samples: int,
    lambdas: tuple[float, ...],
):
    """Ridge solve (G + λ·tr(G)/F·I)w = c with GCV-selected λ.

    Returns (w [F, C], lam_idx) — ``lam_idx`` indexes the winning entry of
    the static ``lambdas`` tuple.  A single-element tuple skips nothing but
    costs one extra reduction; the eigendecomposition dominates either way.
    """
    f = g.shape[0]
    g32 = g.astype(jnp.float32)
    c32 = c.astype(jnp.float32)
    evals, q = jnp.linalg.eigh(g32)              # λᵢ ascending; tiny negatives
    evals = jnp.maximum(evals, 0.0)              # from f32 round-off -> clamp
    qc = q.T @ c32                               # [F, C]
    # Rank truncation: eigenvalues below f32 noise are not signal — keeping
    # them poisons both w (1/λᵢ blow-up) and the residual (the stray qc
    # energy in a null direction enters as qc²/λ′).  The 4·eps·λmax cutoff
    # is calibrated on NARMA10: at F·eps real signal directions get dropped
    # (NRMSE 0.80 vs the host float64 path's 0.60), at 0 the null-space
    # noise explodes some instances.
    tol = evals[-1] * jnp.asarray(4 * jnp.finfo(jnp.float32).eps, jnp.float32)
    valid = evals > tol
    qc = jnp.where(valid[:, None], qc, 0.0)
    qc2 = jnp.sum(qc * qc, axis=1)               # [F]
    lamp = jnp.asarray(lambdas, jnp.float32) * (jnp.sum(evals) / f)  # [L]

    def per_lambda(lam):
        inv = jnp.where(valid, 1.0 / (evals + lam), 0.0)   # [F]
        w = q @ (qc * inv[:, None])              # [F, C]
        dof = jnp.sum(evals * inv)
        # ‖y − ŷ‖² = ‖y‖² − Σᵢ qcᵢ²·(λᵢ + 2λ′)/(λᵢ + λ′)²  — evaluated in
        # the eigenbasis; the naive y2 − 2cᵀw + wᵀGw cancels catastrophically
        # in f32 once cond(G) approaches 1/eps.
        fit_energy = jnp.sum(qc2 * jnp.where(valid, (evals + 2.0 * lam) * inv * inv, 0.0))
        rss = jnp.maximum(y2 - fit_energy, 0.0)
        gcv = n_samples * rss / jnp.maximum(n_samples - dof, 1.0) ** 2
        return w, gcv

    ws, gcvs = jax.vmap(per_lambda)(lamp)        # [L, F, C], [L]
    idx = jnp.argmin(gcvs)
    return ws[idx], idx


def solve_gcv_svd(
    x: jnp.ndarray,        # [T, F]
    y: jnp.ndarray,        # [T, C]
    lambdas: tuple[float, ...],
):
    """GCV ridge from the SVD of X — the default in-graph solve.

    Works on X directly, so its conditioning is √cond(G): in f32 this
    matches the host float64 Gram path on every paper task, where the
    eigh-of-G route loses the small singular directions (cond squares).
    Use the Gram route (``solve_gcv``) only when X cannot be resident —
    streaming/kernel accumulation.
    """
    x32 = x.astype(jnp.float32)
    y32 = y.astype(jnp.float32)
    u, s, vt = jnp.linalg.svd(x32, full_matrices=False)   # [T,F], [F], [F,F]
    uty = u.T @ y32                                       # [F, C]
    uy2 = jnp.sum(uty * uty, axis=1)                      # [F]
    y2 = jnp.sum(y32 * y32)
    s2 = s * s
    n_samples = x.shape[0]
    lamp = jnp.asarray(lambdas, jnp.float32) * (jnp.sum(s2) / x.shape[1])

    def per_lambda(lam):
        shrink = s2 / (s2 + lam)                          # [F]
        w = vt.T @ (uty * (s / (s2 + lam))[:, None])      # [F, C]
        dof = jnp.sum(shrink)
        rss = jnp.maximum(y2 - jnp.sum((2.0 * shrink - shrink * shrink) * uy2), 0.0)
        gcv = n_samples * rss / jnp.maximum(n_samples - dof, 1.0) ** 2
        return w, gcv

    ws, gcvs = jax.vmap(per_lambda)(lamp)
    idx = jnp.argmin(gcvs)
    return ws[idx], idx


def fit_ridge(
    states: jnp.ndarray,   # [T, N]
    targets: jnp.ndarray,  # [T] or [T, C]
    *,
    lambdas: tuple[float, ...] = (1e-6,),
    use_kernel: bool = False,
):
    """One-shot readout fit: states -> (w [N + 1, C], lam_idx).

    Pure jax; jit- and vmap-safe (``lambdas`` must be a static tuple).
    Default path is the SVD-of-X solve; ``use_kernel=True`` switches to the
    streaming Gram accumulation (Pallas kernel) + eigh solve, trading the
    last decade of λ-conditioning for never materialising X on device.
    """
    y = targets[:, None] if targets.ndim == 1 else targets
    x = with_bias(states)
    if use_kernel:
        g, c = gram(x, y.astype(x.dtype), use_kernel=True)
        y2 = jnp.sum(y.astype(jnp.float32) ** 2)
        return solve_gcv(g, c, y2, x.shape[0], tuple(lambdas))
    return solve_gcv_svd(x, y, tuple(lambdas))


def fit_ridge_batched(
    states: jnp.ndarray,   # [B, T, N]
    targets: jnp.ndarray,  # [B, T] or [B, T, C]
    *,
    lambdas: tuple[float, ...] = (1e-6,),
    use_kernel: bool = False,
    block_t: int = 512,
):
    """Batched readout fit: B instance fits -> (w [B, N + 1, C], lam_idx [B]).

    The default (SVD) path is just ``vmap(fit_ridge)``.  ``use_kernel=True``
    runs ONE batch-gridded Pallas ``gram_accumulate_batched`` launch over the
    whole instance stack (the kernel has no jax batching rule, so a naive
    vmap/``lax.map`` would serialise B launches) and vmaps the eigh/GCV solve
    over the resulting [B, F, F] Gram stack.  ``block_t`` sizes the kernel's
    T tile (sublane-aligned internally).
    """
    y = targets[..., None] if targets.ndim == 2 else targets
    lams = tuple(lambdas)
    if use_kernel:
        from repro.kernels.ridge_gram import ops as gram_ops

        x = with_bias(states)
        g, c = gram_ops.gram_accumulate_batched(x, y.astype(x.dtype),
                                                block_t=block_t)
        y32 = y.astype(jnp.float32)
        y2 = jnp.sum(y32 * y32, axis=(1, 2))
        n_samples = x.shape[1]
        return jax.vmap(lambda gb, cb, y2b: solve_gcv(gb, cb, y2b, n_samples, lams))(
            g, c, y2)
    return jax.vmap(functools.partial(fit_ridge, lambdas=lams))(states, y)


def apply_readout(states: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """y = [states, 1] @ w; squeezes a single output channel."""
    y = with_bias(states) @ w
    return y[..., 0] if y.shape[-1] == 1 else y
