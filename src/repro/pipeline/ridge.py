"""In-graph ridge readout: streaming Gram accumulation + GCV λ selection.

The host-side trainer (core/readout.py) solves the readout in float64 with a
numpy SVD — fine for one accelerator, useless for a jit/vmap sweep.  This
module is the pure-jax equivalent built on the *Gram* statistics

    G = XᵀX  [F, F],    c = Xᵀy  [F, C],    y2 = ‖y‖²

which are (a) streamable — the T×N state matrix never has to be resident,
(b) accumulable with the kernels/ridge_gram Pallas kernel, and (c) shardable:
``gram`` constrains the sample axis over the ("pod", "data") mesh axes via
parallel/sharding.maybe_shard, so under an active mesh each device reduces
its local shard of the state stream and GSPMD inserts the psum.

``fit_ridge_streaming`` takes (a) to its conclusion (DESIGN.md §8): one
jitted ``lax.scan`` over K-chunks drives the reservoir kernel and the
accumulate-into Gram kernel back to back, so the full per-instance state
matrix never exists in HBM — peak state memory is O(B·chunk·N) instead of
O(B·T·N), with washout handled by row masking, the bias column folded into
the chunk update, and digitiser noise applied as its expected Tikhonov
diagonal (``state_noise_mode="diagonal"``).

λ selection matches core/readout.py: generalised cross-validation

    GCV(λ) = T·‖y − ŷ_λ‖² / (T − dof(λ))²,   dof(λ) = Σ λᵢ/(λᵢ + λ′)

evaluated from the eigendecomposition G = QΛQᵀ (the λᵢ are the squared
singular values of X, so dof agrees with the host SVD path), with
λ′ = λ·tr(G)/F.  Everything — residual, dof, the winning weight vector — is
a function of (G, c, y2, T) only.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.graph import ReservoirGraph, stage_link_drive, stage_states
from repro.core.reservoir import generate_channel_states, generate_states
from repro.parallel.sharding import maybe_shard


def with_bias(states: jnp.ndarray) -> jnp.ndarray:
    """Append the constant-1 bias feature: [..., T, N] -> [..., T, N + 1]."""
    ones = jnp.ones((*states.shape[:-1], 1), dtype=states.dtype)
    return jnp.concatenate([states, ones], axis=-1)


def gram(x: jnp.ndarray, y: jnp.ndarray, *, use_kernel: bool = False):
    """(G = XᵀX [F, F], c = Xᵀy [F, C]) in f32 from X [T, F], y [T, C].

    ``use_kernel=True`` accumulates with the Pallas streaming kernel
    (interpret mode off-TPU); the jnp path shards the sample axis.
    """
    if use_kernel:
        from repro.kernels.ridge_gram import ops as gram_ops

        return gram_ops.gram_accumulate(x, y)
    x32 = maybe_shard(x.astype(jnp.float32), ("pod", "data"))
    y32 = maybe_shard(y.astype(jnp.float32), ("pod", "data"))
    return x32.T @ x32, x32.T @ y32


def solve_gcv(
    g: jnp.ndarray,        # [F, F]
    c: jnp.ndarray,        # [F, C]
    y2: jnp.ndarray,       # scalar ‖y‖²
    n_samples: int,
    lambdas: tuple[float, ...],
):
    """Ridge solve (G + λ·tr(G)/F·I)w = c with GCV-selected λ.

    Returns (w [F, C], lam_idx) — ``lam_idx`` indexes the winning entry of
    the static ``lambdas`` tuple.  A single-element tuple skips nothing but
    costs one extra reduction; the eigendecomposition dominates either way.
    """
    f = g.shape[0]
    g32 = g.astype(jnp.float32)
    c32 = c.astype(jnp.float32)
    evals, q = jnp.linalg.eigh(g32)              # λᵢ ascending; tiny negatives
    evals = jnp.maximum(evals, 0.0)              # from f32 round-off -> clamp
    qc = q.T @ c32                               # [F, C]
    # Rank truncation: eigenvalues below f32 noise are not signal — keeping
    # them poisons both w (1/λᵢ blow-up) and the residual (the stray qc
    # energy in a null direction enters as qc²/λ′).  The 4·eps·λmax cutoff
    # is calibrated on NARMA10: at F·eps real signal directions get dropped
    # (NRMSE 0.80 vs the host float64 path's 0.60), at 0 the null-space
    # noise explodes some instances.
    tol = evals[-1] * jnp.asarray(4 * jnp.finfo(jnp.float32).eps, jnp.float32)
    valid = evals > tol
    qc = jnp.where(valid[:, None], qc, 0.0)
    qc2 = jnp.sum(qc * qc, axis=1)               # [F]
    lamp = jnp.asarray(lambdas, jnp.float32) * (jnp.sum(evals) / f)  # [L]

    def per_lambda(lam):
        inv = jnp.where(valid, 1.0 / (evals + lam), 0.0)   # [F]
        w = q @ (qc * inv[:, None])              # [F, C]
        dof = jnp.sum(evals * inv)
        # ‖y − ŷ‖² = ‖y‖² − Σᵢ qcᵢ²·(λᵢ + 2λ′)/(λᵢ + λ′)²  — evaluated in
        # the eigenbasis; the naive y2 − 2cᵀw + wᵀGw cancels catastrophically
        # in f32 once cond(G) approaches 1/eps.
        fit_energy = jnp.sum(qc2 * jnp.where(valid, (evals + 2.0 * lam) * inv * inv, 0.0))
        rss = jnp.maximum(y2 - fit_energy, 0.0)
        gcv = n_samples * rss / jnp.maximum(n_samples - dof, 1.0) ** 2
        return w, gcv

    ws, gcvs = jax.vmap(per_lambda)(lamp)        # [L, F, C], [L]
    idx = jnp.argmin(gcvs)
    return ws[idx], idx


def solve_gcv_svd(
    x: jnp.ndarray,        # [T, F]
    y: jnp.ndarray,        # [T, C]
    lambdas: tuple[float, ...],
):
    """GCV ridge from the SVD of X — the default in-graph solve.

    Works on X directly, so its conditioning is √cond(G): in f32 this
    matches the host float64 Gram path on every paper task, where the
    eigh-of-G route loses the small singular directions (cond squares).
    Use the Gram route (``solve_gcv``) only when X cannot be resident —
    streaming/kernel accumulation.
    """
    x32 = x.astype(jnp.float32)
    y32 = y.astype(jnp.float32)
    u, s, vt = jnp.linalg.svd(x32, full_matrices=False)   # [T,F], [F], [F,F]
    uty = u.T @ y32                                       # [F, C]
    uy2 = jnp.sum(uty * uty, axis=1)                      # [F]
    y2 = jnp.sum(y32 * y32)
    s2 = s * s
    n_samples = x.shape[0]
    lamp = jnp.asarray(lambdas, jnp.float32) * (jnp.sum(s2) / x.shape[1])

    def per_lambda(lam):
        shrink = s2 / (s2 + lam)                          # [F]
        w = vt.T @ (uty * (s / (s2 + lam))[:, None])      # [F, C]
        dof = jnp.sum(shrink)
        rss = jnp.maximum(y2 - jnp.sum((2.0 * shrink - shrink * shrink) * uy2), 0.0)
        gcv = n_samples * rss / jnp.maximum(n_samples - dof, 1.0) ** 2
        return w, gcv

    ws, gcvs = jax.vmap(per_lambda)(lamp)
    idx = jnp.argmin(gcvs)
    return ws[idx], idx


def fit_ridge(
    states: jnp.ndarray,   # [T, N]
    targets: jnp.ndarray,  # [T] or [T, C]
    *,
    lambdas: tuple[float, ...] = (1e-6,),
    use_kernel: bool = False,
):
    """One-shot readout fit: states -> (w [N + 1, C], lam_idx).

    Pure jax; jit- and vmap-safe (``lambdas`` must be a static tuple).
    Default path is the SVD-of-X solve; ``use_kernel=True`` switches to the
    streaming Gram accumulation (Pallas kernel) + eigh solve, trading the
    last decade of λ-conditioning for never materialising X on device.
    """
    y = targets[:, None] if targets.ndim == 1 else targets
    x = with_bias(states)
    if use_kernel:
        g, c = gram(x, y.astype(x.dtype), use_kernel=True)
        y2 = jnp.sum(y.astype(jnp.float32) ** 2)
        return solve_gcv(g, c, y2, x.shape[0], tuple(lambdas))
    return solve_gcv_svd(x, y, tuple(lambdas))


def fit_ridge_batched(
    states: jnp.ndarray,   # [B, T, N]
    targets: jnp.ndarray,  # [B, T] or [B, T, C]
    *,
    lambdas: tuple[float, ...] = (1e-6,),
    use_kernel: bool = False,
    block_t: int = 512,
):
    """Batched readout fit: B instance fits -> (w [B, N + 1, C], lam_idx [B]).

    The default (SVD) path is just ``vmap(fit_ridge)``.  ``use_kernel=True``
    runs ONE batch-gridded Pallas ``gram_accumulate_batched`` launch over the
    whole instance stack (the kernel has no jax batching rule, so a naive
    vmap/``lax.map`` would serialise B launches) and vmaps the eigh/GCV solve
    over the resulting [B, F, F] Gram stack.  ``block_t`` sizes the kernel's
    T tile (sublane-aligned internally).
    """
    y = targets[..., None] if targets.ndim == 2 else targets
    lams = tuple(lambdas)
    if use_kernel:
        from repro.kernels.ridge_gram import ops as gram_ops

        x = with_bias(states)
        g, c = gram_ops.gram_accumulate_batched(x, y.astype(x.dtype),
                                                block_t=block_t)
        y32 = y.astype(jnp.float32)
        y2 = jnp.sum(y32 * y32, axis=(1, 2))
        n_samples = x.shape[1]
        return jax.vmap(lambda gb, cb, y2b: solve_gcv(gb, cb, y2b, n_samples, lams))(
            g, c, y2)
    return jax.vmap(functools.partial(fit_ridge, lambdas=lams))(states, y)


def guard_readout(w_new: jnp.ndarray, idx_new: jnp.ndarray,
                  w_last: jnp.ndarray, idx_last: jnp.ndarray):
    """Last-good-readout fallback for batched GCV solves (DESIGN.md §12).

    ``w_new`` [B, F, C] / ``idx_new`` [B] is a freshly solved readout batch;
    rows where the solve produced any non-finite weight keep
    (``w_last``, ``idx_last``) instead — an eigh that failed to converge or
    a fold that slipped an Inf past the upstream guards must degrade ONE
    row to its previous readout, never emit NaN predictions or poison the
    slab.  Pure ``jnp.where`` row selects: for finite rows the fallback is
    bitwise invisible, so guarded solves stay bit-identical to unguarded
    ones on healthy data (tests/test_robustness.py pins both properties).
    """
    ok = jnp.all(jnp.isfinite(w_new.reshape(w_new.shape[0], -1)), axis=1)
    w = jnp.where(ok[:, None, None], w_new, w_last)
    idx = jnp.where(ok, idx_new.astype(idx_last.dtype), idx_last)
    return w, idx


def apply_readout(states: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """y = [states, 1] @ w; squeezes a single output channel."""
    y = with_bias(states) @ w
    return y[..., 0] if y.shape[-1] == 1 else y


def _chunk_layout(k_total: int, chunk_k: int):
    """Static chunking of a K-long stream: (n_chunks, padded K)."""
    if chunk_k < 1:
        raise ValueError(f"chunk_k must be >= 1, got {chunk_k}")
    n_chunks = -(-k_total // chunk_k)
    return n_chunks, n_chunks * chunk_k


def _chunk_axis(x: jnp.ndarray, n_chunks: int, chunk_k: int) -> jnp.ndarray:
    """[B, Kp, ...] -> [n_chunks, B, chunk_k, ...] (zero-padded upstream)."""
    b = x.shape[0]
    return jnp.moveaxis(x.reshape(b, n_chunks, chunk_k, *x.shape[2:]), 1, 0)


def _canon_stream(j, targets):
    """Canonicalise a (j, targets) stream pair to ([B, K], [B, K, C])."""
    j = jnp.asarray(j, jnp.float32)
    if j.ndim == 1:
        j = j[None, :]
    y = jnp.asarray(targets, jnp.float32)
    if y.ndim == 1:
        y = y[None, :]
    if y.ndim == 2:
        y = y[..., None]
    if y.shape[:2] != j.shape:
        raise ValueError(f"targets {y.shape} do not match inputs {j.shape}")
    return j, y


@dataclasses.dataclass(frozen=True)
class _FoldPlan:
    """Static layout of one chunk -> Gram fold (shared by the streaming fits
    and the online-learning sessions, pipeline/session.py).

    ``fq`` is the feature-padded Gram side (kernel path: F rounded up to the
    block_f tile so the carried [B, Fp, Fp] stacks never pad per chunk);
    ``chunk_pt``/``eff_bt`` are the sublane-aligned T tile of the Pallas Gram
    kernel (16-row tiles for sub-f32 chunks).  The jnp path folds with a
    plain einsum and needs no padding.
    """

    f: int            # features = N + 1 (bias folded)
    fq: int           # feature-padded Gram side
    chunk_k: int      # periods per chunk
    chunk_pt: int     # T-tile-padded chunk length (kernel path)
    eff_bt: int       # effective Gram T tile (kernel path)
    block_f: int
    use_kernel: bool
    interpret: bool


def _plan_fold(f: int, chunk_k: int, *, use_kernel: bool, block_t: int,
               block_f: int, state_dtype) -> _FoldPlan:
    """Resolve the static fold layout for (F, chunk) under the chosen path."""
    interpret = jax.default_backend() != "tpu"
    if use_kernel:
        from repro.kernels.ridge_gram.ops import effective_block_t

        eff_bt = effective_block_t(chunk_k, block_t)
        sdt = jnp.dtype(state_dtype if state_dtype is not None else jnp.float32)
        if sdt.itemsize < 4:
            # sub-f32 chunks need a 16-row sublane tile (bf16 min tile is
            # (16, 128)); round the T tile up and let padding absorb it.
            eff_bt = -(-eff_bt // 16) * 16
        chunk_pt = chunk_k + (-chunk_k % eff_bt)
        fq = f + (-f % block_f)
    else:
        eff_bt, chunk_pt, fq = 0, chunk_k, f
    return _FoldPlan(f=f, fq=fq, chunk_k=chunk_k, chunk_pt=chunk_pt,
                     eff_bt=eff_bt, block_f=block_f, use_kernel=use_kernel,
                     interpret=interpret)


def _fold_chunk(plan: _FoldPlan, g, cvec, y2, x, yv, *, forgetting: float = 1.0):
    """Fold one washout/padding-masked chunk into the running statistics.

    ``x`` [B, chunk, F] (bias column appended, invalid rows zeroed), ``yv``
    [B, chunk, C] (invalid rows zeroed) update G [B, Fq, Fq], c [B, Fq, C]
    and ‖y‖² [B] — via the accumulate-into Pallas kernel or a plain einsum,
    per ``plan``.  ``forgetting`` < 1 applies RLS-style exponential decay:
    the *carried* statistics are scaled by λ before this chunk accumulates,
    so after n chunks chunk i carries weight λ^(n-1-i).  At λ = 1.0 the
    scaling inserts no ops at trace time — the fold is bit-identical to the
    historical (un-decayed) path, which tests/benchmarks pin bitwise.
    """
    if forgetting != 1.0:
        lam = jnp.float32(forgetting)
        g = g * lam
        cvec = cvec * lam
        y2 = y2 * lam
    y2 = y2 + jnp.sum(yv * yv, axis=(1, 2))
    if plan.use_kernel:
        from repro.kernels.ridge_gram.ridge_gram import gram_tiled_batched_into

        xq = jnp.pad(x, ((0, 0), (0, plan.chunk_pt - plan.chunk_k),
                         (0, plan.fq - plan.f)))
        yq = jnp.pad(yv, ((0, 0), (0, plan.chunk_pt - plan.chunk_k), (0, 0)))
        g, cvec = gram_tiled_batched_into(g, cvec, xq, yq, block_t=plan.eff_bt,
                                          block_f=plan.block_f,
                                          interpret=plan.interpret)
    else:
        g = g + jnp.einsum("btf,btg->bfg", x, x,
                           preferred_element_type=jnp.float32)
        cvec = cvec + jnp.einsum("btf,btc->bfc", x, yv,
                                 preferred_element_type=jnp.float32)
    return g, cvec, y2


def _fit_streaming_core(
    states_fn,             # (j_chunk [B, chunk, ...], carry f32) -> (states, carry')
    n: int,                # feature nodes per instance (graph width)
    j: jnp.ndarray,        # [B, K] (or [B, K, ...]) canonicalised stream
    y: jnp.ndarray,        # [B, K, C] canonicalised targets
    *,
    washout: int,
    chunk_k: int,
    lambdas: tuple[float, ...],
    use_kernel: bool,
    block_t: int,
    block_f: int,
    noise_rel: float,
    state_dtype,
    s0,                    # carry pytree matching states_fn (None = dark)
    forgetting: float = 1.0,
    carry_layout: tuple[tuple[int, int], ...] | None = None,
):
    """The shared chunk-scan of both streaming fits (DESIGN.md §8/§9/§10).

    ``states_fn`` is the only degree of freedom between the single-mask fit
    (``fit_ridge_streaming``: one mask broadcast over B task instances) and
    the WDM fit (``fit_ridge_streaming_wdm``: per-channel masks, B = R
    wavelength channels) — everything downstream of state generation (washout
    row-masking, bias fold, Gram accumulation, noise-as-Tikhonov, the GCV
    solve) is identical, so it lives here once.

    ``state_dtype`` (e.g. bf16) applies to the emitted state *chunks* only:
    the reservoir carry between chunks stays f32 (resume is unaffected), the
    Gram/moment accumulators stay f32 (MXU partials via
    ``preferred_element_type``), and the target stream stays f32 — only the
    [B, chunk, F] block that round-trips through HBM per chunk narrows, which
    is where the traffic is.

    ``forgetting`` < 1 turns the fit into RLS-style exponential forgetting
    (DESIGN.md §10): the carried (G, c, ‖y‖²) are scaled by λ per chunk
    before the chunk accumulates, and the GCV solve sees the *effective*
    (decayed) sample count instead of T_fit.  λ = 1.0 adds no ops — the
    historical path, pinned bitwise by tests/test_serving.py.

    ``carry_layout`` generalises the reservoir carry from one [B, N] array to
    a pytree (DESIGN.md §13): a tuple of per-stage (L, N_s) entries declares
    the carry a matching tuple of [B, L, N_s] leaves AND how a feature row
    [B, n] slices back into per-stage carries (stage s occupies columns
    [Σ_{<s} L·N, …), loop-major within the stage) — which is what the
    mid-stream s_end extraction needs when the last real period is not at a
    chunk end.  ``None`` keeps the legacy single-array carry with identical
    traced ops, so existing fits stay bitwise.
    """
    b, k_total = j.shape[0], j.shape[1]
    f = n + 1
    c_cols = y.shape[-1]
    if k_total <= washout:
        raise ValueError(f"stream length {k_total} <= washout {washout}")
    if not 0.0 < forgetting <= 1.0:
        raise ValueError(f"forgetting must be in (0, 1], got {forgetting}")
    if noise_rel and forgetting != 1.0:
        raise ValueError(
            "noise_rel as an expected Tikhonov diagonal assumes un-decayed "
            "Gram statistics; forgetting < 1 is not supported with it")
    t_fit = k_total - washout
    n_chunks, k_padded = _chunk_layout(k_total, chunk_k)
    plan = _plan_fold(f, chunk_k, use_kernel=use_kernel, block_t=block_t,
                      block_f=block_f, state_dtype=state_dtype)
    fq = plan.fq

    jp = jnp.pad(j, ((0, 0), (0, k_padded - k_total))
                 + ((0, 0),) * (j.ndim - 2))
    yp = jnp.pad(y, ((0, 0), (0, k_padded - k_total), (0, 0)))
    if carry_layout is None:
        if s0 is None:
            s0 = jnp.zeros((b, n), jnp.float32)
        res0 = jnp.asarray(s0, jnp.float32)

        def carry_from_row(row):   # [B, n] f32 feature row IS the carry
            return row
    else:
        if s0 is None:
            s0 = tuple(jnp.zeros((b, lp, w), jnp.float32)
                       for lp, w in carry_layout)
        res0 = jax.tree.map(lambda x: jnp.asarray(x, jnp.float32), tuple(s0))
        offs, off = [], 0
        for lp, w in carry_layout:
            offs.append(off)
            off += lp * w
        if off != n:
            raise ValueError(f"carry_layout covers {off} features, expected {n}")

        def carry_from_row(row):   # [B, n] f32 -> tuple of [B, L, N_s]
            return tuple(
                jax.lax.dynamic_slice_in_dim(row, o, lp * w, axis=1)
                .reshape(b, lp, w)
                for o, (lp, w) in zip(offs, carry_layout))

    carry0 = (
        res0,                                  # running reservoir carry
        jnp.zeros((b, fq, fq), jnp.float32),   # G (feature-padded on kernel path)
        jnp.zeros((b, fq, c_cols), jnp.float32),
        jnp.zeros((b,), jnp.float32),          # ‖y‖² over the fit window
        jnp.zeros((b,), jnp.float32),          # Σ s   (noise σ estimate)
        jnp.zeros((b,), jnp.float32),          # Σ s²
        jnp.zeros((b,), jnp.float32),          # effective (decayed) samples
        res0,                                  # carry after period K - 1
    )
    xs = (_chunk_axis(jp, n_chunks, chunk_k),
          _chunk_axis(yp, n_chunks, chunk_k),
          jnp.arange(n_chunks, dtype=jnp.int32) * chunk_k)

    def body(carry, chunk):
        s, g, cvec, y2, ssum, ssq, tcnt, s_end = carry
        j_c, y_c, k_start = chunk
        states, s_next = states_fn(j_c, s)
        tidx = k_start + jnp.arange(chunk_k, dtype=jnp.int32)
        vfit = ((tidx >= washout) & (tidx < k_total)).astype(jnp.float32)

        x = jnp.concatenate(
            [states, jnp.ones((b, chunk_k, 1), states.dtype)], axis=-1)
        # washout/padding rows -> zero; keep the mask in the chunk dtype so a
        # bf16 chunk is not silently promoted back to f32 by the multiply
        x = x * vfit.astype(x.dtype)[None, :, None]
        yv = y_c * vfit[None, :, None]
        if noise_rel:
            sv = states.astype(jnp.float32) * vfit[None, :, None]
            ssum = ssum + jnp.sum(sv, axis=(1, 2))
            ssq = ssq + jnp.sum(sv * sv, axis=(1, 2))
        if forgetting != 1.0:
            tcnt = tcnt * jnp.float32(forgetting) + jnp.sum(vfit)

        g, cvec, y2 = _fold_chunk(plan, g, cvec, y2, x, yv,
                                  forgetting=forgetting)

        # State after period K - 1 (this chunk's padded tail, if any, keeps
        # evolving on zero input — the carry must come from the last *real*
        # period, not the end of the chunk).  When the last real period sits
        # exactly at the chunk end, prefer the f32 VMEM carry over the state
        # tensor: with bf16 chunks the tensor is rounded, the carry is not.
        in_chunk = (k_start <= k_total - 1) & (k_total - 1 < k_start + chunk_k)
        at_chunk_end = k_total - 1 == k_start + chunk_k - 1
        last_local = jnp.clip(k_total - 1 - k_start, 0, chunk_k - 1)
        row = jax.lax.dynamic_index_in_dim(states, last_local, axis=1,
                                           keepdims=False).astype(jnp.float32)
        s_k = carry_from_row(row)
        s_k = jax.tree.map(lambda nxt, sk: jnp.where(at_chunk_end, nxt, sk),
                           s_next, s_k)
        s_end = jax.tree.map(lambda sk, se: jnp.where(in_chunk, sk, se),
                             s_k, s_end)
        return (s_next, g, cvec, y2, ssum, ssq, tcnt, s_end), None

    (s_last, g, cvec, y2, ssum, ssq, tcnt, s_end), _ = jax.lax.scan(
        body, carry0, xs)
    del s_last

    if noise_rel:
        cnt = jnp.asarray(t_fit * n, jnp.float32)
        var = jnp.maximum(ssq / cnt - (ssum / cnt) ** 2, 0.0)
        sig2_t = (noise_rel ** 2) * var * t_fit       # σ²·T_fit per instance
        dn = jnp.arange(n)
        g = g.at[:, dn, dn].add(sig2_t[:, None])
    g = g[:, :f, :f]
    cvec = cvec[:, :f]

    lams = tuple(lambdas)
    if forgetting != 1.0:
        # decayed statistics -> decayed effective sample count in the GCV
        # score (Σ_i λ^(n-1-i)·valid_i, the standard RLS memory length)
        w, idx = jax.vmap(lambda gb, cb, y2b, nb: solve_gcv(
            gb, cb, y2b, nb, lams))(g, cvec, y2, tcnt)
    else:
        w, idx = jax.vmap(
            lambda gb, cb, y2b: solve_gcv(gb, cb, y2b, t_fit, lams))(g, cvec, y2)
    return w, idx, s_end


@functools.partial(jax.jit, static_argnames=(
    "model", "washout", "chunk_k", "lambdas", "state_method", "block_s",
    "use_kernel", "block_t", "block_f", "noise_rel", "state_dtype",
    "forgetting"))
def fit_ridge_streaming(
    model,
    mask: jnp.ndarray,     # [N]
    j: jnp.ndarray,        # [B, K] sample-and-held input stream
    targets: jnp.ndarray,  # [B, K] or [B, K, C]
    *,
    washout: int,
    chunk_k: int,
    lambdas: tuple[float, ...] = (1e-6,),
    state_method: str = "kernel",
    block_s: int | None = None,
    use_kernel: bool = True,
    block_t: int = 512,
    block_f: int = 128,
    noise_rel: float = 0.0,
    state_dtype=None,
    s0: jnp.ndarray | None = None,
    forgetting: float = 1.0,
    dev_params=None,
):
    """Streaming fused reservoir -> readout fit: states never fully resident.

    ONE ``lax.scan`` over ``ceil(K / chunk_k)`` chunks; each iteration runs
    the reservoir for ``chunk_k`` periods (resuming bit-exactly from the
    carried final state), masks washout/padding rows to zero, appends the
    bias column, and folds the chunk into running per-instance Gram stacks
    (G [B, F, F], c [B, F, C], F = N + 1) — via the accumulate-into Pallas
    kernel (``use_kernel=True``, carried in feature-padded [B, Fp, Fp] form
    so no per-chunk pad/slice copies of G) or a plain einsum.  Peak live
    state memory is O(B·chunk_k·N); the [B, K, N] state tensor of the
    materialized path never exists.  ``state_dtype`` (e.g. ``"bfloat16"``)
    narrows the emitted state chunks, halving their HBM round-trip; carry
    and accumulators stay f32 (DESIGN.md §9 bounds the accuracy cost).

    The solve is necessarily the Gram/eigh route (``solve_gcv``): running
    (G, c, ‖y‖²) statistics are all a streaming fit ever holds, and the
    better-conditioned SVD-of-X solve needs X resident.  Parity targets are
    therefore the materialized *Gram* fit (``fit_ridge_batched(use_kernel=
    True)``); vs the SVD default the last decade of λ-conditioning can
    differ (see ``solve_gcv_svd``).

    ``noise_rel`` > 0 applies the digitiser noise of the materialized path
    in expectation, without a second pass over the stream: for i.i.d. state
    noise ε with σ = noise_rel·std(states over the fit window),

        E[(X+ε)ᵀ(X+ε)] = XᵀX + σ²·T_fit·I,   E[(X+ε)ᵀy] = Xᵀy,

    so the fit adds σ²·T_fit to the N state-feature diagonal entries of G
    (not the bias), with σ estimated from in-scan sum/sum-of-squares
    accumulators over the same fit window.  This is
    ``ExperimentConfig.state_noise_mode="diagonal"``; the sampled-noise path
    stays available on the unfused route.

    ``forgetting`` < 1 applies RLS-style exponential forgetting (DESIGN.md
    §10): chunk i of n carries weight λ^(n-1-i) in the Gram statistics, so
    the fit tracks a drifting stream (online channel equalisation, device
    operating-point drift) instead of averaging over its whole history.
    λ = 1.0 is bit-identical to the un-decayed fit.

    Returns ``(w [B, F, C], lam_idx [B], s_end [B, N])`` where ``s_end`` is
    the reservoir state after period K - 1 (the train -> test carry), exact
    even when K is not a multiple of ``chunk_k`` — except that with a
    sub-f32 ``state_dtype`` AND a ragged tail (K % chunk_k != 0) the carry
    is read from the rounded state chunk (the f32 VMEM carry describes the
    chunk *end*, which is past period K - 1); chunk-aligned K keeps it
    f32-exact (DESIGN.md §9).

    ``dev_params`` (a traced device operating-point pytree, e.g.
    ``devices.cmt.CMTSweepParams`` with [B] leaves) threads per-lane swept
    device parameters into state generation — an *operand*, so a design-
    space sweep over it reuses this compiled program (DESIGN.md §14).
    jnp state methods only (``generate_states`` rejects kernel+params).
    """
    j, y = _canon_stream(j, targets)

    def states_fn(j_c, s):
        return generate_states(model, j_c, mask, s0=s, method=state_method,
                               block_s=block_s, return_final=True,
                               state_dtype=state_dtype,
                               dev_params=dev_params)

    return _fit_streaming_core(
        states_fn, int(mask.shape[-1]), j, y, washout=washout, chunk_k=chunk_k,
        lambdas=lambdas, use_kernel=use_kernel, block_t=block_t,
        block_f=block_f, noise_rel=noise_rel, state_dtype=state_dtype, s0=s0,
        forgetting=forgetting)


@functools.partial(jax.jit, static_argnames=(
    "model", "washout", "chunk_k", "lambdas", "state_method", "block_s",
    "use_kernel", "block_t", "block_f", "noise_rel", "state_dtype",
    "forgetting"))
def fit_ridge_streaming_wdm(
    model,
    masks: jnp.ndarray,    # [R, N] — one MLS mask per wavelength channel
    j: jnp.ndarray,        # [R, K] — one sample-and-held stream per channel
    targets: jnp.ndarray,  # [R, K] or [R, K, C]
    *,
    washout: int,
    chunk_k: int,
    lambdas: tuple[float, ...] = (1e-6,),
    state_method: str = "kernel",
    block_s: int | None = None,
    use_kernel: bool = True,
    block_t: int = 512,
    block_f: int = 128,
    noise_rel: float = 0.0,
    state_dtype=None,
    s0: jnp.ndarray | None = None,
    forgetting: float = 1.0,
):
    """Streaming readout fit for a WDM ensemble: per-channel masks, one scan.

    The WDM workload (paper Section VI; DESIGN.md §9) is R microring
    wavelength channels sharing one delay loop — software-side, R reservoirs
    with *different* masks over *different* input streams.  This is the
    ``fit_ridge_streaming`` chunk scan with the per-lane-mask reservoir
    kernel in the driver's seat: each chunk runs all R channels as ONE
    Pallas launch (``generate_channel_states(method="kernel")`` — channels
    are batch lanes with their own [N] mask tiles in VMEM) and folds into
    per-channel Gram stacks G [R, F, F] / c [R, F, C] via the accumulate-into
    kernel, followed by one vmapped GCV solve.  Peak live state memory is
    O(R·chunk_k·N); the [R, K, N] channel-state tensor of the materialized
    ``generate_channel_states`` path never exists — which is what lets long
    WDM streams (K ≫ chunk) scale past HBM.

    All other knob semantics (``noise_rel`` as expected Tikhonov diagonal,
    ``state_dtype`` bf16 chunks, kernel/einsum Gram accumulation,
    ``forgetting`` as per-chunk RLS decay) match ``fit_ridge_streaming``.
    Returns ``(w [R, F, C], lam_idx [R], s_end [R, N])`` with ``s_end`` the
    per-channel train -> test carry (same exactness caveat for sub-f32
    chunks with a ragged tail).
    """
    j, y = _canon_stream(j, targets)
    if masks.ndim != 2 or masks.shape[0] != j.shape[0]:
        raise ValueError(f"channels mismatch: j {j.shape} vs masks {masks.shape}")

    def states_fn(j_c, s):
        return generate_channel_states(model, j_c, masks, s0=s,
                                       method=state_method, block_s=block_s,
                                       return_final=True,
                                       state_dtype=state_dtype)

    return _fit_streaming_core(
        states_fn, int(masks.shape[-1]), j, y, washout=washout,
        chunk_k=chunk_k, lambdas=lambdas, use_kernel=use_kernel,
        block_t=block_t, block_f=block_f, noise_rel=noise_rel,
        state_dtype=state_dtype, s0=s0, forgetting=forgetting)


def composed_chunk_states_fn(graph: ReservoirGraph, masks, *,
                             state_method: str = "kernel",
                             block_s: int | None = None,
                             state_dtype=None):
    """The per-chunk transformer of a reservoir graph (DESIGN.md §13).

    Returns ``states_fn(j_chunk [B, chunk], carries) -> (features
    [B, chunk, graph.width], carries')`` with ``carries`` a tuple of
    per-stage [B, L, N_s] f32 arrays (``graph.carry_layout``): each stage
    runs over the *chunk* (loops folded into batch lanes — one Pallas launch
    per stage), its linked drive feeds the next stage inside the SAME scan
    step, and only chunk-sized feature blocks ever exist — no stage
    materialises a full-K [B, K, L·N] tensor.  Shared between the composed
    streaming fit below and the composed streaming eval
    (pipeline/experiment.py), so train and test trace identical stage ops.
    """
    masks = tuple(masks)
    if len(masks) != graph.depth:
        raise ValueError(f"expected {graph.depth} stage mask stacks, "
                         f"got {len(masks)}")
    depth = graph.depth

    def states_fn(j_c, carries):
        feats, new_c = [], []
        drive = j_c
        for i, stage in enumerate(graph.stages):
            f_i, c_i = stage_states(stage, drive, masks[i], carries[i],
                                    method=state_method, block_s=block_s,
                                    state_dtype=state_dtype)
            feats.append(f_i)
            new_c.append(c_i)
            if i + 1 < depth:
                drive = stage_link_drive(stage, f_i)
        states = feats[0] if depth == 1 else jnp.concatenate(feats, axis=-1)
        return states, tuple(new_c)

    return states_fn


@functools.partial(jax.jit, static_argnames=(
    "graph", "washout", "chunk_k", "lambdas", "state_method", "block_s",
    "use_kernel", "block_t", "block_f", "noise_rel", "state_dtype",
    "forgetting"))
def fit_ridge_streaming_composed(
    graph: ReservoirGraph,
    masks,                 # tuple of per-stage [L, N] / [B, L, N] mask stacks
    j: jnp.ndarray,        # [B, K] stage-0 sample-and-held input stream
    targets: jnp.ndarray,  # [B, K] or [B, K, C]
    *,
    washout: int,
    chunk_k: int,
    lambdas: tuple[float, ...] = (1e-6,),
    state_method: str = "kernel",
    block_s: int | None = None,
    use_kernel: bool = True,
    block_t: int = 512,
    block_f: int = 128,
    noise_rel: float = 0.0,
    state_dtype=None,
    s0=None,               # tuple of per-stage [B, L, N] carries
    forgetting: float = 1.0,
):
    """Streaming readout fit over a composed reservoir graph (DESIGN.md §13).

    The ``fit_ridge_streaming`` chunk scan with the whole stage *chain* in
    the driver's seat: each scan step runs every stage over the chunk
    (stage k + 1 driven by stage k's linked output, computed in-step), folds
    the concatenated [B, chunk, graph.width] feature block into per-instance
    Gram stacks, and carries the per-stage reservoir states as a tuple —
    threaded independently, so the chain resumes bit-exactly at any chunk
    split.  Peak live state memory is O(B·chunk·width); no stage ever holds
    a full-K block (``repro.analysis`` NoStateTensor pins this per stage).

    A depth-1/loops-1 graph is the legacy fit, bit for bit: the stage calls
    ``generate_states`` literally and the single-element concat is skipped,
    so ``w``/``lam_idx`` match ``fit_ridge_streaming`` bitwise (the carry
    just gains the [B, 1, N] stage axis).  Knob semantics (``noise_rel``,
    ``state_dtype``, ``forgetting``, kernel/einsum Gram) are inherited
    unchanged from ``fit_ridge_streaming``.

    Returns ``(w [B, F, C], lam_idx [B], s_end)`` with F = graph.width + 1
    and ``s_end`` the per-stage carry tuple after period K - 1 — feed it to
    the composed eval (or back in as ``s0``) as the train -> test carry.
    """
    j, y = _canon_stream(j, targets)
    states_fn = composed_chunk_states_fn(graph, masks,
                                         state_method=state_method,
                                         block_s=block_s,
                                         state_dtype=state_dtype)
    return _fit_streaming_core(
        states_fn, graph.width, j, y, washout=washout, chunk_k=chunk_k,
        lambdas=lambdas, use_kernel=use_kernel, block_t=block_t,
        block_f=block_f, noise_rel=noise_rel, state_dtype=state_dtype,
        s0=None if s0 is None else tuple(s0), forgetting=forgetting,
        carry_layout=graph.carry_layout)


@functools.partial(jax.jit, static_argnames=(
    "model", "washout", "chunk_k", "lambdas", "state_method", "block_s",
    "use_kernel", "block_t", "block_f", "noise_rel", "state_dtype",
    "forgetting"))
def fit_ridge_streaming_shared(
    model,
    masks: jnp.ndarray,    # [R, N] — one MLS mask per wavelength channel
    j: jnp.ndarray,        # [R, K] — one sample-and-held stream per channel
    targets: jnp.ndarray,  # [K] or [K, C] — ONE target for the ensemble
    *,
    washout: int,
    chunk_k: int,
    lambdas: tuple[float, ...] = (1e-6,),
    state_method: str = "kernel",
    block_s: int | None = None,
    use_kernel: bool = True,
    block_t: int = 512,
    block_f: int = 128,
    noise_rel: float = 0.0,
    state_dtype=None,
    s0: jnp.ndarray | None = None,  # [R, N]
    forgetting: float = 1.0,
):
    """Shared-readout WDM fit: ONE readout over all R channels' features.

    ``fit_ridge_streaming_wdm`` trains R independent readouts — R separate
    [F, F] Grams, each channel predicting its own target.  Here the R
    channels are treated as ONE wide reservoir observing one task: per
    period the readout sees the concatenation of every channel's N node
    states (feature r·N + i = channel r, node i), so the single Gram is
    [R·N + 1, R·N + 1] and its off-diagonal blocks carry the *cross-channel*
    state correlations the per-channel fits discard.  This is the
    series/parallel-coupled-MR readout of arXiv:2308.15902 mapped onto the
    WDM hardware: same photonic ensemble, richer (and R× larger) linear
    readout, one target stream.

    Streaming shape: the channel axis rides the chunk scan as a trailing
    input dim (stream [1, K, R]), each chunk runs all R channels as ONE
    per-lane-mask kernel launch, and the features fold into a single Gram —
    peak state memory O(R·chunk·N), the [K, R·N] feature matrix never
    resident.  Carry layout is one ((R, N),) entry, so mid-chunk s_end
    extraction reshapes a feature row back to [R, N] per channel.

    Returns ``(w [F, C], lam_idx, s_end [R, N])`` — one weight vector and
    one λ for the whole ensemble, per-channel train -> test carry.
    """
    masks = jnp.asarray(masks)
    if masks.ndim != 2:
        raise ValueError(f"masks must be [R, N], got {masks.shape}")
    r, n_nodes = masks.shape
    j = jnp.asarray(j, jnp.float32)
    if j.ndim != 2 or j.shape[0] != r:
        raise ValueError(f"channels mismatch: j {j.shape} vs masks {masks.shape}")
    y = jnp.asarray(targets, jnp.float32)
    if y.ndim == 1:
        y = y[:, None]
    if y.ndim != 2 or y.shape[0] != j.shape[1]:
        raise ValueError(f"targets {y.shape} do not match stream length "
                         f"{j.shape[1]}")
    j_core = jnp.moveaxis(j, 0, 1)[None]       # [1, K, R]
    y_core = y[None]                           # [1, K, C]

    def states_fn(j_c, carries):               # j_c [1, chunk, R]
        s = carries[0]                         # [1, R, N]
        states, s_next = generate_channel_states(
            model, j_c[0].T, masks, s0=s[0], method=state_method,
            block_s=block_s, return_final=True, state_dtype=state_dtype)
        feats = jnp.moveaxis(states, 0, 1).reshape(
            j_c.shape[1], r * n_nodes)[None]   # [1, chunk, R·N]
        return feats, (s_next[None],)

    w, idx, s_end = _fit_streaming_core(
        states_fn, r * n_nodes, j_core, y_core, washout=washout,
        chunk_k=chunk_k, lambdas=lambdas, use_kernel=use_kernel,
        block_t=block_t, block_f=block_f, noise_rel=noise_rel,
        state_dtype=state_dtype,
        s0=None if s0 is None else (jnp.asarray(s0, jnp.float32)[None],),
        forgetting=forgetting, carry_layout=((r, n_nodes),))
    return w[0], idx[0], s_end[0][0]
