"""Jaxpr introspection: intermediate-tensor accounting for memory guards.

The streaming fused path (DESIGN.md §8) exists to keep the full [B, T, N]
state tensor out of HBM; these helpers make that property *checkable* by
walking a traced jaxpr (recursively through scan/pjit/cond sub-jaxprs) and
collecting the abstract values every equation produces.  Used by the
tests/test_streaming.py jaxpr guard (no full-T state tensor, exactly one
chunk scan) and by benchmarks/streaming_fusion.py (peak live state bytes,
materialized vs streamed).

Equations inside a ``pallas_call`` body are skipped: a kernel's jaxpr
describes per-*block* VMEM compute, not HBM-resident arrays, and in
interpret mode it contains emulation loops that are not real scans.
"""

from __future__ import annotations

import jax

try:  # jax >= 0.4.14
    from jax.extend import core as jax_core
except ImportError:  # pragma: no cover - older jax
    from jax import core as jax_core


def _sub_jaxprs(params):
    """Yield every Jaxpr/ClosedJaxpr nested in an eqn's params."""
    for value in params.values():
        leaves = value if isinstance(value, (tuple, list)) else (value,)
        for leaf in leaves:
            if isinstance(leaf, jax_core.ClosedJaxpr):
                yield leaf.jaxpr
            elif isinstance(leaf, jax_core.Jaxpr):
                yield leaf


def walk_eqns(jaxpr, *, skip_pallas: bool = True):
    """Depth-first iterator over all equations, entering sub-jaxprs."""
    for eqn in jaxpr.eqns:
        yield eqn
        if skip_pallas and eqn.primitive.name == "pallas_call":
            continue
        for sub in _sub_jaxprs(eqn.params):
            yield from walk_eqns(sub, skip_pallas=skip_pallas)


def trace_jaxpr(fn, *args, **kwargs):
    """ClosedJaxpr of ``fn(*args, **kwargs)`` (no execution)."""
    return jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args)


def intermediate_shapes(closed_jaxpr) -> list[tuple[tuple[int, ...], int]]:
    """All (shape, nbytes) pairs produced by equations in the program.

    Covers every intermediate array the traced computation names —
    sub-jaxpr (scan body, pjit) outputs included, pallas kernel-internal
    VMEM blocks excluded.
    """
    out = []
    for eqn in walk_eqns(closed_jaxpr.jaxpr):
        for var in eqn.outvars:
            aval = var.aval
            if hasattr(aval, "shape") and hasattr(aval, "dtype"):
                nbytes = int(aval.size) * aval.dtype.itemsize
                out.append((tuple(aval.shape), nbytes))
    return out


def max_intermediate_bytes(closed_jaxpr) -> int:
    """Largest single intermediate array in the program, in bytes."""
    return max((b for _, b in intermediate_shapes(closed_jaxpr)), default=0)


def state_tensor_bytes(closed_jaxpr, t_len: int, min_elems: int) -> int:
    """Largest "state-like" intermediate: carries the stream axis (a dim ==
    ``t_len``) at state-tensor scale (>= ``min_elems`` elements).

    The element floor is what separates a state tensor from the O(B·T)
    input/target streams that legitimately carry the T axis: pass
    ``B·t_len·N`` (full-stream check; 0 == the streaming property holds) or
    ``B·chunk·N`` with ``t_len=chunk`` (the streamed path's peak live state
    block — lane/feature padding of the kernel layouts is included in the
    measured tensor, so compare against a padded budget).
    """
    best = 0
    for shape, nbytes in intermediate_shapes(closed_jaxpr):
        elems = 1
        for d in shape:
            elems *= d
        if t_len in shape and elems >= min_elems:
            best = max(best, nbytes)
    return best


def count_scans(closed_jaxpr) -> int:
    """Number of ``lax.scan`` equations (pallas kernel bodies excluded)."""
    return sum(1 for eqn in walk_eqns(closed_jaxpr.jaxpr)
               if eqn.primitive.name == "scan")


def count_pallas_calls(closed_jaxpr) -> int:
    """Number of ``pallas_call`` equations anywhere in the program.

    The WDM streaming guard uses this to pin the per-lane-mask claim
    (DESIGN.md §9): all R wavelength channels run as ONE dfr_scan launch
    plus ONE accumulate-into Gram launch per chunk-scan body — a program
    that vmapped ``pallas_call`` per channel would show R× the count.
    """
    return sum(1 for eqn in walk_eqns(closed_jaxpr.jaxpr)
               if eqn.primitive.name == "pallas_call")
