"""Back-compat shim: jaxpr introspection moved to ``repro.analysis``.

ISSUE 7 promoted this module into the static-analysis subsystem
(``repro.analysis.walker`` — hardened sub-jaxpr descent with equation
provenance; ``repro.analysis.rules`` — the declarative contract API built
on top).  Import from ``repro.analysis`` directly in new code.
"""

from repro.analysis.walker import (count_pallas_calls, count_scans,
                                   intermediate_shapes,
                                   max_intermediate_bytes,
                                   state_tensor_bytes, trace_jaxpr,
                                   walk_eqns)

__all__ = [
    "count_pallas_calls", "count_scans", "intermediate_shapes",
    "max_intermediate_bytes", "state_tensor_bytes", "trace_jaxpr",
    "walk_eqns",
]
