"""Jit-end-to-end batched DFRC experiment pipeline (mask → reservoir →
ridge readout → metrics) — see experiment.py for the API (including the WDM
ensemble entry ``WDMExperiment``), ridge.py for the in-graph Gram/GCV
readout solve and the streaming (chunk-scan) fits."""

from .experiment import (Experiment, ExperimentConfig, ExperimentResult,
                         WDMExperiment, channel_states)
from .ridge import (apply_readout, fit_ridge, fit_ridge_batched,
                    fit_ridge_streaming, fit_ridge_streaming_wdm, gram,
                    solve_gcv, solve_gcv_svd, with_bias)

__all__ = [
    "Experiment",
    "ExperimentConfig",
    "ExperimentResult",
    "WDMExperiment",
    "apply_readout",
    "channel_states",
    "fit_ridge",
    "fit_ridge_batched",
    "fit_ridge_streaming",
    "fit_ridge_streaming_wdm",
    "gram",
    "solve_gcv",
    "solve_gcv_svd",
    "with_bias",
]
