"""Jit-end-to-end batched DFRC experiment pipeline (mask → reservoir →
ridge readout → metrics) — see experiment.py for the API (including the WDM
ensemble entry ``WDMExperiment``), ridge.py for the in-graph Gram/GCV
readout solve and the streaming (chunk-scan) fits."""

from .experiment import (Experiment, ExperimentConfig, ExperimentResult,
                         WDMExperiment, channel_states)
from .ridge import (apply_readout, composed_chunk_states_fn, fit_ridge,
                    fit_ridge_batched, fit_ridge_streaming,
                    fit_ridge_streaming_composed, fit_ridge_streaming_shared,
                    fit_ridge_streaming_wdm, gram, solve_gcv, solve_gcv_svd,
                    with_bias)
from .session import (SessionConfig, SessionState, session_init,
                      session_predict, session_reset, session_solve,
                      session_step, session_update)

__all__ = [
    "Experiment",
    "ExperimentConfig",
    "ExperimentResult",
    "SessionConfig",
    "SessionState",
    "WDMExperiment",
    "apply_readout",
    "channel_states",
    "composed_chunk_states_fn",
    "fit_ridge",
    "fit_ridge_batched",
    "fit_ridge_streaming",
    "fit_ridge_streaming_composed",
    "fit_ridge_streaming_shared",
    "fit_ridge_streaming_wdm",
    "gram",
    "session_init",
    "session_predict",
    "session_reset",
    "session_solve",
    "session_step",
    "session_update",
    "solve_gcv",
    "solve_gcv_svd",
    "with_bias",
]
