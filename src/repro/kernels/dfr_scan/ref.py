"""Pure-jnp oracle for the DFR scan kernel.

Masks the sample series and chains ``model.node_update`` strictly
sequentially over (periods × nodes) — the physical device evolution.
Shapes: j [B, K], mask [N], s0 [B, N] -> states [B, K, N].
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def dfr_scan_ref(model, j: jnp.ndarray, mask: jnp.ndarray, s0: jnp.ndarray) -> jnp.ndarray:
    j = jnp.asarray(j)
    mask = jnp.asarray(mask, j.dtype)
    s0 = jnp.asarray(s0, j.dtype)
    u = j[..., :, None] * mask  # [B, K, N]

    def period(carry, u_k):
        s_prev, s_last = carry  # [B, N], [B]

        def node(s_pn, xs):
            u_i, s_tau_i = xs
            s_i = model.node_update(u_i, s_tau_i, s_pn)
            return s_i, s_i

        xs = (jnp.moveaxis(u_k, -1, 0), jnp.moveaxis(s_prev, -1, 0))
        s_last_new, s_nodes = jax.lax.scan(node, s_last, xs)
        s_new = jnp.moveaxis(s_nodes, 0, -1)
        return (s_new, s_last_new), s_new

    (_, _), states = jax.lax.scan(period, (s0, s0[..., -1]), jnp.moveaxis(u, 1, 0))
    return jnp.moveaxis(states, 0, 1)
