"""Pallas TPU kernel: fused masking + delayed-feedback reservoir scan.

One kernel evaluates the whole DFR evolution for a tile of batch lanes:
the masked input u = j·m (paper input layer), the per-node nonlinear update
(reservoir layer), and the τ-period feedback carry — with the reservoir
state resident in VMEM for the entire scan.  HBM traffic is one read of j
and one write of the states, instead of K·N round trips.

Layout (DESIGN.md §2): batch is the vector axis, tiled (S sublanes × 128
lanes) so every VPU op runs on full (8, 128) vregs; the node axis N lives in
VMEM rows; the period axis K is the innermost (sequential) grid dimension.
TPU grid order guarantees k advances fastest, so the VMEM scratch carries
s(t−τ) across periods of the same batch tile.

  grid = (B_tiles, K)
  j       [K, B_s, B_l]          block [1, S, L]    @ (k, b·S, 0)
  mask    [N, 1]                 block [N, 1]       (whole, every step)
       or [N, B_s, B_l]          block [N, S, L]    @ (0, b·S, 0)  (per-lane)
  s0      [N, B_s, B_l]          block [N, S, L]    @ (0, b·S, 0)
  out     [K, N, B_s, B_l]       block [1, N, S, L] @ (k, 0, b·S, 0)
  fin     [N, B_s, B_l]          block [N, S, L]    @ (0, b·S, 0)
  scratch s_prev [N, S, L] f32, s_last [S, L] f32

Two outputs: the per-period states AND the final reservoir state (the VMEM
``s_prev`` carry, flushed on the last period of each batch tile).  The final
state is what a *chunked* caller feeds back as ``s0`` of the next K-chunk —
for f32 I/O the resume is bit-exact, because the flush stores exactly the
f32 scratch values the uninterrupted scan would have kept in VMEM (DESIGN.md
§8).  The mask is either one [N, 1] vector broadcast across all batch lanes
(the paper's single-accelerator sweep — every lane shares the MLS mask) or a
per-lane [N, S, L] tile (WDM ensembles: each batch lane is a wavelength
channel with its own mask; pipeline/experiment.channel_states).

The node chain (θ coupling) is sequential by construction — the realised
branch bit of node i−1 feeds the value of node i (nonlinear.py docstring) —
so the inner loop is a ``fori_loop`` over N with dynamic row access into the
VMEM scratch; every step is elementwise on an [S, L] tile.

Compute is f32 in-kernel regardless of the I/O dtype (bf16 inputs are
upcast on load, downcast on store): the recurrence is a long product of
near-1 factors, where bf16 carries would accumulate error over K·N steps.
``out_dtype`` downcasts only the *emitted* state tensor (e.g. bf16 chunks
for the streaming path, halving the HBM write+readback traffic of each
chunk — DESIGN.md §9); the final-state carry always flushes in the input
dtype so chunked resume stays bit-exact in f32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128


def _kernel(model, n_nodes, per_lane,
            j_ref, mask_ref, s0_ref, out_ref, fin_ref, s_prev_ref, s_last_ref):
    k = pl.program_id(1)
    n_k = pl.num_programs(1)

    # First period of this batch tile: load the initial reservoir state.
    @pl.when(k == 0)
    def _init():
        s_prev_ref[...] = s0_ref[...].astype(jnp.float32)
        s_last_ref[...] = s0_ref[n_nodes - 1, :, :].astype(jnp.float32)

    j_k = j_ref[0, :, :].astype(jnp.float32)  # [S, L] — this period's sample

    def node(i, s_last):
        if per_lane:
            m_i = mask_ref[i, :, :].astype(jnp.float32)     # [S, L] tile
        else:
            m_i = mask_ref[i, 0]                            # lane-broadcast
        u_i = j_k * m_i                                 # input layer: u = j·m
        s_tau_i = s_prev_ref[i, :, :]                   # s(t−τ): same node, prev period
        s_i = model.node_update(u_i, s_tau_i, s_last)   # NL node (θ-chain via s_last)
        s_prev_ref[i, :, :] = s_i                       # becomes s(t−τ) for period k+1
        out_ref[0, i, :, :] = s_i.astype(out_ref.dtype)
        return s_i

    s_last = jax.lax.fori_loop(0, n_nodes, node, s_last_ref[...])
    s_last_ref[...] = s_last

    # Last period: flush the VMEM state carry — the resume point for the
    # next K-chunk (and the pipeline's train -> test continuation).
    @pl.when(k == n_k - 1)
    def _fin():
        fin_ref[...] = s_prev_ref[...].astype(fin_ref.dtype)


@functools.partial(jax.jit, static_argnames=("model", "block_s", "interpret",
                                             "out_dtype"))
def dfr_scan_tiled(
    model,
    j: jnp.ndarray,      # [K, S_total, L]
    mask: jnp.ndarray,   # [N, 1] (broadcast) or [N, S_total, L] (per-lane)
    s0: jnp.ndarray,     # [N, S_total, L]
    *,
    block_s: int = 8,
    interpret: bool = False,
    out_dtype=None,      # state-tensor dtype (default: j.dtype); fin stays j.dtype
) -> tuple[jnp.ndarray, jnp.ndarray]:  # ([K, N, S_total, L], [N, S_total, L])
    out_dtype = jnp.dtype(out_dtype) if out_dtype is not None else j.dtype
    k_periods, s_total, lanes = j.shape
    n_nodes = mask.shape[0]
    if s_total % block_s:
        raise ValueError(f"S_total {s_total} not divisible by block_s {block_s}")
    # Multi-tile emitted blocks must start on the out dtype's min-tile
    # boundary: (8, 128) covers f32, but a bf16/int8 out block needs
    # (16/32, 128) sublane alignment — a sub-minimal block_s would place
    # tile b at sublane offset b·block_s, illegal for every odd b on real
    # Mosaic even though interpret mode happily computes it.  Single-tile
    # blocks (block spans the whole S axis, offset always 0) are exempt.
    min_sub = max(8, 32 // out_dtype.itemsize)
    if s_total > block_s and out_dtype.itemsize < 4 and block_s % min_sub:
        raise ValueError(
            f"out_dtype {out_dtype} needs block_s a multiple of {min_sub} "
            f"once the batch spans multiple tiles (S_total {s_total} > "
            f"block_s {block_s}); pick block_s={min_sub} or let "
            f"auto_block_s choose it")
    per_lane = mask.ndim == 3
    grid = (s_total // block_s, k_periods)

    if per_lane:
        mask_spec = pl.BlockSpec((n_nodes, block_s, lanes), lambda b, k: (0, b, 0))
    else:
        mask_spec = pl.BlockSpec((n_nodes, 1), lambda b, k: (0, 0))

    kernel = functools.partial(_kernel, model, n_nodes, per_lane)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_s, lanes), lambda b, k: (k, b, 0)),
            mask_spec,
            pl.BlockSpec((n_nodes, block_s, lanes), lambda b, k: (0, b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, n_nodes, block_s, lanes), lambda b, k: (k, 0, b, 0)),
            pl.BlockSpec((n_nodes, block_s, lanes), lambda b, k: (0, b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k_periods, n_nodes, s_total, lanes), out_dtype),
            jax.ShapeDtypeStruct((n_nodes, s_total, lanes), j.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((n_nodes, block_s, lanes), jnp.float32),
            pltpu.VMEM((block_s, lanes), jnp.float32),
        ],
        interpret=interpret,
    )(j, mask, s0)
