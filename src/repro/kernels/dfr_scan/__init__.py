from . import ops, ref
from .ops import dfr_scan
from .ref import dfr_scan_ref

__all__ = ["dfr_scan", "dfr_scan_ref", "ops", "ref"]
