from . import ops, ref
from .ops import auto_block_s, dfr_scan, min_sublanes, padded_lanes
from .ref import dfr_scan_ref

__all__ = ["auto_block_s", "dfr_scan", "dfr_scan_ref", "min_sublanes", "ops",
           "padded_lanes", "ref"]
