from . import ops, ref
from .ops import auto_block_s, dfr_scan, padded_lanes
from .ref import dfr_scan_ref

__all__ = ["auto_block_s", "dfr_scan", "dfr_scan_ref", "ops", "padded_lanes", "ref"]
