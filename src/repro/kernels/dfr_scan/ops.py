"""Public jit'd wrapper for the DFR scan Pallas kernel.

Canonicalises [B, K] batches into the kernel's (S sublanes × 128 lanes)
tiling, pads the batch to a tile boundary, and restores [B, K, N] on the way
out.  On non-TPU backends the kernel runs in interpret mode (CPU-validated,
TPU-targeted); ``interpret`` can be forced either way.

``block_s`` sizes the sublane tile.  The default (``None``) picks the
smallest tile in {1, 2, 4, 8} that covers the batch, so small sweeps don't
pay for lanes they never use: a fixed block_s = 8 pads every batch to a
multiple of 1024 lanes (a B = 8 sweep would run 128× wasted reservoir work),
whereas auto-tiling pads B ≤ 128 to one 128-lane vreg row.

``mask`` is [N] (one mask broadcast across every batch lane — the paper's
sweep) or [B, N] (a mask per lane — WDM ensembles, where each lane is a
wavelength channel).  ``return_final=True`` additionally returns the final
reservoir state [B, N] straight from the kernel's VMEM carry: feeding it
back as ``s0`` of a following call resumes the scan bit-exactly for f32 I/O
(chunked streaming, train -> test continuation; DESIGN.md §8).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .dfr_scan import LANES, dfr_scan_tiled

_BLOCK_S_CHOICES = (1, 2, 4, 8, 16, 32)


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def min_sublanes(dtype) -> int:
    """Minimum sublane count of a TPU vreg tile for ``dtype``.

    (8, 128) for 4-byte types, (16, 128) for 2-byte (bf16), (32, 128) for
    1-byte (int8/fp8) — the packing rule sublanes × itemsize = 32 bytes.
    A *multi-tile* block of this dtype must start on such a boundary; a
    block that spans the whole axis (single tile) is exempt, since Mosaic
    pads sub-minimal whole arrays internally.
    """
    return max(8, 32 // jnp.dtype(dtype).itemsize)


def auto_block_s(batch: int, out_dtype=None) -> int:
    """Smallest sublane tile in {1, 2, 4, 8} whose (block_s, 128) tile covers
    ``batch``; once the batch spans multiple tiles, 8 (a full f32 vreg) — or
    the min tile of ``out_dtype`` when the *emitted* states are narrower than
    f32, so every multi-tile out block sits on a legal (16/32, 128) boundary
    instead of inheriting the f32 path's sub-minimal tile."""
    sublanes = -(-batch // LANES)
    for cand in _BLOCK_S_CHOICES[:4]:          # single-tile ladder: 1, 2, 4, 8
        if cand >= sublanes:
            return cand
    if out_dtype is not None and jnp.dtype(out_dtype).itemsize < 4:
        return min_sublanes(out_dtype)
    return 8


def padded_lanes(batch: int, block_s: int | None = None, out_dtype=None) -> int:
    """Total batch lanes (incl. padding) the kernel runs for ``batch``."""
    if block_s is None:
        block_s = auto_block_s(batch, out_dtype)
    tile = block_s * LANES
    return batch + (-batch % tile)


def dfr_scan(
    model,
    j: jnp.ndarray,      # [B, K]
    mask: jnp.ndarray,   # [N] (broadcast) or [B, N] (per-lane)
    s0: jnp.ndarray,     # [B, N]
    *,
    block_s: int | None = None,
    interpret: bool | None = None,
    return_final: bool = False,
    out_dtype=None,
):
    """States [B, K, N]; with ``return_final`` also the final state [B, N].

    ``out_dtype`` downcasts only the emitted state tensor (bf16 chunks for
    the streaming path); the final-state carry keeps the input dtype, so
    chunked resume stays bit-exact regardless of the chunk dtype.
    """
    if interpret is None:
        interpret = _auto_interpret()
    j = jnp.asarray(j)
    b, k_periods = j.shape
    mask = jnp.asarray(mask, j.dtype)
    n_nodes = int(mask.shape[-1])
    if mask.ndim == 2 and mask.shape[0] != b:
        raise ValueError(f"per-lane mask batch {mask.shape[0]} != j batch {b}")
    if block_s is None:
        block_s = auto_block_s(b, out_dtype)
    elif block_s not in _BLOCK_S_CHOICES:
        raise ValueError(f"block_s must be one of {_BLOCK_S_CHOICES}, got {block_s}")

    tile = block_s * LANES
    b_pad = -b % tile
    jp = jnp.pad(j, ((0, b_pad), (0, 0)))
    s0p = jnp.pad(jnp.asarray(s0, j.dtype), ((0, b_pad), (0, 0)))
    s_total = (b + b_pad) // LANES

    # [B, K] -> [K, S, L];  [B, N] -> [N, S, L]
    jt = jp.T.reshape(k_periods, s_total, LANES)
    s0t = s0p.T.reshape(n_nodes, s_total, LANES)
    if mask.ndim == 2:
        maskt = jnp.pad(mask, ((0, b_pad), (0, 0))).T.reshape(n_nodes, s_total, LANES)
    else:
        maskt = mask.reshape(n_nodes, 1)

    out, fin = dfr_scan_tiled(model, jt, maskt, s0t, block_s=block_s,
                              interpret=interpret, out_dtype=out_dtype)
    # [K, N, S, L] -> [B, K, N];  [N, S, L] -> [B, N]
    out = out.reshape(k_periods, n_nodes, s_total * LANES)
    states = jnp.moveaxis(out, -1, 0)[:b]
    if not return_final:
        return states
    s_final = fin.reshape(n_nodes, s_total * LANES).T[:b]
    return states, s_final
