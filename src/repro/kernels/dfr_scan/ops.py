"""Public jit'd wrapper for the DFR scan Pallas kernel.

Canonicalises [B, K] batches into the kernel's (S sublanes × 128 lanes)
tiling, pads the batch to a tile boundary, and restores [B, K, N] on the way
out.  On non-TPU backends the kernel runs in interpret mode (CPU-validated,
TPU-targeted); ``interpret`` can be forced either way.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .dfr_scan import LANES, dfr_scan_tiled


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def dfr_scan(
    model,
    j: jnp.ndarray,      # [B, K]
    mask: jnp.ndarray,   # [N]
    s0: jnp.ndarray,     # [B, N]
    *,
    block_s: int = 8,
    interpret: bool | None = None,
) -> jnp.ndarray:        # [B, K, N]
    if interpret is None:
        interpret = _auto_interpret()
    j = jnp.asarray(j)
    b, k_periods = j.shape
    n_nodes = int(mask.shape[-1])

    tile = block_s * LANES
    b_pad = -b % tile
    jp = jnp.pad(j, ((0, b_pad), (0, 0)))
    s0p = jnp.pad(jnp.asarray(s0, j.dtype), ((0, b_pad), (0, 0)))
    s_total = (b + b_pad) // LANES

    # [B, K] -> [K, S, L];  [B, N] -> [N, S, L]
    jt = jp.T.reshape(k_periods, s_total, LANES)
    s0t = s0p.T.reshape(n_nodes, s_total, LANES)
    maskt = jnp.asarray(mask, j.dtype).reshape(n_nodes, 1)

    out = dfr_scan_tiled(model, jt, maskt, s0t, block_s=block_s, interpret=interpret)
    # [K, N, S, L] -> [B, K, N]
    out = out.reshape(k_periods, n_nodes, s_total * LANES)
    return jnp.moveaxis(out, -1, 0)[:b]
