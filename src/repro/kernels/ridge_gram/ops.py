"""Public jit'd wrappers for the Gram accumulation Pallas kernel.

Pads T and F to tile boundaries (zero rows/cols contribute nothing to XᵀX)
and strips the padding from the outputs.  Interpret mode off-TPU.

``gram_accumulate`` is the single-instance [T, F] API;
``gram_accumulate_batched`` runs a whole [B, T, F] instance stack as ONE
kernel launch with a leading batch grid dimension — the batched readout fit
in pipeline/ridge.py uses it to avoid a sequential per-instance loop;
``gram_accumulate_batched_into`` folds one stream chunk into running
(G, c) stacks in place (the streaming fit's per-chunk update, DESIGN.md §8).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .ridge_gram import gram_tiled, gram_tiled_batched, gram_tiled_batched_into


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def effective_block_t(t: int, block_t: int = 512) -> int:
    """Clamp the requested T tile to the stream length, sublane-aligned.

    TPU f32 tiling needs the sublane (second-to-last) block dimension to be a
    multiple of 8; a naive ``min(block_t, t)`` produces e.g. a (100, 128)
    block for T = 100, which fails to lower.  Round the clamped tile UP to a
    multiple of 8 and let the caller pad T to match — zero rows are free.
    """
    eff = min(block_t, max(8, t))
    return -(-eff // 8) * 8


def gram_accumulate(
    x: jnp.ndarray,  # [T, F]
    y: jnp.ndarray,  # [T] or [T, C]
    *,
    block_t: int = 512,
    block_f: int = 128,
    interpret: bool | None = None,
):
    """Return (G = XᵀX [F, F] f32, c = XᵀY [F, C] f32) in one pass."""
    if interpret is None:
        interpret = _auto_interpret()
    x = jnp.asarray(x)
    y = jnp.asarray(y)
    if y.ndim == 1:
        y = y[:, None]
    t, f = x.shape
    block_t = effective_block_t(t, block_t)
    t_pad = -t % block_t
    f_pad = -f % block_f
    xp = jnp.pad(x, ((0, t_pad), (0, f_pad)))
    yp = jnp.pad(y.astype(x.dtype), ((0, t_pad), (0, 0)))
    g, c = gram_tiled(xp, yp, block_t=block_t, block_f=block_f, interpret=interpret)
    return g[:f, :f], c[:f]


def gram_accumulate_batched(
    x: jnp.ndarray,  # [B, T, F]
    y: jnp.ndarray,  # [B, T] or [B, T, C]
    *,
    block_t: int = 512,
    block_f: int = 128,
    interpret: bool | None = None,
):
    """Per-instance (G [B, F, F] f32, c [B, F, C] f32), one kernel launch.

    The batch axis becomes the outermost grid dimension of the kernel, so B
    instances share one ``pallas_call`` instead of a host/``lax.map`` loop.
    """
    if interpret is None:
        interpret = _auto_interpret()
    x = jnp.asarray(x)
    y = jnp.asarray(y)
    if y.ndim == 2:
        y = y[..., None]
    if x.ndim != 3 or y.ndim != 3 or y.shape[:2] != x.shape[:2]:
        raise ValueError(f"expected x [B, T, F] with y [B, T(, C)], got "
                         f"{x.shape} / {y.shape}")
    _, t, f = x.shape
    block_t = effective_block_t(t, block_t)
    t_pad = -t % block_t
    f_pad = -f % block_f
    xp = jnp.pad(x, ((0, 0), (0, t_pad), (0, f_pad)))
    yp = jnp.pad(y.astype(x.dtype), ((0, 0), (0, t_pad), (0, 0)))
    g, c = gram_tiled_batched(xp, yp, block_t=block_t, block_f=block_f,
                              interpret=interpret)
    return g[:, :f, :f], c[:, :f]


def gram_accumulate_batched_into(
    g0: jnp.ndarray,  # [B, F, F] f32 (running Gram; donated to the output)
    c0: jnp.ndarray,  # [B, F, C] f32 (running moment)
    x: jnp.ndarray,   # [B, T, F]
    y: jnp.ndarray,   # [B, T] or [B, T, C]
    *,
    block_t: int = 512,
    block_f: int = 128,
    interpret: bool | None = None,
):
    """(G0 + XᵀX, c0 + XᵀY) per instance, one in-place kernel launch.

    Chunked accumulation is bit-identical to a one-shot ``gram_accumulate_
    batched`` over the concatenated stream whenever every chunk's T is a
    multiple of the effective T tile (the kernel seeds its VMEM accumulator
    from the running value, so the f32 additions happen in the same order).

    F padding note: when F is not a multiple of ``block_f`` the init/output
    stacks are padded and re-sliced per call, which copies G.  Streaming
    callers that fold many chunks should carry the *padded* [B, Fp, Fp]
    stacks and call ``gram_tiled_batched_into`` directly (see
    pipeline/ridge.fit_ridge_streaming), stripping the padding once at the
    end.
    """
    if interpret is None:
        interpret = _auto_interpret()
    x = jnp.asarray(x)
    y = jnp.asarray(y)
    if y.ndim == 2:
        y = y[..., None]
    if x.ndim != 3 or y.ndim != 3 or y.shape[:2] != x.shape[:2]:
        raise ValueError(f"expected x [B, T, F] with y [B, T(, C)], got "
                         f"{x.shape} / {y.shape}")
    b, t, f = x.shape
    c_cols = y.shape[-1]
    if g0.shape != (b, f, f) or c0.shape != (b, f, c_cols):
        raise ValueError(f"init stacks {g0.shape} / {c0.shape} do not match "
                         f"x {x.shape} / y {y.shape}")
    block_t = effective_block_t(t, block_t)
    t_pad = -t % block_t
    f_pad = -f % block_f
    xp = jnp.pad(x, ((0, 0), (0, t_pad), (0, f_pad)))
    yp = jnp.pad(y.astype(x.dtype), ((0, 0), (0, t_pad), (0, 0)))
    g0p = jnp.pad(jnp.asarray(g0, jnp.float32), ((0, 0), (0, f_pad), (0, f_pad)))
    c0p = jnp.pad(jnp.asarray(c0, jnp.float32), ((0, 0), (0, f_pad), (0, 0)))
    g, c = gram_tiled_batched_into(g0p, c0p, xp, yp, block_t=block_t,
                                   block_f=block_f, interpret=interpret)
    return g[:, :f, :f], c[:, :f]
