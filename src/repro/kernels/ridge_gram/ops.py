"""Public jit'd wrapper for the Gram accumulation Pallas kernel.

Pads T and F to tile boundaries (zero rows/cols contribute nothing to XᵀX)
and strips the padding from the outputs.  Interpret mode off-TPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .ridge_gram import gram_tiled


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def gram_accumulate(
    x: jnp.ndarray,  # [T, F]
    y: jnp.ndarray,  # [T] or [T, C]
    *,
    block_t: int = 512,
    block_f: int = 128,
    interpret: bool | None = None,
):
    """Return (G = XᵀX [F, F] f32, c = XᵀY [F, C] f32) in one pass."""
    if interpret is None:
        interpret = _auto_interpret()
    x = jnp.asarray(x)
    y = jnp.asarray(y)
    if y.ndim == 1:
        y = y[:, None]
    t, f = x.shape
    block_t = min(block_t, max(8, t))
    t_pad = -t % block_t
    f_pad = -f % block_f
    xp = jnp.pad(x, ((0, t_pad), (0, f_pad)))
    yp = jnp.pad(y.astype(x.dtype), ((0, t_pad), (0, 0)))
    g, c = gram_tiled(xp, yp, block_t=block_t, block_f=block_f, interpret=interpret)
    return g[:f, :f], c[:f]
