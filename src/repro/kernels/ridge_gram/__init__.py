from . import ops, ref
from .ops import effective_block_t, gram_accumulate, gram_accumulate_batched
from .ref import gram_ref, gram_ref_batched

__all__ = [
    "effective_block_t",
    "gram_accumulate",
    "gram_accumulate_batched",
    "gram_ref",
    "gram_ref_batched",
    "ops",
    "ref",
]
