from . import ops, ref
from .ops import (effective_block_t, gram_accumulate, gram_accumulate_batched,
                  gram_accumulate_batched_into)
from .ref import gram_ref, gram_ref_batched

__all__ = [
    "effective_block_t",
    "gram_accumulate",
    "gram_accumulate_batched",
    "gram_accumulate_batched_into",
    "gram_ref",
    "gram_ref_batched",
    "ops",
    "ref",
]
