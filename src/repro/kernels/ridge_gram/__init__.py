from . import ops, ref
from .ops import gram_accumulate
from .ref import gram_ref

__all__ = ["gram_accumulate", "gram_ref", "ops", "ref"]
