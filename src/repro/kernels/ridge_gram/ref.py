"""Pure-jnp oracle for the streaming Gram/moment accumulation kernel.

X [T, F], Y [T, C]  ->  G = XᵀX [F, F],  c = XᵀY [F, C], accumulated in f32.
``gram_ref_batched`` is the per-instance [B, ...] form.
"""

from __future__ import annotations

import jax.numpy as jnp


def gram_ref(x: jnp.ndarray, y: jnp.ndarray):
    x32 = x.astype(jnp.float32)
    y32 = y.astype(jnp.float32)
    return x32.T @ x32, x32.T @ y32


def gram_ref_batched(x: jnp.ndarray, y: jnp.ndarray):
    x32 = x.astype(jnp.float32)
    y32 = y.astype(jnp.float32)
    return (jnp.einsum("btf,btg->bfg", x32, x32),
            jnp.einsum("btf,btc->bfc", x32, y32))
