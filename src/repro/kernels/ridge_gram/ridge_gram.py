"""Pallas TPU kernel: streaming normal-equation accumulation for the readout.

Readout training (paper Section III.A.3) solves (XᵀX + λI)·w = Xᵀy over the
T×(N+1) reservoir-state matrix.  The physical accelerator streams states into
a sample memory; here the analogue is a single pass over the state stream
that accumulates the Gram matrix G = XᵀX and moment c = Xᵀy tile-by-tile on
the MXU, so the state matrix never has to be HBM-resident at once — T can be
arbitrarily long for a fixed F = N+1.

The grid carries a leading *batch* dimension so a whole sweep of B task
instances is one kernel launch (the pipeline's vmap axis), instead of a
sequential ``lax.map`` of B launches:

  grid = (B, I, J, T_tiles)   (T innermost: sequential accumulation)
  X  [B, T, F]   lhs block [1, block_t, block_f] @ (b, t, i)   (re-read per J)
  X  [B, T, F]   rhs block [1, block_t, block_f] @ (b, t, j)
  Y  [B, T, C]   block [1, block_t, C]           @ (b, t, 0)
  G  [B, F, F]   block [1, block_f, block_f]     @ (b, i, j)
  c  [B, F, C]   block [1, block_f, C]           @ (b, i, 0)  (accumulated at j == 0)

Accumulators live in VMEM scratch in f32 (MXU partials in f32 via
``preferred_element_type``) and are flushed to HBM on the last T step of each
(b, i, j) tile — the t == 0 re-zero makes the scratch per-instance, so batch
lanes never mix.  bf16/f32 inputs give identical G up to f32 accumulation
order.  The B = 1 wrapper ``gram_tiled`` serves the single-instance API.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(n_t_tiles, xl_ref, xr_ref, y_ref, g_ref, c_ref, g_acc, c_acc):
    t = pl.program_id(3)
    j = pl.program_id(2)

    # First T step of this (b, i, j) tile: reset the per-instance accumulator.
    @pl.when(t == 0)
    def _zero():
        g_acc[...] = jnp.zeros_like(g_acc)
        c_acc[...] = jnp.zeros_like(c_acc)

    xl = xl_ref[0]
    g_acc[...] += jax.lax.dot_general(
        xl, xr_ref[0],
        dimension_numbers=(((0,), (0,)), ((), ())),  # xlᵀ @ xr, contraction over T
        preferred_element_type=jnp.float32,
    )

    @pl.when(j == 0)
    def _moment():
        c_acc[...] += jax.lax.dot_general(
            xl, y_ref[0],
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(t == n_t_tiles - 1)
    def _flush_g():
        g_ref[0] = g_acc[...]

    # c's output block maps to (b, i, 0) for every j; only the j == 0 pass
    # accumulates it, so only that pass may flush it.
    @pl.when(jnp.logical_and(t == n_t_tiles - 1, j == 0))
    def _flush_c():
        c_ref[0] = c_acc[...]


@functools.partial(jax.jit, static_argnames=("block_t", "block_f", "interpret"))
def gram_tiled_batched(
    x: jnp.ndarray,  # [B, T, F], T % block_t == 0, F % block_f == 0
    y: jnp.ndarray,  # [B, T, C]
    *,
    block_t: int = 512,
    block_f: int = 128,
    interpret: bool = False,
):
    batch, t_total, f_total = x.shape
    c_cols = y.shape[-1]
    grid = (batch, f_total // block_f, f_total // block_f, t_total // block_t)

    kernel = functools.partial(_kernel, grid[3])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, block_f), lambda b, i, j, t: (b, t, i)),
            pl.BlockSpec((1, block_t, block_f), lambda b, i, j, t: (b, t, j)),
            pl.BlockSpec((1, block_t, c_cols), lambda b, i, j, t: (b, t, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_f, block_f), lambda b, i, j, t: (b, i, j)),
            pl.BlockSpec((1, block_f, c_cols), lambda b, i, j, t: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((batch, f_total, f_total), jnp.float32),
            jax.ShapeDtypeStruct((batch, f_total, c_cols), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_f, block_f), jnp.float32),
            pltpu.VMEM((block_f, c_cols), jnp.float32),
        ],
        interpret=interpret,
    )(x, x, y)


@functools.partial(jax.jit, static_argnames=("block_t", "block_f", "interpret"))
def gram_tiled(
    x: jnp.ndarray,  # [T, F], T % block_t == 0, F % block_f == 0
    y: jnp.ndarray,  # [T, C]
    *,
    block_t: int = 512,
    block_f: int = 128,
    interpret: bool = False,
):
    """Single-instance entry point: the batched kernel at B = 1."""
    g, c = gram_tiled_batched(x[None], y[None], block_t=block_t,
                              block_f=block_f, interpret=interpret)
    return g[0], c[0]
