"""Pallas TPU kernel: streaming normal-equation accumulation for the readout.

Readout training (paper Section III.A.3) solves (XᵀX + λI)·w = Xᵀy over the
T×(N+1) reservoir-state matrix.  The physical accelerator streams states into
a sample memory; here the analogue is a single pass over the state stream
that accumulates the Gram matrix G = XᵀX and moment c = Xᵀy tile-by-tile on
the MXU, so the state matrix never has to be HBM-resident at once — T can be
arbitrarily long for a fixed F = N+1.

The grid carries a leading *batch* dimension so a whole sweep of B task
instances is one kernel launch (the pipeline's vmap axis), instead of a
sequential ``lax.map`` of B launches:

  grid = (B, I, J, T_tiles)   (T innermost: sequential accumulation)
  X  [B, T, F]   lhs block [1, block_t, block_f] @ (b, t, i)   (re-read per J)
  X  [B, T, F]   rhs block [1, block_t, block_f] @ (b, t, j)
  Y  [B, T, C]   block [1, block_t, C]           @ (b, t, 0)
  G  [B, F, F]   block [1, block_f, block_f]     @ (b, i, j)
  c  [B, F, C]   block [1, block_f, C]           @ (b, i, 0)  (accumulated at j == 0)

Accumulators live in VMEM scratch in f32 (MXU partials in f32 via
``preferred_element_type``) and are flushed to HBM on the last T step of each
(b, i, j) tile — the t == 0 re-zero makes the scratch per-instance, so batch
lanes never mix.  bf16/f32 inputs give identical G up to f32 accumulation
order.  The B = 1 wrapper ``gram_tiled`` serves the single-instance API.

``gram_tiled_batched_into`` is the *accumulate-into* variant (DESIGN.md §8):
two extra inputs carry running (G₀, c₀) stacks, aliased onto the outputs
(``input_output_aliases`` — the update is in-place in HBM), and the t == 0
step loads the VMEM scratch from them instead of zeroing.  Because each
chunk's partial products are added onto the running accumulator in exactly
the order an uninterrupted pass would use, folding a T-stream chunk-by-chunk
reproduces the one-shot result bit-for-bit whenever the chunk length is a
multiple of the T tile.  This is what lets a streaming caller fold
per-chunk state blocks into a running [B, F, F]/[B, F, C] Gram stack without
the full [B, T, F] state matrix ever existing.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(n_t_tiles, has_init, *refs):
    if has_init:
        g0_ref, c0_ref, xl_ref, xr_ref, y_ref, g_ref, c_ref, g_acc, c_acc = refs
    else:
        xl_ref, xr_ref, y_ref, g_ref, c_ref, g_acc, c_acc = refs
        g0_ref = c0_ref = None
    t = pl.program_id(3)
    j = pl.program_id(2)

    # First T step of this (b, i, j) tile: seed the per-instance accumulator —
    # zeros for the one-shot kernel, the running G₀/c₀ block when folding a
    # chunk into a carried accumulator.
    @pl.when(t == 0)
    def _seed():
        if has_init:
            g_acc[...] = g0_ref[0]
            c_acc[...] = c0_ref[0]
        else:
            g_acc[...] = jnp.zeros_like(g_acc)
            c_acc[...] = jnp.zeros_like(c_acc)

    xl = xl_ref[0]
    g_acc[...] += jax.lax.dot_general(
        xl, xr_ref[0],
        dimension_numbers=(((0,), (0,)), ((), ())),  # xlᵀ @ xr, contraction over T
        preferred_element_type=jnp.float32,
    )

    # bf16 state chunks keep the target stream f32 (it is O(B·T), not worth
    # rounding); dot_general needs homogeneous operands, so upcast the lhs
    # tile in VMEM — the HBM read already happened at the narrow dtype.
    @pl.when(j == 0)
    def _moment():
        xl_m = xl if xl.dtype == y_ref.dtype else xl.astype(y_ref.dtype)
        c_acc[...] += jax.lax.dot_general(
            xl_m, y_ref[0],
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(t == n_t_tiles - 1)
    def _flush_g():
        g_ref[0] = g_acc[...]

    # c's output block maps to (b, i, 0) for every j; only the j == 0 pass
    # accumulates it, so only that pass may flush it.
    @pl.when(jnp.logical_and(t == n_t_tiles - 1, j == 0))
    def _flush_c():
        c_ref[0] = c_acc[...]


def _specs(block_t, block_f, c_cols):
    in_specs = [
        pl.BlockSpec((1, block_t, block_f), lambda b, i, j, t: (b, t, i)),
        pl.BlockSpec((1, block_t, block_f), lambda b, i, j, t: (b, t, j)),
        pl.BlockSpec((1, block_t, c_cols), lambda b, i, j, t: (b, t, 0)),
    ]
    out_specs = [
        pl.BlockSpec((1, block_f, block_f), lambda b, i, j, t: (b, i, j)),
        pl.BlockSpec((1, block_f, c_cols), lambda b, i, j, t: (b, i, 0)),
    ]
    scratch = [
        pltpu.VMEM((block_f, block_f), jnp.float32),
        pltpu.VMEM((block_f, c_cols), jnp.float32),
    ]
    return in_specs, out_specs, scratch


@functools.partial(jax.jit, static_argnames=("block_t", "block_f", "interpret"))
def gram_tiled_batched(
    x: jnp.ndarray,  # [B, T, F], T % block_t == 0, F % block_f == 0
    y: jnp.ndarray,  # [B, T, C]
    *,
    block_t: int = 512,
    block_f: int = 128,
    interpret: bool = False,
):
    batch, t_total, f_total = x.shape
    c_cols = y.shape[-1]
    grid = (batch, f_total // block_f, f_total // block_f, t_total // block_t)
    in_specs, out_specs, scratch = _specs(block_t, block_f, c_cols)

    kernel = functools.partial(_kernel, grid[3], False)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=[
            jax.ShapeDtypeStruct((batch, f_total, f_total), jnp.float32),
            jax.ShapeDtypeStruct((batch, f_total, c_cols), jnp.float32),
        ],
        scratch_shapes=scratch,
        interpret=interpret,
    )(x, x, y)


@functools.partial(jax.jit, static_argnames=("block_t", "block_f", "interpret"))
def gram_tiled_batched_into(
    g0: jnp.ndarray,  # [B, F, F] f32 — running Gram stack (donated)
    c0: jnp.ndarray,  # [B, F, C] f32 — running moment stack (donated)
    x: jnp.ndarray,   # [B, T, F], T % block_t == 0, F % block_f == 0
    y: jnp.ndarray,   # [B, T, C]
    *,
    block_t: int = 512,
    block_f: int = 128,
    interpret: bool = False,
):
    """(G₀ + XᵀX, c₀ + XᵀY): fold one stream chunk into the running stats.

    The init stacks alias the outputs (in-place HBM update); each (b, i, j)
    tile reads its init block once (t == 0) before overwriting it on its
    last T step, so the aliasing is race-free under the sequential-T grid.
    """
    batch, t_total, f_total = x.shape
    c_cols = y.shape[-1]
    grid = (batch, f_total // block_f, f_total // block_f, t_total // block_t)
    in_specs, out_specs, scratch = _specs(block_t, block_f, c_cols)
    init_specs = [
        pl.BlockSpec((1, block_f, block_f), lambda b, i, j, t: (b, i, j)),
        pl.BlockSpec((1, block_f, c_cols), lambda b, i, j, t: (b, i, 0)),
    ]

    kernel = functools.partial(_kernel, grid[3], True)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=init_specs + in_specs,
        out_specs=out_specs,
        out_shape=[
            jax.ShapeDtypeStruct((batch, f_total, f_total), jnp.float32),
            jax.ShapeDtypeStruct((batch, f_total, c_cols), jnp.float32),
        ],
        scratch_shapes=scratch,
        input_output_aliases={0: 0, 1: 1},
        interpret=interpret,
    )(g0.astype(jnp.float32), c0.astype(jnp.float32), x, x, y)


@functools.partial(jax.jit, static_argnames=("block_t", "block_f", "interpret"))
def gram_tiled(
    x: jnp.ndarray,  # [T, F], T % block_t == 0, F % block_f == 0
    y: jnp.ndarray,  # [T, C]
    *,
    block_t: int = 512,
    block_f: int = 128,
    interpret: bool = False,
):
    """Single-instance entry point: the batched kernel at B = 1."""
    g, c = gram_tiled_batched(x[None], y[None], block_t=block_t,
                              block_f=block_f, interpret=interpret)
    return g[0], c[0]
