"""Pallas TPU kernel: streaming normal-equation accumulation for the readout.

Readout training (paper Section III.A.3) solves (XᵀX + λI)·w = Xᵀy over the
T×(N+1) reservoir-state matrix.  The physical accelerator streams states into
a sample memory; here the analogue is a single pass over the state stream
that accumulates the Gram matrix G = XᵀX and moment c = Xᵀy tile-by-tile on
the MXU, so the state matrix never has to be HBM-resident at once — T can be
arbitrarily long for a fixed F = N+1.

  grid = (I, J, T_tiles)   (T innermost: sequential accumulation)
  X  [T, F]   lhs block [block_t, block_f] @ (t, i)   (re-read per J — see ops)
  X  [T, F]   rhs block [block_t, block_f] @ (t, j)
  Y  [T, C]   block [block_t, C]           @ (t, 0)
  G  [F, F]   block [block_f, block_f]     @ (i, j)
  c  [F, C]   block [block_f, C]           @ (i, 0)   (accumulated at j == 0)

Accumulators live in VMEM scratch in f32 (MXU partials in f32 via
``preferred_element_type``) and are flushed to HBM on the last T step —
bf16/f32 inputs give identical G up to f32 accumulation order.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(n_t_tiles, xl_ref, xr_ref, y_ref, g_ref, c_ref, g_acc, c_acc):
    t = pl.program_id(2)
    j = pl.program_id(1)

    @pl.when(t == 0)
    def _zero():
        g_acc[...] = jnp.zeros_like(g_acc)
        c_acc[...] = jnp.zeros_like(c_acc)

    xl = xl_ref[...]
    g_acc[...] += jax.lax.dot_general(
        xl, xr_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),  # xlᵀ @ xr, contraction over T
        preferred_element_type=jnp.float32,
    )

    @pl.when(j == 0)
    def _moment():
        c_acc[...] += jax.lax.dot_general(
            xl, y_ref[...],
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(t == n_t_tiles - 1)
    def _flush_g():
        g_ref[...] = g_acc[...]

    # c's output block maps to (i, 0) for every j; only the j == 0 pass
    # accumulates it, so only that pass may flush it.
    @pl.when(jnp.logical_and(t == n_t_tiles - 1, j == 0))
    def _flush_c():
        c_ref[...] = c_acc[...]


@functools.partial(jax.jit, static_argnames=("block_t", "block_f", "interpret"))
def gram_tiled(
    x: jnp.ndarray,  # [T, F], T % block_t == 0, F % block_f == 0
    y: jnp.ndarray,  # [T, C]
    *,
    block_t: int = 512,
    block_f: int = 128,
    interpret: bool = False,
):
    t_total, f_total = x.shape
    c_cols = y.shape[1]
    grid = (f_total // block_f, f_total // block_f, t_total // block_t)

    kernel = functools.partial(_kernel, grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, block_f), lambda i, j, t: (t, i)),
            pl.BlockSpec((block_t, block_f), lambda i, j, t: (t, j)),
            pl.BlockSpec((block_t, c_cols), lambda i, j, t: (t, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_f, block_f), lambda i, j, t: (i, j)),
            pl.BlockSpec((block_f, c_cols), lambda i, j, t: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((f_total, f_total), jnp.float32),
            jax.ShapeDtypeStruct((f_total, c_cols), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_f, block_f), jnp.float32),
            pltpu.VMEM((block_f, c_cols), jnp.float32),
        ],
        interpret=interpret,
    )(x, x, y)
