"""Fault injection and chaos soaking for DFR serving (DESIGN.md §12).

``faults`` — seedable, traced, per-slot fault models (NaN/Inf ticks,
stuck-at nodes, carry corruption, MR thermal detuning, laser droop,
digitizer saturation) as pure wrappers around the serving tick; the
neutral spec is a bitwise identity.

``chaos`` — the soak harness that runs a slab through faults and grades
isolation / containment / re-convergence against a clean reference run.
"""

from .chaos import make_streams, run_soak
from .faults import (FaultSpec, faulted_rows, faulty_session_step,
                     faulty_step, inject_carry, inject_inputs, no_faults,
                     on_rows)

__all__ = [
    "FaultSpec", "no_faults", "on_rows", "faulted_rows",
    "inject_inputs", "inject_carry", "faulty_session_step", "faulty_step",
    "make_streams", "run_soak",
]
