"""Chaos soak: drive a session slab through faults and grade the guards.

One helper, :func:`run_soak`, runs the SAME stream data through the serving
tick twice — once under the neutral :class:`~repro.robustness.faults.FaultSpec`
(the clean reference) and once under the caller's spec — and grades the
three robustness claims of DESIGN.md §12:

* **isolation** — slots whose spec is neutral must produce *bitwise*
  identical predictions and final state to the clean run, faults in the
  other slots notwithstanding (the guards are per-row selects; the
  row-parallel pipeline never mixes rows);
* **containment** — slots with poisoning faults (NaN/Inf/corrupt) must be
  quarantined in-graph (``poison > 0``) and never emit a non-finite
  prediction to the host;
* **re-convergence** — a quarantined slot restarts from the dark-reservoir
  state and must learn again from post-fault data: its tail symbol-error
  rate is reported so callers can gate it (< 0.5 = better than chance;
  the smoke benchmark gates tighter).

The kill-and-restore leg of the chaos story exercises the *server*
(checkpoint + resume) and lives in ``benchmarks/chaos_soak.py`` on top of
``launch/serve_dfr.DFRServer``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tasks
from repro.core.masking import make_mask
from repro.pipeline.session import SessionConfig, session_init

from .faults import FaultSpec, faulted_rows, faulty_step, no_faults

__all__ = ["make_streams", "run_soak"]


def make_streams(batch: int, n_periods: int, *, snr_db: float = 24.0,
                 seed: int = 0):
    """[B, T] (inputs, targets) — one channel-equalization link per slot.

    Same input layer as the serving CLI: per-stream affine map to [0, 1]
    (the masked MR drive is an optical intensity and cannot go negative).
    """
    js, ys = [], []
    for r in range(batch):
        # over-request: the train_frac split may return a couple periods
        # fewer than asked, and the soak needs exactly whole ticks
        ds = tasks.channel_equalization(n_periods + 64, snr_db=snr_db,
                                        train_frac=0.999, seed=seed + r)
        x = np.asarray(ds.inputs_train[:n_periods], np.float32)
        x = (x - x.min()) / (x.max() - x.min() + 1e-12)
        js.append(x)
        ys.append(np.asarray(ds.targets_train[:n_periods], np.float32))
    return np.stack(js), np.stack(ys)


def _ser(y_hat: np.ndarray, y: np.ndarray) -> float:
    sym = np.asarray(tasks.SYMBOLS, np.float32)
    dec = sym[np.argmin(np.abs(y_hat[:, None] - sym[None, :]), axis=1)]
    return float(np.mean(dec != y))


def _run(cfg: SessionConfig, mask, spec: FaultSpec, j_all, y_all, *,
         n_ticks: int, seed: int):
    k = cfg.chunk_k
    state = session_init(cfg, spec.batch)
    y_hist, q_hist = [], []
    for t in range(n_ticks):
        jc = jnp.asarray(j_all[:, t * k:(t + 1) * k])
        yc = jnp.asarray(y_all[:, t * k:(t + 1) * k])
        y_hat, state = faulty_step(cfg, mask, spec, state, jc, yc, t,
                                   seed=seed,
                                   refresh=(t % cfg.refresh_every) == 0)
        y_hist.append(np.asarray(y_hat[..., 0]))
        q_hist.append(np.asarray(state.quarantined))
    y_hist = np.concatenate(y_hist, axis=1)          # [B, n_ticks * k]
    q_hist = np.stack(q_hist, axis=1)                # [B, n_ticks]
    return y_hist, q_hist, jax.device_get(state)


def run_soak(cfg: SessionConfig, spec: FaultSpec, *, n_ticks: int,
             seed: int = 0, data_seed: int = 0, snr_db: float = 24.0,
             tail_frac: float = 0.25) -> dict:
    """Soak ``spec`` against the clean reference and return the report.

    Both passes run the *same* compiled programs on the *same* data; only
    the traced spec differs.  Returns a JSON-serialisable report with the
    isolation / containment / re-convergence evidence; callers decide the
    gates (tests/test_robustness.py and benchmarks/chaos_soak.py).
    """
    batch, k = spec.batch, cfg.chunk_k
    mask = jnp.asarray(make_mask(cfg.n_nodes, seed=data_seed))
    j_all, y_all = make_streams(batch, n_ticks * k, snr_db=snr_db,
                                seed=data_seed)
    yh_clean, _, st_clean = _run(cfg, mask, no_faults(batch), j_all, y_all,
                                 n_ticks=n_ticks, seed=seed)
    yh_fault, q_hist, st_fault = _run(cfg, mask, spec, j_all, y_all,
                                      n_ticks=n_ticks, seed=seed)

    faulty = np.asarray(faulted_rows(spec))
    healthy = ~faulty
    leaves_equal = all(
        np.array_equal(np.asarray(a)[healthy], np.asarray(b)[healthy])
        for a, b in zip(st_clean, st_fault))
    healthy_bitwise = bool(
        np.array_equal(yh_clean[healthy], yh_fault[healthy]) and leaves_equal)

    tail = max(1, int(round(n_ticks * k * tail_frac)))
    w = cfg.washout

    def tail_ser(rows: np.ndarray, yh: np.ndarray) -> float | None:
        if not rows.any():
            return None
        return _ser(yh[rows, -tail:].ravel(), y_all[rows, -tail:].ravel())

    return {
        "batch": batch,
        "n_ticks": n_ticks,
        "chunk": k,
        "washout": w,
        "faulty_rows": np.flatnonzero(faulty).tolist(),
        "healthy_bitwise_identical": healthy_bitwise,
        "quarantine_events": np.asarray(st_fault.poison).tolist(),
        "quarantine_ticks": [np.flatnonzero(q_hist[i]).tolist()
                             for i in range(batch)],
        "output_all_finite": bool(np.isfinite(yh_fault).all()),
        "tail_periods": tail,
        "tail_ser_healthy": tail_ser(healthy, yh_fault),
        "tail_ser_faulty": tail_ser(faulty, yh_fault),
        "tail_ser_clean": tail_ser(np.ones(batch, bool), yh_clean),
        "tail_ser_rows": [_ser(yh_fault[i, -tail:], y_all[i, -tail:])
                          for i in range(batch)],
    }
