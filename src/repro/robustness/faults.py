"""Seedable, traced fault injection for DFR serving (DESIGN.md §12).

The fault models are *pure transforms over the step inputs and carries* —
nothing inside ``pipeline/session`` or the kernels changes.  A
:class:`FaultSpec` carries one value per slot ([B] leaves, a pytree), so

* it is **traced data**, not configuration: clean and faulted runs share
  ONE compiled program (the neutral spec is a bitwise identity — see
  below), which is what makes "healthy slots are bitwise identical to a
  fault-free run" a meaningful gate rather than a compiler coincidence;
* it is **vmappable per slot**: every model is elementwise in the batch
  axis, so faults target individual sessions of the continuously-batched
  slab without touching their neighbours;
* it is **seedable and replayable**: stochastic faults draw from
  ``fold_in(PRNGKey(seed), tick)`` with the tick as a traced operand, so a
  crash-and-restore run re-injects the exact same faults at the exact same
  ticks (the chaos soak's resume gate depends on this).

Fault taxonomy (motivated by arXiv:2310.09433 — cavity nonlinearities and
losses materially shift MR-RC behaviour — plus plain digital-link rot):

===================  =====================================================
``nan_prob``         per-period probability a drive sample becomes NaN
                     (ADC glitch / dropped host tick)
``inf_prob``         per-period probability a drive sample becomes +Inf
                     (TIA rail / overflow)
``corrupt_prob``     per-tick probability the reservoir carry row is
                     poisoned with NaN (SEU in the state memory)
``stuck_node``       virtual-node index held at ``stuck_value`` at every
                     tick boundary (-1 = none) — a dead MR tap
``detune_amp/period``MR thermal-detuning drift: slow sinusoidal
                     multiplicative gain on the drive (period in reservoir
                     periods)
``droop_rate``       laser power droop: ``exp(-rate · t)`` gain decay over
                     absolute periods
``sat_level``        digitizer saturation: drive clipped to ±``sat_level``
===================  =====================================================

**Neutral-spec bitwise identity.**  :func:`no_faults` sets probs to 0
(``u < 0`` never fires), ``stuck_node`` to -1 (never matches a node index),
``detune_amp`` to 0 and ``droop_rate`` to 0 (gain is exactly 1.0, and
``x * 1.0`` is IEEE-exact), and ``sat_level`` to +Inf (``clip(x, -inf,
inf)`` returns x).  Every transform degenerates to a select of the
identical value, so :func:`faulty_session_step` under the neutral spec is
*bitwise* equal to the plain guarded ``session_step`` — pinned by
tests/test_robustness.py.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.pipeline.session import (SessionConfig, SessionState,
                                    _session_step, session_reset)

__all__ = ["FaultSpec", "no_faults", "on_rows", "faulted_rows",
           "inject_inputs", "inject_carry", "faulty_session_step",
           "faulty_step"]


class FaultSpec(NamedTuple):
    """Per-slot fault intensities — a [B]-leaf pytree, traced like data."""

    nan_prob: jnp.ndarray      # [B] f32 — P(drive sample -> NaN) per period
    inf_prob: jnp.ndarray      # [B] f32 — P(drive sample -> +Inf) per period
    corrupt_prob: jnp.ndarray  # [B] f32 — P(carry row -> NaN) per tick
    stuck_node: jnp.ndarray    # [B] i32 — node held at stuck_value (-1 = none)
    stuck_value: jnp.ndarray   # [B] f32 — the stuck-at level
    detune_amp: jnp.ndarray    # [B] f32 — thermal-drift gain amplitude
    detune_period: jnp.ndarray  # [B] f32 — drift period in periods (> 0)
    droop_rate: jnp.ndarray    # [B] f32 — laser droop rate per period
    sat_level: jnp.ndarray     # [B] f32 — digitizer full-scale (clip ±sat)
    from_tick: jnp.ndarray     # [B] i32 — faults active from this tick …
    until_tick: jnp.ndarray    # [B] i32 — … up to (excluding) this tick

    @property
    def batch(self) -> int:
        return self.nan_prob.shape[0]

    def active(self, tick) -> jnp.ndarray:
        """[B] bool — slots whose fault window covers ``tick``.

        Outside the window every transform selects the untouched value, so
        a windowed fault is bitwise invisible before it starts and after it
        ends — that is what lets the chaos soak grade *re-convergence*: arm
        a poisoning fault for ticks [0, w), watch the quarantine fire, then
        verify the slot learns again from the clean tail.
        """
        t = jnp.asarray(tick, jnp.int32)
        return (t >= self.from_tick) & (t < self.until_tick)


def no_faults(batch: int) -> FaultSpec:
    """The neutral spec: a bitwise identity on every transform."""
    z = jnp.zeros((batch,), jnp.float32)
    return FaultSpec(
        nan_prob=z, inf_prob=z, corrupt_prob=z,
        stuck_node=jnp.full((batch,), -1, jnp.int32), stuck_value=z,
        detune_amp=z, detune_period=jnp.ones((batch,), jnp.float32),
        droop_rate=z, sat_level=jnp.full((batch,), jnp.inf, jnp.float32),
        from_tick=jnp.zeros((batch,), jnp.int32),
        until_tick=jnp.full((batch,), jnp.iinfo(jnp.int32).max, jnp.int32),
    )


def on_rows(spec: FaultSpec, rows, **fields) -> FaultSpec:
    """Return ``spec`` with ``fields`` applied on the given slot indices.

    ``on_rows(no_faults(8), [2, 5], nan_prob=0.2)`` arms a NaN-tick fault
    on slots 2 and 5 and leaves every other slot neutral.
    """
    rows = jnp.asarray(rows, jnp.int32)
    upd = {}
    for name, value in fields.items():
        leaf = getattr(spec, name)
        upd[name] = leaf.at[rows].set(jnp.asarray(value, leaf.dtype))
    return spec._replace(**upd)


def faulted_rows(spec: FaultSpec) -> jnp.ndarray:
    """[B] bool — True where the slot's spec deviates from neutral."""
    return ((spec.nan_prob > 0) | (spec.inf_prob > 0)
            | (spec.corrupt_prob > 0) | (spec.stuck_node >= 0)
            | (spec.detune_amp != 0) | (spec.droop_rate != 0)
            | jnp.isfinite(spec.sat_level))


def _tick_key(seed: int, tag: int, tick) -> jax.Array:
    """Replayable per-(seed, fault-kind, tick) key; ``tick`` may be traced."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), tag)
    return jax.random.fold_in(key, tick)


def inject_inputs(spec: FaultSpec, j_chunk: jnp.ndarray, tick, *,
                  seed: int = 0) -> jnp.ndarray:
    """Apply the drive-side fault models to one [B, K] input chunk.

    Order mirrors the physical signal path: MR thermal detuning and laser
    droop modulate the optical drive (multiplicative gains over absolute
    period index ``tick·K + k``), the digital link then drops/overflows
    samples (NaN/Inf ticks), and the digitizer clips last.  Works on any
    [B, K] drive chunk — ``session_step``'s per-tick chunk or a ``dfr_scan``
    input split into chunks.  Slots outside their fault window pass the
    chunk through bitwise untouched.
    """
    j0 = jnp.asarray(j_chunk, jnp.float32)
    b, k = j0.shape
    t_abs = (jnp.asarray(tick, jnp.int32) * k
             + jnp.arange(k, dtype=jnp.int32))[None, :].astype(jnp.float32)
    gain = 1.0 + spec.detune_amp[:, None] * jnp.sin(
        (2.0 * jnp.pi) * t_abs / spec.detune_period[:, None])
    gain = gain * jnp.exp(-spec.droop_rate[:, None] * t_abs)
    j = j0 * gain
    u = jax.random.uniform(_tick_key(seed, 0, tick), (b, k))
    nanp = spec.nan_prob[:, None]
    j = jnp.where(u < nanp, jnp.nan, j)
    j = jnp.where((u >= nanp) & (u < nanp + spec.inf_prob[:, None]),
                  jnp.inf, j)
    j = jnp.clip(j, -spec.sat_level[:, None], spec.sat_level[:, None])
    return jnp.where(spec.active(tick)[:, None], j, j0)


def inject_carry(spec: FaultSpec, s: jnp.ndarray, tick, *,
                 seed: int = 0) -> jnp.ndarray:
    """Apply the state-side fault models to one [B, N] reservoir carry.

    The stuck-at node is pinned at every tick boundary (a dead MR tap keeps
    re-asserting itself); carry corruption poisons the whole row with NaN
    with per-tick probability ``corrupt_prob`` (an SEU in state memory).
    Slots outside their fault window pass through bitwise untouched.
    """
    s0 = jnp.asarray(s)
    b, n = s0.shape
    node = jnp.arange(n, dtype=jnp.int32)[None, :]
    s = jnp.where(node == spec.stuck_node[:, None],
                  spec.stuck_value[:, None].astype(s0.dtype), s0)
    u = jax.random.uniform(_tick_key(seed, 1, tick), (b,))
    s = jnp.where((u < spec.corrupt_prob)[:, None],
                  jnp.asarray(jnp.nan, s0.dtype), s)
    return jnp.where(spec.active(tick)[:, None], s, s0)


def faulty_session_step(cfg: SessionConfig, mask: jnp.ndarray,
                        spec: FaultSpec, state: SessionState,
                        j_chunk: jnp.ndarray, y_chunk: jnp.ndarray, tick, *,
                        seed: int = 0, refresh: bool = False,
                        n_valid: jnp.ndarray | None = None,
                        reset: jnp.ndarray | None = None):
    """``session_step`` with the fault models wrapped around its inputs.

    Pure wrapper: slot resets land first (exactly where the clean step
    applies them), then the carry- and drive-side injections, then the
    unmodified serving tick — the health guard inside ``_session_step``
    (DESIGN.md §12) is what the injected faults exercise.  ``spec`` and
    ``tick`` are traced operands; ``seed`` is static.  Under the neutral
    spec the whole wrapper is bitwise invisible (module docstring).
    """
    if reset is not None:
        state = session_reset(state, reset)
    tick = jnp.asarray(tick, jnp.int32)
    state = state._replace(s=inject_carry(spec, state.s, tick, seed=seed))
    j = inject_inputs(spec, j_chunk, tick, seed=seed)
    return _session_step(cfg, mask, state, j, y_chunk, refresh=refresh,
                         n_valid=n_valid, reset=None)


# jit-per-(cfg, seed, refresh): the same two compiled variants as the clean
# step (fold-only / fold+solve) — faults ride on traced operands, never on
# new program shapes.  Servers re-jit with donate_argnums=(3,) to keep the
# slab donated (launch/serve_dfr.py).
faulty_step = functools.partial(
    jax.jit, static_argnames=("cfg", "seed", "refresh"))(faulty_session_step)
