"""jax version-compatibility shims.

The mesh-context API was reworked between jax 0.4.x and 0.5+/0.6+:

* ``jax.sharding.get_abstract_mesh`` — public in newer jax (returns an empty
  ``AbstractMesh`` when no mesh is set); 0.4.x keeps it in ``jax._src.mesh``
  and returns ``()`` when unset.
* ``AbstractMesh`` — newer jax takes ``(axis_sizes, axis_names)``; 0.4.x
  takes a single ``((name, size), ...)`` shape tuple.
* ``jax.make_mesh`` — newer jax accepts ``axis_types=``; 0.4.x does not.
* ``jax.set_mesh`` — newer jax's context manager that sets both the concrete
  and abstract mesh; 0.4.x only supports entering the ``Mesh`` itself (which
  sets the thread-resources physical mesh).

Everything in the repo that touches a mesh context goes through this module
so the codebase runs unmodified on the installed jax (0.4.37) and on newer
releases.  No other module should import from ``jax._src``.
"""

from __future__ import annotations

import contextlib

import jax
from jax.sharding import AbstractMesh, Mesh


def get_abstract_mesh():
    """The mesh of the current mesh context, or ``None`` when there is none.

    Unlike newer jax's ``jax.sharding.get_abstract_mesh`` this never returns
    an *empty* mesh — callers can test ``mesh is None`` only.  Under 0.4.x a
    plain ``with mesh:`` context is also picked up (via the thread-resources
    physical mesh), so ``use_mesh`` works uniformly across versions.
    """
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is None:
        from jax._src import mesh as _mesh_lib

        fn = getattr(_mesh_lib, "get_abstract_mesh", lambda: None)
    mesh = fn()
    if isinstance(mesh, (AbstractMesh, Mesh)) and not mesh.empty:
        return mesh
    # jax 0.4.x: `with mesh:` populates thread resources, not the abstract
    # mesh context; fall back to the physical mesh so maybe_shard & co. see
    # the active mesh on old releases too.
    try:
        from jax._src import mesh as _mesh_lib

        phys = _mesh_lib.thread_resources.env.physical_mesh
    except Exception:  # pragma: no cover - future jax may drop thread_resources
        return None
    if phys is not None and not phys.empty:
        return phys
    return None


def abstract_mesh(axis_sizes, axis_names) -> AbstractMesh:
    """``AbstractMesh`` from parallel size/name tuples, on any jax version."""
    axis_sizes = tuple(int(s) for s in axis_sizes)
    axis_names = tuple(axis_names)
    if len(axis_sizes) != len(axis_names):
        raise ValueError(f"{axis_sizes=} vs {axis_names=}")
    try:
        return AbstractMesh(axis_sizes, axis_names)  # jax >= 0.5
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))  # jax 0.4.x


def axis_types_auto(n: int):
    """``(AxisType.Auto,) * n`` where supported, else ``None`` (0.4.x)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    return None if axis_type is None else (axis_type.Auto,) * n


def make_mesh(axis_shapes, axis_names, *, devices=None) -> Mesh:
    """``jax.make_mesh`` with Auto axis types where the kwarg exists."""
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    types = axis_types_auto(len(tuple(axis_names)))
    if types is not None:
        kwargs["axis_types"] = types
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def shardings_for(mesh, tree):
    """Resolve a pytree of ``PartitionSpec`` into ``NamedSharding`` on ``mesh``.

    Newer jax lets ``jax.jit(in_shardings=...)`` take bare specs when a mesh
    is set; 0.4.x insists on ``Sharding`` objects.  Explicit ``NamedSharding``
    works everywhere, so jit call sites route their spec trees through here.
    """
    from jax.sharding import NamedSharding, PartitionSpec

    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, PartitionSpec) else s,
        tree,
        is_leaf=lambda s: isinstance(s, PartitionSpec),
    )


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    """Activate ``mesh`` for the dynamic extent: ``jax.set_mesh`` on newer
    jax, ``with mesh:`` (thread-resources) on 0.4.x."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        with set_mesh(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh
