"""Nonlinear (NL) node models for delayed-feedback reservoirs.

Closed-form devices; the first three match the paper's evaluation
(Section V.A):

* :class:`SiliconMR`      — the paper's contribution: an active silicon
  microring resonator's TPA drop-port response, paper Eq. (6-7) under the
  θ-corrected reading (below).  'Silicon MR'.
* :class:`MackeyGlass`    — Appeltant et al., Nat. Commun. 2, 468 (2011)
  [paper ref 19].  'Electronic (MG)'.
* :class:`MZISine`        — Duport et al., Sci. Rep. 6, 22381 (2016)
  [paper ref 20] (sin^2 intensity response).  'All Optical (MZI)'.
* :class:`SiliconMRLiteral` — paper Eq. (6-7) *exactly as printed*.  Kept as
  an ablation: the printed recurrence is exponentially unstable (see below),
  which tests/benchmarks demonstrate; it is not used for headline numbers.

The model surface extends beyond this module:

* ``MODEL_REGISTRY`` (below) names every reservoir device model, keyed by a
  stable string id; subsystems register theirs on import via
  :func:`register_model`.  ``repro.devices`` adds ``"mr_cavity_cmt"`` — the
  physics-fidelity coupled-mode-theory cavity (sub-stepped TPA, free-carrier
  and thermal dynamics inside each tick; DESIGN.md §14) whose zero-power
  calibrated limit recovers :class:`SiliconMR` (devices/calibrate.py).
* ``LINK_NONLINEARITIES`` (bottom of this module) are the *inter-stage* link
  maps of composed reservoir graphs (DESIGN.md §13) — identity / saturable
  ('sat') / MZI sin² ('sin2') — referenced by name from ``ReservoirStage``,
  not device models themselves.

The θ-corrected reading (DESIGN.md §7)
--------------------------------------
Eq. (6-7) as printed add the τ-delayed state ``s(t−τ)`` as the relaxation
term.  That makes the charge branch an affine map with multiplier
``1 + γ·α > 1`` on ``s(t−τ)`` whose branch condition compares ``u(t)``
against the *neighbouring* node ``s(t−θ)`` — nothing limits repeated
charging, and the dynamics diverge for every useful γ (verified: NRMSE = inf
for γ ≥ 0.1 on NARMA10; tests/test_paper_claims.py).  The DFR literature the
paper builds on (Appeltant 2011, Eq. (1) discretised) relaxes each node from
its *own previous state one θ earlier* and injects the delayed feedback
through the drive.  Reading Eq. (6-7)'s relaxation term as ``s(t−θ)`` —
a one-symbol typo — recovers exactly that structure and a bounded, fading
memory system:

    P(t)  = u(t) + γ·s(t−τ)                      (drive: input + feedback)
    D(t)  = P / (1 + β_tpa·P)                    (TPA-saturated drop power)
    α     = 1 − exp(−θ/τ_ph)                     (photon-lifetime response)
    s(t) = α·D + s(t−θ)          if u(t) > s(t−θ)   (fast charge, Eq. 6)
    s(t) = α·D + (1−α)·s(t−θ)    if u(t) ≤ s(t−θ)   (relaxed discharge, Eq. 7)

β_tpa = 0 keeps the published form (the branch asymmetry is then the only
nonlinearity — the map is positively homogeneous); β_tpa > 0 adds the
power-dependent two-photon-absorption loss the paper attributes the MR's
"rich nonlinearity" to (Section III.B).  Headline configs use β_tpa = 0.

Interface (shared by all models) over virtual nodes: with K input periods
(one τ each) and N virtual nodes (one θ slot each, τ = N·θ):

``node_update(u, s_tau, s_prev_node)``
    Elementwise update for one virtual node: ``u`` is the masked input for
    this node in this period, ``s_tau`` the same node's state one τ earlier,
    ``s_prev_node`` the immediately preceding node's state (θ earlier).
    This is the *sequential* physical evolution (the oracle).

``period_update(u_k, s_prev, s_last)``
    Whole-period update: ``u_k`` [..., N], ``s_prev`` [..., N] (the previous
    period), ``s_last`` [...] (state of node N-1 of the previous period).
    Exactly equal to chaining ``node_update`` over the node axis; evaluated

      - SiliconMR: sequentially (``lax.scan`` over nodes) — the realised
        branch bit feeds the *value* of the next node, which is not an
        associative recurrence.  Parallelism is over the batch axis
        (the Pallas kernel tiles batch lanes in VMEM; kernels/dfr_scan).
      - SiliconMRLiteral: O(log N) — the θ-chain enters only through the
        branch *condition*; condition bits propagate as {0,1}→{0,1} boolean
        transition functions composed with ``jax.lax.associative_scan``.
      - MackeyGlass: O(log N) — the θ-chain is an *affine* recurrence
        x_i = a_i + c·x_{i-1}; affine maps compose associatively.
      - MZISine: no θ-chain (Duport's synchronised regime) — elementwise.

Models are frozen dataclasses of Python floats: hashable statics that can be
closed over by jit without retracing hazards.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp


def _compose_bool(f, g):
    """Compose boolean transition functions represented as (out_if_0, out_if_1).

    Returns h = g ∘ f (f applied first), i.e. h(x) = g(f(x)).
    """
    f0, f1 = f
    g0, g1 = g
    h0 = jnp.where(f0, g1, g0)
    h1 = jnp.where(f1, g1, g0)
    return h0, h1


def _compose_affine(p, q):
    """Compose affine maps (m, a): x -> a + m·x.  Returns q ∘ p."""
    m1, a1 = p
    m2, a2 = q
    return m1 * m2, a2 + m2 * a1


@dataclasses.dataclass(frozen=True)
class SiliconMR:
    """Active microring TPA charging/discharging map — paper Eq. (6-7),
    θ-corrected reading (module docstring).

    τ_ph is set by the MR Q-factor (reverse-biased PN junction, paper
    Section IV.B); the paper's operating point is τ_ph = 50 ps with
    θ = 50 ps (N = 900, τ = 45 ns for NARMA10).  γ is the round-trip power
    attenuation of the feedback waveguide (coupler + splitter + propagation;
    not specified in the paper — 0.9 assumes the quoted low-loss devices).
    β_tpa ≥ 0 strengthens the TPA saturation of the intracavity drive.
    """

    theta_ps: float = 50.0
    tau_ph_ps: float = 50.0
    gamma: float = 0.9
    beta_tpa: float = 0.0

    name: str = dataclasses.field(default="Silicon MR", repr=False)

    @property
    def alpha(self) -> float:
        return 1.0 - math.exp(-self.theta_ps / self.tau_ph_ps)

    def _drive(self, u, s_tau):
        p = u + self.gamma * s_tau
        if self.beta_tpa:
            p = p / (1.0 + self.beta_tpa * p)
        return jnp.asarray(self.alpha, u.dtype) * p

    # -- sequential (physical) ------------------------------------------------
    def node_update(self, u, s_tau, s_prev_node):
        a = jnp.asarray(self.alpha, u.dtype)
        pre = self._drive(u, s_tau)
        charge = pre + s_prev_node               # Eq. (6), θ-corrected
        discharge = pre + s_prev_node * (1.0 - a)  # Eq. (7), θ-corrected
        return jnp.where(u > s_prev_node, charge, discharge)

    # -- whole period (node chain is inherently sequential here) --------------
    def period_update(self, u_k, s_prev, s_last):
        pre = self._drive(u_k, s_prev)  # [..., N] — parallel over batch
        a = jnp.asarray(self.alpha, u_k.dtype)

        def node(s_pn, xs):
            u_i, pre_i = xs  # [...], [...]
            s_i = jnp.where(u_i > s_pn, pre_i + s_pn, pre_i + s_pn * (1.0 - a))
            return s_i, s_i

        xs = (jnp.moveaxis(u_k, -1, 0), jnp.moveaxis(pre, -1, 0))  # [N, ...]
        _, s_nodes = jax.lax.scan(node, s_last, xs)
        return jnp.moveaxis(s_nodes, 0, -1)


@dataclasses.dataclass(frozen=True)
class SiliconMRLiteral:
    """Paper Eq. (6-7) exactly as printed (relaxation from s(t−τ)).

    Unstable: the charge branch multiplies s(t−τ) by (1 + γ·α) > 1 and its
    condition tests the *neighbour's* state, so nodes following a low-masked
    neighbour charge without bound (demonstrated in tests + EXPERIMENTS.md).
    Retained for the faithfulness ablation; within one period the node chain
    enters only through the branch bit, so the period update runs in
    O(log N) depth via an associative scan over boolean transition functions.
    """

    theta_ps: float = 50.0
    tau_ph_ps: float = 50.0
    gamma: float = 0.9

    name: str = dataclasses.field(default="Silicon MR (literal)", repr=False)

    @property
    def alpha(self) -> float:
        return 1.0 - math.exp(-self.theta_ps / self.tau_ph_ps)

    def _candidates(self, u, s_tau):
        a = jnp.asarray(self.alpha, u.dtype)
        pre = (u + self.gamma * s_tau) * a
        charge = pre + s_tau                 # Eq. (6) as printed
        discharge = pre + s_tau * (1.0 - a)  # Eq. (7) as printed
        return charge, discharge

    def node_update(self, u, s_tau, s_prev_node):
        charge, discharge = self._candidates(u, s_tau)
        return jnp.where(u > s_prev_node, charge, discharge)

    def period_update(self, u_k, s_prev, s_last):
        charge, discharge = self._candidates(u_k, s_prev)
        # Branch bit for node i given the *realised* bit of node i-1:
        #   prev bit 1 => s_{i-1} = charge[i-1];  prev bit 0 => discharge[i-1].
        prev_c = jnp.concatenate([s_last[..., None], charge[..., :-1]], axis=-1)
        prev_d = jnp.concatenate([s_last[..., None], discharge[..., :-1]], axis=-1)
        out_if_0 = u_k > prev_d
        out_if_1 = u_k > prev_c
        # Node 0 sees the known s_last in both slots -> constant function, so
        # the scanned prefix composition is independent of the seed bit.
        bits, _ = jax.lax.associative_scan(_compose_bool, (out_if_0, out_if_1), axis=-1)
        return jnp.where(bits, charge, discharge)


@dataclasses.dataclass(frozen=True)
class MackeyGlass:
    """Appeltant et al. (2011) single-node electronic DFR ('Electronic (MG)').

    Delay differential equation  T·ẋ = -x + η·X/(1 + X^p),
    X = x(t-τ) + γ·J(t), integrated exactly over one θ slot assuming the
    drive is constant within the slot:

        x_i(k) = e^{-θ/T}·x_{i-1}(k) + (1 - e^{-θ/T})·η·X/(1 + |X|^p).

    Defaults follow Appeltant et al.'s NARMA10 point: p = 7, θ = 0.2·T
    (virtual nodes deliberately spaced inside the relaxation time so
    neighbouring nodes couple), (η, γ) tuned per task on the training split
    (values recorded in repro/configs/dfrc_*.py).  τ = 10 ms class hardware —
    the training-time model (timing.py) uses that.
    """

    eta: float = 0.75
    gamma_in: float = 0.15
    p: float = 7.0
    theta_over_T: float = 0.2

    name: str = dataclasses.field(default="Electronic (MG)", repr=False)

    @property
    def decay(self) -> float:
        return math.exp(-self.theta_over_T)

    def _drive(self, u, s_tau):
        x = s_tau + self.gamma_in * u
        return self.eta * x / (1.0 + jnp.abs(x) ** self.p)

    def node_update(self, u, s_tau, s_prev_node):
        c = jnp.asarray(self.decay, u.dtype)
        return c * s_prev_node + (1.0 - c) * self._drive(u, s_tau)

    def period_update(self, u_k, s_prev, s_last):
        c = jnp.asarray(self.decay, u_k.dtype)
        a = (1.0 - c) * self._drive(u_k, s_prev)
        m = jnp.broadcast_to(c, a.shape)
        mm, aa = jax.lax.associative_scan(_compose_affine, (m, a), axis=-1)
        return aa + mm * s_last[..., None]


@dataclasses.dataclass(frozen=True)
class MZISine:
    """Duport et al. (2016) fibre-spool analogue photonic DFR ('All Optical (MZI)').

    Intensity response of the MZI modulator in the loop:
        x_i(k) = sin²(φ + β·u_i(k) + α·x_i(k-1)).
    Synchronised regime: no θ coupling between neighbouring virtual nodes.
    τ = 7.56 µs (1.7 km fibre spool) — used by timing.py.  Operating point
    (φ near quadrature-off, weak drive) tuned like the other devices.
    """

    alpha_fb: float = 0.8
    beta_in: float = 0.1
    phi: float = 0.1 * math.pi

    name: str = dataclasses.field(default="All Optical (MZI)", repr=False)

    def node_update(self, u, s_tau, s_prev_node):
        del s_prev_node
        return jnp.sin(self.phi + self.beta_in * u + self.alpha_fb * s_tau) ** 2

    def period_update(self, u_k, s_prev, s_last):
        del s_last
        return self.node_update(u_k, s_prev, None)


NLModel = SiliconMR | SiliconMRLiteral | MackeyGlass | MZISine


# ---------------------------------------------------------------------------
# Model registry: every reservoir device model, by stable string id
# ---------------------------------------------------------------------------
#
# The union alias above is a *type hint*; the contract itself is structural
# (``node_update``/``period_update`` on a hashable frozen dataclass), and
# other subsystems provide models too.  The registry is the runtime source
# of truth — config files, benchmarks and serving ingest resolve model ids
# through it, and ``repro.devices`` registers its CMT cavity here on import.

MODEL_REGISTRY: dict[str, type] = {
    "silicon_mr": SiliconMR,
    "silicon_mr_literal": SiliconMRLiteral,
    "mackey_glass": MackeyGlass,
    "mzi_sine": MZISine,
}


def register_model(model_id: str, cls: type) -> type:
    """Register a reservoir device model class under a stable string id.

    Idempotent for the same class; a different class under an existing id is
    a programming error (two subsystems fighting over a name) and raises.
    """
    prev = MODEL_REGISTRY.get(model_id)
    if prev is not None and prev is not cls:
        raise ValueError(
            f"model id {model_id!r} already registered to {prev.__name__}")
    MODEL_REGISTRY[model_id] = cls
    return cls


# ---------------------------------------------------------------------------
# Inter-stage link nonlinearities (composed reservoir graphs, DESIGN.md §13)
# ---------------------------------------------------------------------------
#
# Deep/cascaded photonic RC (arXiv:2512.10626) passes each layer's output
# through an on-chip nonlinearity before it drives the next layer — the link
# is part of the physics, not a free software choice.  A ``ReservoirStage``
# (core/graph.py) references one of these by *name* so the stage stays a
# hashable static; each is a pure elementwise map applied to the stage's
# projected scalar drive.  ``sat`` and ``sin2`` are bounded, which is what
# keeps a SiliconMR stage downstream of another reservoir inside the [0, 1]
# drive range the device models were tuned on (serve_dfr normalises its
# ingest the same way).


def link_identity(p: jnp.ndarray) -> jnp.ndarray:
    """Transparent link: the projected drive passes through unchanged."""
    return p


def link_saturable(p: jnp.ndarray) -> jnp.ndarray:
    """TPA-style saturable absorber, p / (1 + |p|) — the same saturation
    shape as SiliconMR's β_tpa drive term.  Monotone, bounded to (−1, 1);
    non-negative reservoir states map into [0, 1)."""
    return p / (1.0 + jnp.abs(p))


def link_sin2(p: jnp.ndarray) -> jnp.ndarray:
    """MZI intensity response, sin²(p) — the on-chip nonlinearity of the
    all-optical cascades.  Bounded to [0, 1]; folds at p = π/2, so it is the
    stronger (information-losing) choice at large drive."""
    return jnp.sin(p) ** 2


LINK_NONLINEARITIES = {
    "identity": link_identity,
    "sat": link_saturable,
    "sin2": link_sin2,
}
