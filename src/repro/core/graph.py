"""Composable reservoir graphs: deep, multi-loop, series-coupled topologies.

The paper's accelerator is ONE delay loop + ONE MR neuron; the related work
scales capacity by *composing* reservoirs — deep/cascaded photonic RC with an
on-chip nonlinearity between layers (arXiv:2512.10626), series-coupled
microrings with high linear memory capacity (arXiv:2308.15902), and
multi-loop delay reservoirs whose L loops share one drive (SNIPPETS.md §1's
``loops`` parameter).  This module is the graph abstraction those topologies
share (DESIGN.md §13):

* :class:`ReservoirStage` — one delay-loop layer: a nonlinearity (``model``),
  ``n_nodes`` virtual nodes per loop, ``loops`` parallel delay loops sharing
  the stage's scalar drive (each loop with its own MLS mask, so L·N virtual
  nodes see L mask phases of one input), and the *link* that feeds the next
  stage (a static projection of this stage's node states through an on-chip
  link nonlinearity — ``nonlinear.LINK_NONLINEARITIES``).
* :class:`ReservoirGraph` — a series chain of stages.  Stage k + 1's drive is
  stage k's linked output, period by period; the readout features are the
  concatenation of every stage's node states, so the graph is a drop-in
  ``states``-producer of width ``graph.width``.

Both are frozen dataclasses of Python scalars — hashable jit statics, like
the NL models themselves.  The *arrays* (per-stage mask stacks) are built
separately by :func:`build_stage_masks` and passed as operands.

Execution contract (the reason this lives in ``core/``): every stage is a
per-chunk transformer ``(drive [B, chunk], carry [B, L, N]) -> (features
[B, chunk, L·N], carry')`` — exactly the shape of the PR 3/4 chunk-scan
machinery — so layer k's streamed chunk feeds layer k + 1 *inside one scan
step* and no stage ever materialises a full-T [B, T, N] block on the
streaming path (pipeline/ridge.fit_ridge_streaming_composed; enforced by
``repro.analysis`` NoStateTensor contracts).  :func:`graph_states` is the
materialized reference oracle for tests and small runs; depth-1/loops-1
graphs reduce to a literal ``generate_states`` call, so the legacy single
reservoir is the depth-1 special case, bit for bit.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .masking import make_mask
from .nonlinear import LINK_NONLINEARITIES, NLModel, SiliconMR
from .reservoir import generate_channel_states, generate_states


@dataclasses.dataclass(frozen=True)
class ReservoirStage:
    """One delay-loop layer of a reservoir graph (hashable jit static).

    ``loops`` > 1 is the multi-loop topology: L physically separate delay
    loops (each τ = N·θ long, each with its own MLS mask phase) driven by the
    SAME scalar input — L·N virtual nodes share one drive, and the θ-chain
    of each loop closes on *its own* previous period, never across loops
    (the loops run as independent batch lanes; on the Pallas path all B·L
    lanes are ONE kernel launch via the per-lane mask BlockSpec).

    ``link``/``link_gain`` shape the drive this stage feeds the next one:
    the stage's L·N node states are projected (uniform mean — a static,
    mask-free tap of the delay line), scaled by ``link_gain`` and passed
    through the named on-chip link nonlinearity.  The bounded defaults
    (``sat``) keep a downstream SiliconMR inside the [0, 1] drive range the
    device models are tuned on.  The last stage's link is unused.

    ``input_gain`` scales this stage's incoming drive (1.0 = transparent;
    the Python-level ``!= 1.0`` check keeps the default bit-identical to
    the ungained path).
    """

    model: NLModel = dataclasses.field(default_factory=SiliconMR)
    n_nodes: int = 100
    loops: int = 1
    mask_seed: int = 1
    mask_levels: tuple[float, float] = (0.0, 1.0)
    input_gain: float = 1.0
    link: str = "sat"
    link_gain: float = 1.0

    def __post_init__(self):
        if self.n_nodes < 1:
            raise ValueError(f"n_nodes must be >= 1, got {self.n_nodes}")
        if self.loops < 1:
            raise ValueError(f"loops must be >= 1, got {self.loops}")
        if self.link not in LINK_NONLINEARITIES:
            raise ValueError(f"unknown link {self.link!r}; "
                             f"known: {sorted(LINK_NONLINEARITIES)}")

    @property
    def width(self) -> int:
        """Virtual nodes this stage contributes to the readout features."""
        return self.n_nodes * self.loops


@dataclasses.dataclass(frozen=True)
class ReservoirGraph:
    """A series chain of :class:`ReservoirStage` layers (hashable static)."""

    stages: tuple[ReservoirStage, ...]

    def __post_init__(self):
        if not isinstance(self.stages, tuple):
            object.__setattr__(self, "stages", tuple(self.stages))
        if len(self.stages) < 1:
            raise ValueError("a ReservoirGraph needs at least one stage")
        for st in self.stages:
            if not isinstance(st, ReservoirStage):
                raise TypeError(f"stages must be ReservoirStage, got {st!r}")

    @property
    def depth(self) -> int:
        return len(self.stages)

    @property
    def width(self) -> int:
        """Total readout feature nodes: Σ per-stage n_nodes·loops."""
        return sum(st.width for st in self.stages)

    @property
    def carry_layout(self) -> tuple[tuple[int, int], ...]:
        """Per-stage (loops, n_nodes) — the shape of each carry leaf past
        the batch axis, and the slice layout of a feature row."""
        return tuple((st.loops, st.n_nodes) for st in self.stages)


def chain(*stages: ReservoirStage) -> ReservoirGraph:
    """Convenience constructor: ``chain(stage0, stage1, ...)``."""
    return ReservoirGraph(stages=tuple(stages))


def single(graph_or_stage) -> bool:
    """True when the graph is the depth-1 / loops-1 legacy special case."""
    if isinstance(graph_or_stage, ReservoirStage):
        return graph_or_stage.loops == 1
    g = graph_or_stage
    return g.depth == 1 and g.stages[0].loops == 1


def build_stage_masks(graph: ReservoirGraph, *, channels: int | None = None):
    """The graph's mask arrays: a tuple of per-stage [L, N] stacks.

    Loop l of stage s gets ``make_mask(N_s, seed=stage.mask_seed + l)`` —
    the same ``seed + offset`` convention the WDM channel masks use.  With
    ``channels=R`` (a per-channel topology under ``WDMExperiment``) each
    stage gets an [R, L, N] stack, channel r / loop l seeded at
    ``mask_seed + r·L + l`` so no two (channel, loop) lanes share a mask
    phase; ``channels=None`` shares each stage's masks across the batch
    (the instance-sweep workload), matching the legacy single-mask
    broadcast at depth 1.
    """
    masks = []
    for stage in graph.stages:
        if channels is None:
            masks.append(jnp.stack([
                make_mask(stage.n_nodes, levels=stage.mask_levels,
                          seed=stage.mask_seed + l)
                for l in range(stage.loops)]))
        else:
            masks.append(jnp.stack([
                jnp.stack([make_mask(stage.n_nodes, levels=stage.mask_levels,
                                     seed=stage.mask_seed + r * stage.loops + l)
                           for l in range(stage.loops)])
                for r in range(channels)]))
    return tuple(masks)


def stage_link_drive(stage: ReservoirStage, features: jnp.ndarray) -> jnp.ndarray:
    """The drive this stage feeds the next: [..., W] features -> [...].

    Uniform mean over the stage's L·N nodes (a static tap of the delay
    line — every node weighted equally, so the projection adds no trainable
    or seeded parameters), scaled by ``link_gain``, through the stage's
    on-chip link nonlinearity.  Always computed in f32: with bf16 state
    chunks the emitted features are rounded, and the inter-stage drive
    should not round twice.
    """
    p = jnp.mean(features.astype(jnp.float32), axis=-1)
    if stage.link_gain != 1.0:
        p = p * jnp.float32(stage.link_gain)
    return LINK_NONLINEARITIES[stage.link](p)


def stage_states(
    stage: ReservoirStage,
    drive: jnp.ndarray,      # [B, K] this stage's scalar drive
    masks: jnp.ndarray,      # [L, N] shared or [B, L, N] per-instance masks
    s0: jnp.ndarray | None,  # [B, L, N] carry (None = dark loops)
    *,
    method: str = "fast",
    block_s: int | None = None,
    state_dtype=None,
):
    """One stage over ``drive``: -> (features [B, K, L·N], carry [B, L, N]).

    The L loops run as batch lanes (lane = b·L + l) through the per-lane
    mask path, so the Pallas kernel evaluates all B·L loops in ONE launch;
    the loops-1 shared-mask case is a literal ``generate_states`` call and
    the loops-1 per-instance case a literal ``generate_channel_states``
    call — the legacy paths, bitwise.  Feature index l·N + i is loop l's
    node i, matching the carry's [B, L, N] layout.
    """
    b, k = drive.shape
    per_instance = masks.ndim == 3
    l, n = masks.shape[-2:]
    if per_instance and masks.shape[0] != b:
        raise ValueError(f"per-instance masks {masks.shape} do not match "
                         f"batch {b}")
    if stage.input_gain != 1.0:
        drive = drive * jnp.float32(stage.input_gain)
    if l == 1:
        if per_instance:
            states, s_next = generate_channel_states(
                stage.model, drive, masks[:, 0], s0=None if s0 is None else s0[:, 0],
                method=method, block_s=block_s, return_final=True,
                state_dtype=state_dtype)
        else:
            states, s_next = generate_states(
                stage.model, drive, masks[0], s0=None if s0 is None else s0[:, 0],
                method=method, block_s=block_s, return_final=True,
                state_dtype=state_dtype)
        return states, s_next[:, None, :]
    # fold loops into lanes: lane b·L + l carries (instance b, loop l)
    drive_lanes = jnp.repeat(drive, l, axis=0)                    # [B·L, K]
    masks_lanes = (masks.reshape(b * l, n) if per_instance
                   else jnp.tile(masks, (b, 1)))                  # [B·L, N]
    s0_lanes = None if s0 is None else s0.reshape(b * l, n)
    states, s_next = generate_channel_states(
        stage.model, drive_lanes, masks_lanes, s0=s0_lanes, method=method,
        block_s=block_s, return_final=True, state_dtype=state_dtype)
    features = jnp.moveaxis(states.reshape(b, l, k, n), 1, 2).reshape(b, k, l * n)
    return features, s_next.reshape(b, l, n)


def graph_states(
    graph: ReservoirGraph,
    j: jnp.ndarray,          # [B, K] (or [K]) input drive of stage 0
    masks,                   # tuple of per-stage [L, N] / [B, L, N] stacks
    *,
    s0=None,                 # tuple of per-stage [B, L, N] carries
    method: str = "fast",
    block_s: int | None = None,
    return_final: bool = False,
    state_dtype=None,
):
    """Materialized graph evaluation: -> features [B, K, graph.width].

    The *reference oracle* for the composed streaming path (tests,
    examples, small runs): each stage's full-K state block IS resident
    here, which is exactly what the streaming fit avoids — use
    ``pipeline.fit_ridge_streaming_composed`` on the hot path.  Feature
    columns are the stages in order (stage s occupies
    ``[offset_s, offset_s + width_s)``); a depth-1/loops-1 graph returns
    ``generate_states`` output bit for bit.

    ``return_final=True`` adds the per-stage carry tuple — feed it back as
    ``s0`` to resume the whole chain (the composed train -> test carry).
    """
    j = jnp.asarray(j)
    squeeze = j.ndim == 1
    if squeeze:
        j = j[None, :]
    if len(masks) != graph.depth:
        raise ValueError(f"expected {graph.depth} stage mask stacks, "
                         f"got {len(masks)}")
    feats, carries = [], []
    drive = j
    for i, stage in enumerate(graph.stages):
        f, c = stage_states(stage, drive, masks[i],
                            None if s0 is None else s0[i],
                            method=method, block_s=block_s,
                            state_dtype=state_dtype)
        feats.append(f)
        carries.append(c)
        if i + 1 < graph.depth:
            drive = stage_link_drive(stage, f)
    features = feats[0] if graph.depth == 1 else jnp.concatenate(feats, axis=-1)
    if squeeze:
        features = features[0]
        carries = [c[0] for c in carries]
    return (features, tuple(carries)) if return_final else features
