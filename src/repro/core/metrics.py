"""Error metrics (paper Section V.B)."""

from __future__ import annotations

import numpy as np

# Variance floor of the NRMSE denominator, shared by the host metric below
# and both jit evaluation paths (pipeline/experiment.py): one constant so a
# zero-variance (constant) target yields the same finite value everywhere.
# 1e-30 is exactly representable in f32 (min normal ~1.2e-38), so the device
# paths can use it literally — a float64-only floor like 1e-300 would
# underflow to 0.0 in f32 and reintroduce the host/device disagreement.
VAR_EPS = 1e-30


def nrmse(y_true, y_pred) -> float:
    """Normalised root-mean-square error, paper Eq. (8).

    NRMSE = sqrt( Σ (y - ŷ)² / (N · σ²_y) ) — normalised by the *target*
    variance, so a constant predictor at the target mean scores 1.0.
    """
    y_true = np.asarray(y_true, dtype=np.float64)
    y_pred = np.asarray(y_pred, dtype=np.float64)
    var = np.var(y_true)
    return float(np.sqrt(np.mean((y_true - y_pred) ** 2) / (var + VAR_EPS)))


def ser(symbols_true, symbols_pred) -> float:
    """Symbol error rate: fraction of incorrectly reproduced symbols.

    Paper Eq. (9) as printed reads 'correct / total'; the standard metric
    (and the paper's Fig. 6, where lower is better) is 'incorrect / total' —
    we use the standard (DESIGN.md §7).
    """
    t = np.asarray(symbols_true)
    p = np.asarray(symbols_pred)
    return float(np.mean(t != p))
