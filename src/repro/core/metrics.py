"""Error metrics (paper Section V.B)."""

from __future__ import annotations

import numpy as np

# Variance floor of the NRMSE denominator, shared by the host metric below
# and both jit evaluation paths (pipeline/experiment.py): one constant so a
# zero-variance (constant) target yields the same finite value everywhere.
# 1e-30 is exactly representable in f32 (min normal ~1.2e-38), so the device
# paths can use it literally — a float64-only floor like 1e-300 would
# underflow to 0.0 in f32 and reintroduce the host/device disagreement.
VAR_EPS = 1e-30


def nrmse(y_true, y_pred) -> float:
    """Normalised root-mean-square error, paper Eq. (8).

    NRMSE = sqrt( Σ (y - ŷ)² / (N · σ²_y) ) — normalised by the *target*
    variance, so a constant predictor at the target mean scores 1.0.
    """
    y_true = np.asarray(y_true, dtype=np.float64)
    y_pred = np.asarray(y_pred, dtype=np.float64)
    var = np.var(y_true)
    return float(np.sqrt(np.mean((y_true - y_pred) ** 2) / (var + VAR_EPS)))


def memory_capacity_score(y_true, y_pred) -> float:
    """Linear memory capacity MC = Σ_d r²(y_d, ŷ_d)  (Jaeger 2001).

    ``y_true``/``y_pred`` are [T, D] stacks — channel d the d-step-delayed
    input u(k − d) and its reconstruction (core/tasks.memory_capacity) —
    and r² the squared Pearson correlation per delay channel.  Bounded by
    the number of delay channels D evaluated (and, for a reservoir, by its
    node count); a channel whose target or prediction is constant
    contributes 0, not NaN.  This is the capacity metric of the
    series-coupled-MR and delay-RC characterisation papers
    (arXiv:2308.15902, arXiv:2101.01664).
    """
    y_true = np.asarray(y_true, dtype=np.float64)
    y_pred = np.asarray(y_pred, dtype=np.float64)
    if y_true.ndim == 1:
        y_true, y_pred = y_true[:, None], y_pred[:, None]
    t = y_true - y_true.mean(axis=0)
    p = y_pred - y_pred.mean(axis=0)
    cov = np.sum(t * p, axis=0)
    denom = np.sum(t * t, axis=0) * np.sum(p * p, axis=0)
    r2 = np.divide(cov * cov, denom, out=np.zeros_like(cov),
                   where=denom > 0.0)
    return float(np.sum(r2))


def ser(symbols_true, symbols_pred) -> float:
    """Symbol error rate: fraction of incorrectly reproduced symbols.

    Paper Eq. (9) as printed reads 'correct / total'; the standard metric
    (and the paper's Fig. 6, where lower is better) is 'incorrect / total' —
    we use the standard (DESIGN.md §7).
    """
    t = np.asarray(symbols_true)
    p = np.asarray(symbols_pred)
    return float(np.mean(t != p))
