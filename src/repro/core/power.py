"""Power-consumption model (paper Section V.E, Eq. (15), Table 1).

    P_laser[dBm] = IL_dB + coupling_loss + splitter_loss + dynamic_range + S

The laser must deliver, at the photodetector, its sensitivity S plus every
dB of loss in the path plus the dynamic range used to encode the mask levels.
Electrical laser power divides the optical power by the wall-plug efficiency.
Per-device electrical terms (modulators, filters, amplifier, feedback PD) are
added on top.

The paper quotes totals of 126.48 mW ('Silicon MR') and 549.54 mW
('All Optical (MZI)').  Evaluating Eq. (15) literally with Table 1's numbers
reproduces the Silicon MR total to within a few percent, but overshoots the
MZI total unless the wall-plug division is skipped for the MZI laser; both
readings are reported by benchmarks/table1_power.py and the discrepancy is
noted in EXPERIMENTS.md.  The architectural claim — the MR's 6 dB vs the
MZI's 20 dB masking dynamic range dominating the budget — holds in every
reading.
"""

from __future__ import annotations

import dataclasses


def dbm_to_mw(dbm: float) -> float:
    return 10.0 ** (dbm / 10.0)


@dataclasses.dataclass(frozen=True)
class PowerSpec:
    """Loss/power budget of one accelerator (one Table 1 column)."""

    name: str
    insertion_loss_db: float
    coupling_loss_db: float
    dynamic_range_db: float
    pd_sensitivity_dbm: float = -5.8      # 10 Gb/s receiver [37]
    splitter_loss_db: float = 0.0
    wall_plug_efficiency: float = 0.10    # [35]
    # electrical adders (mW at the operating rate)
    modulator_mw: float = 0.0
    filter_mw: float = 0.0
    amplifier_mw: float = 0.0
    feedback_pd_mw: float = 0.0

    def laser_optical_dbm(self) -> float:
        """Eq. (15)."""
        return (
            self.insertion_loss_db
            + self.coupling_loss_db
            + self.splitter_loss_db
            + self.dynamic_range_db
            + self.pd_sensitivity_dbm
        )

    def laser_optical_mw(self) -> float:
        return dbm_to_mw(self.laser_optical_dbm())

    def laser_electrical_mw(self, *, apply_wall_plug: bool = True) -> float:
        p = self.laser_optical_mw()
        return p / self.wall_plug_efficiency if apply_wall_plug else p

    def total_mw(self, *, apply_wall_plug: bool = True) -> float:
        return (
            self.laser_electrical_mw(apply_wall_plug=apply_wall_plug)
            + self.modulator_mw
            + self.filter_mw
            + self.amplifier_mw
            + self.feedback_pd_mw
        )

    def breakdown_mw(self, *, apply_wall_plug: bool = True) -> dict[str, float]:
        return {
            "laser": self.laser_electrical_mw(apply_wall_plug=apply_wall_plug),
            "modulator": self.modulator_mw,
            "filter": self.filter_mw,
            "amplifier": self.amplifier_mw,
            "feedback_pd": self.feedback_pd_mw,
            "total": self.total_mw(apply_wall_plug=apply_wall_plug),
        }


# Table 1 columns.  Rate-dependent device energies are evaluated at the
# 10 Gb/s output-sampling rate of the PD/receiver chain the paper cites [37]:
#   MR modulator 15 fJ/bit -> 0.15 mW;  MR filter 0.705 pJ/bit -> 7.05 mW.
SILICON_MR = PowerSpec(
    name="Silicon MR",
    insertion_loss_db=8.25,
    coupling_loss_db=2.0,
    splitter_loss_db=0.5,
    dynamic_range_db=6.0,
    modulator_mw=15e-15 * 10e9 * 1e3,
    filter_mw=0.705e-12 * 10e9 * 1e3,
)

ALL_OPTICAL_MZI = PowerSpec(
    name="All Optical (MZI)",
    insertion_loss_db=7.4,
    coupling_loss_db=3.3,
    splitter_loss_db=0.0,
    dynamic_range_db=20.0,
    modulator_mw=100.0,            # MZI modulator [20]
    amplifier_mw=dbm_to_mw(10.0),  # ZHL-32A listed at 10 dBm [20]
    feedback_pd_mw=1.2,            # TTI TIA525 [20]
)

PAPER_TOTALS_MW = {"Silicon MR": 126.48, "All Optical (MZI)": 549.54}
