"""Benchmark task datasets (paper Section V).

* NARMA10 — Eq. (10); inputs i(k) ~ U[0, 0.5].  2000 samples: 1000 train /
  1000 test, as in the paper (following Duport et al.).
* Santa Fe dataset-A — chaotic far-infrared NH3 laser.  The original recording
  is not redistributable offline, so we integrate the Haken–Lorenz equations
  (the standard physical model of that laser; Hübner et al., Phys. Rev. A 40,
  6354) and quantise the intensity to 8-bit counts like the original ADC.
  6000 samples: 4000 train / 2000 test, as in the paper.  Documented as a
  surrogate wherever numbers are reported (DESIGN.md §7).
* Nonlinear channel equalisation — Eq. (11-12); 4-level symbols {-3,-1,1,3}
  through a linear-ISI + cubic channel with AWGN at a given SNR.  9000
  symbols: 6000 train / 3000 test.

Everything is generated deterministically from integer seeds with
numpy Generators (host-side data pipeline; see repro/data for the sharded
streaming wrapper).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Dataset:
    """Input series + aligned targets, split into train/test."""

    inputs_train: np.ndarray
    targets_train: np.ndarray
    inputs_test: np.ndarray
    targets_test: np.ndarray
    name: str = ""

    @property
    def n_train(self) -> int:
        return self.inputs_train.shape[0]


# NARMA10 recursion escape detection: bounded trajectories stay well under 1
# (the test suite pins max < 2.0); once |y| passes this bound the quadratic
# term has taken over and the run goes to inf within a few steps.
_NARMA_DIVERGENCE_BOUND = 10.0
_NARMA_MAX_REDRAWS = 16


def _narma10_recursion(i: np.ndarray) -> np.ndarray:
    """The raw Eq. (10) recursion; diverges for unlucky input draws."""
    n = i.shape[0]
    y = np.zeros(n)
    with np.errstate(over="ignore", invalid="ignore"):
        for k in range(9, n - 1):
            y[k + 1] = (
                0.3 * y[k]
                + 0.05 * y[k] * np.sum(y[k - 9 : k + 1])
                + 1.5 * i[k] * i[k - 9]
                + 0.1
            )
            if not np.isfinite(y[k + 1]) or abs(y[k + 1]) > _NARMA_DIVERGENCE_BOUND:
                y[k + 1 :] = np.inf      # flag divergence; caller redraws
                break
    return y


def narma10(n_samples: int = 2000, *, train_frac: float = 0.5, seed: int = 0) -> Dataset:
    """NARMA10 (paper Eq. (10)): y(k+1) = 0.3y(k) + 0.05y(k)Σ₉y(k-i) + 1.5i(k)i(k-9) + 0.1.

    The NARMA10 recursion is not globally stable: for unlucky uniform input
    draws the quadratic term wins and y escapes to inf, which would silently
    poison a vmapped seed sweep (every instance shares one jit program, so a
    single inf row corrupts batch reductions).  Divergent draws are detected
    (|y| > 10, or non-finite) and the inputs re-drawn — deterministically
    from ``(seed, attempt)``, with attempt 0 reproducing the historical
    single-draw stream bit-for-bit — up to a bounded number of retries.
    """
    warm = 50
    n = n_samples + warm
    for attempt in range(_NARMA_MAX_REDRAWS):
        # attempt 0 must equal the pre-guard behavior: default_rng(seed)
        rng = np.random.default_rng(seed if attempt == 0 else (seed, attempt))
        i = rng.uniform(0.0, 0.5, size=n)
        y = _narma10_recursion(i)
        if np.isfinite(y).all():
            break
    else:
        raise RuntimeError(
            f"narma10(seed={seed}) diverged on {_NARMA_MAX_REDRAWS} "
            f"consecutive input draws — the recursion escape bound "
            f"{_NARMA_DIVERGENCE_BOUND} should make this astronomically rare")
    i, y = i[warm:], y[warm:]
    split = int(n_samples * train_frac)
    return Dataset(i[:split], y[:split], i[split:], y[split:], name="narma10")


def santa_fe(n_samples: int = 6000, *, train_frac: float = 4000 / 6000, seed: int = 0) -> Dataset:
    """Santa Fe-A surrogate: Haken–Lorenz laser intensity, one-step-ahead target.

    ẋ = σ(y−x), ẏ = (r−z)x − y, ż = xy − bz;  intensity ∝ x².  Parameters in
    the chaotic spiking regime of the NH3 laser model.  RK4, subsampled, then
    scaled to 8-bit counts (0..255) like the original recording.
    """
    rng = np.random.default_rng(seed)
    sigma, r, b = 2.0, 15.0, 0.25
    dt, sub = 0.04, 12
    warm = 2000
    state = np.array([1.0, 1.0, 1.0]) + 0.1 * rng.standard_normal(3)

    def deriv(s):
        x, y, z = s
        return np.array([sigma * (y - x), (r - z) * x - y, x * y - b * z])

    total = warm + n_samples + 1
    out = np.empty(total)
    for k in range(total):
        for _ in range(sub):
            k1 = deriv(state)
            k2 = deriv(state + 0.5 * dt * k1)
            k3 = deriv(state + 0.5 * dt * k2)
            k4 = deriv(state + dt * k3)
            state = state + (dt / 6.0) * (k1 + 2 * k2 + 2 * k3 + k4)
        out[k] = state[0] ** 2
    out = out[warm:]
    out = np.round(255.0 * (out - out.min()) / (np.ptp(out) + 1e-12))
    i, y = out[:-1], out[1:]  # predict one step ahead
    split = int(n_samples * train_frac)
    return Dataset(i[:split], y[:split], i[split:], y[split:], name="santa_fe")


SYMBOLS = np.array([-3.0, -1.0, 1.0, 3.0])

# Linear-ISI taps of the Jaeger & Haas channel (paper Eq. (11)):
# q(n) = Σ_off w_off · d(n + off), taps n+2 .. n-7.
_CHAN_EQ_TAPS = {2: 0.08, 1: -0.12, 0: 1.0, -1: 0.18, -2: -0.1, -3: 0.09,
                 -4: -0.05, -5: 0.04, -6: 0.03, -7: 0.01}


# Post-drift link of channel_equalization_drift: the multipath changes — the
# first post-cursor echo flips sign and strengthens, the pre-cursor and
# second echo grow.  A readout equalising the old link misreads this one.
_CHAN_EQ_TAPS_DRIFTED = {**_CHAN_EQ_TAPS, 1: 0.20, -1: -0.25, -2: 0.15}


def _chan_eq_clean(d: np.ndarray, taps=_CHAN_EQ_TAPS) -> np.ndarray:
    """Noise-free received signal: linear ISI + cubic distortion (Eq. (11-12))."""
    q = np.zeros(d.shape[0])
    for off, w in taps.items():
        q += w * np.roll(d, -off)  # q(n) += w * d(n + off)
    return q + 0.036 * q**2 - 0.011 * q**3


def channel_equalization(
    n_symbols: int = 9000, *, snr_db: float = 24.0, train_frac: float = 6000 / 9000, seed: int = 0
) -> Dataset:
    """Nonlinear channel equalisation (paper Eq. (11-12), from Jaeger & Haas).

    d(n) i.i.d. over {-3,-1,1,3}; linear ISI q(n) over taps n+2..n-7; cubic
    distortion + AWGN.  Input to the reservoir is the received x(n); the
    target is the transmitted d(n).
    """
    rng = np.random.default_rng(seed)
    pad = 16
    n = n_symbols + 2 * pad
    d = rng.choice(SYMBOLS, size=n)
    x = _chan_eq_clean(d)
    sig_p = np.mean(x**2)
    noise_p = sig_p / (10.0 ** (snr_db / 10.0))
    x = x + rng.normal(0.0, np.sqrt(noise_p), size=n)
    d, x = d[pad:-pad], x[pad:-pad]
    split = int(n_symbols * train_frac)
    return Dataset(x[:split], d[:split], x[split:], d[split:], name=f"chan_eq_snr{snr_db:g}")


def channel_equalization_drift(
    n_symbols: int = 6000, *, snr_db: float = 28.0, snr_db_after: float = 16.0,
    drift_frac: float = 0.5, drift_taps: bool = True, train_frac: float = 0.0,
    seed: int = 0,
) -> Dataset:
    """Channel equalisation with a mid-stream link drift (online workload).

    Same ISI + cubic channel family as :func:`channel_equalization`, but at
    ``drift_frac`` of the stream the link changes: the AWGN power steps from
    ``snr_db`` to ``snr_db_after`` and (``drift_taps=True``) the multipath
    taps switch to ``_CHAN_EQ_TAPS_DRIFTED`` — the canonical drifting-link
    scenario where a forgetting-factor readout (pipeline/session, DESIGN.md
    §10) must out-track a λ = 1 one: the old link's equaliser misreads the
    new echoes, and the plain running Gram keeps it anchored there.  The
    default ``train_frac=0`` puts the whole stream in the test split: the
    intended consumer is the online session API, which learns as it serves
    (examples/online_equalization.py).
    """
    if not 0.0 < drift_frac < 1.0:
        raise ValueError(f"drift_frac must be in (0, 1), got {drift_frac}")
    rng = np.random.default_rng(seed)
    pad = 16
    n = n_symbols + 2 * pad
    d = rng.choice(SYMBOLS, size=n)
    k_step = pad + int(n_symbols * drift_frac)
    before = np.arange(n) < k_step
    taps_after = _CHAN_EQ_TAPS_DRIFTED if drift_taps else _CHAN_EQ_TAPS
    x_before = _chan_eq_clean(d)
    x = np.where(before, x_before, _chan_eq_clean(d, taps_after))
    # SNR referenced to the ORIGINAL link's clean power, so the pre-drift
    # segment is independent of what the link later drifts to
    sig_p = np.mean(x_before**2)
    sigma = np.where(before,
                     np.sqrt(sig_p / 10.0 ** (snr_db / 10.0)),
                     np.sqrt(sig_p / 10.0 ** (snr_db_after / 10.0)))
    x = x + sigma * rng.standard_normal(n)
    d, x = d[pad:-pad], x[pad:-pad]
    split = int(n_symbols * train_frac)
    return Dataset(x[:split], d[:split], x[split:], d[split:],
                   name=f"chan_eq_drift_snr{snr_db:g}to{snr_db_after:g}")


# ---------------------------------------------------------------------------
# Memory-capacity task suite (arXiv:2308.15902 / arXiv:2101.01664)
# ---------------------------------------------------------------------------
#
# The composed-reservoir payoff (core/graph.py, DESIGN.md §13) is *memory*,
# not just regression accuracy — deep chains and series-coupled loops are
# reported to hold inputs longer than one loop of the same total node count.
# These canonical characterisation tasks quantify that: linear MC (how many
# delayed copies of the input the readout can reconstruct), delayed XOR and
# parity (nonlinear memory — products of delayed bits).  All targets ride
# the pipeline's [T, C] multi-channel convention, so one vmapped Experiment
# evaluates every delay channel of every instance in a single jit call and
# `metrics.memory_capacity_score` reduces the predictions to the MC number.


def memory_capacity(
    n_samples: int = 2400, *, max_delay: int = 40, train_frac: float = 0.5,
    seed: int = 0,
) -> Dataset:
    """Linear memory-capacity probe (Jaeger 2001; arXiv:2308.15902 §IV).

    Input u(k) i.i.d. ~ U[0, 1]; target channel d (of ``max_delay``) is the
    delayed copy u(k − d), d = 1..max_delay — targets [T, max_delay].  The
    readout reconstructs every delay simultaneously (one multi-channel
    ridge fit); MC = Σ_d r²(u(k−d), ŷ_d) over the *test* split
    (``metrics.memory_capacity_score``).  ``max_delay`` bounds the curve —
    size it past the memory you expect (MC saturates below it).
    """
    if max_delay < 1:
        raise ValueError(f"max_delay must be >= 1, got {max_delay}")
    rng = np.random.default_rng(seed)
    n = n_samples + max_delay
    u = rng.uniform(0.0, 1.0, size=n)
    # y[k, d-1] = u[k - d], built on the warm prefix so every row is real
    y = np.stack([u[max_delay - d : n - d] for d in range(1, max_delay + 1)],
                 axis=1)
    u = u[max_delay:]
    split = int(n_samples * train_frac)
    return Dataset(u[:split], y[:split], u[split:], y[split:],
                   name=f"memory_capacity_d{max_delay}")


def delayed_xor(
    n_samples: int = 2400, *, delay: int = 2, train_frac: float = 0.5,
    seed: int = 0,
) -> Dataset:
    """Delayed-XOR probe: y(k) = u(k) XOR u(k − delay), u(k) ∈ {0, 1}.

    XOR is not linearly separable in (u(k), u(k−delay)), so reconstructing
    it needs *nonlinear* memory — the reservoir must mix the two bits, not
    just hold them (arXiv:2101.01664's XOR task).  Inputs are the raw bit
    stream; targets in {0, 1}.
    """
    if delay < 1:
        raise ValueError(f"delay must be >= 1, got {delay}")
    rng = np.random.default_rng(seed)
    n = n_samples + delay
    u = rng.integers(0, 2, size=n).astype(np.float64)
    y = np.logical_xor(u[delay:] > 0.5, u[:-delay] > 0.5).astype(np.float64)
    u = u[delay:]
    split = int(n_samples * train_frac)
    return Dataset(u[:split], y[:split], u[split:], y[split:],
                   name=f"delayed_xor_d{delay}")


def parity(
    n_samples: int = 2400, *, order: int = 3, delay: int = 1,
    train_frac: float = 0.5, seed: int = 0,
) -> Dataset:
    """Parity-``order`` probe: y(k) = Π_{m<order} b(k − delay − m), b ∈ {−1, +1}.

    The standard PAR-n nonlinear-memory benchmark: the product of ``order``
    consecutive ±1 bits starting ``delay`` steps back.  Each extra order
    multiplies in another delayed bit, so PAR-n needs n-way nonlinear
    mixing across the delay line.  Inputs are the ±1 bit stream mapped to
    {0, 1} drive levels ((b + 1)/2 — optical intensities are
    non-negative); targets stay ±1.
    """
    if order < 1:
        raise ValueError(f"order must be >= 1, got {order}")
    if delay < 0:
        raise ValueError(f"delay must be >= 0, got {delay}")
    rng = np.random.default_rng(seed)
    warm = delay + order
    n = n_samples + warm
    b = rng.choice([-1.0, 1.0], size=n)
    y = np.ones(n)
    for m in range(order):
        y *= np.roll(b, delay + m)
    u = (b + 1.0) / 2.0
    u, y = u[warm:], y[warm:]
    split = int(n_samples * train_frac)
    return Dataset(u[:split], y[:split], u[split:], y[split:],
                   name=f"parity_{order}_d{delay}")


def quantize_symbols(y: np.ndarray) -> np.ndarray:
    """Map regression outputs to the nearest 4-PAM symbol."""
    y = np.asarray(y)
    return SYMBOLS[np.argmin(np.abs(y[..., None] - SYMBOLS[None, :]), axis=-1)]
