"""Paper core: silicon-MR delayed-feedback reservoir computing in JAX."""

from . import power, tasks, timing
from .accelerator import DFRCAccelerator, DFRCConfig
from .graph import (ReservoirGraph, ReservoirStage, build_stage_masks, chain,
                    graph_states)
from .masking import make_mask, masked_input, mls_sequence, sample_and_hold
from .metrics import memory_capacity_score, nrmse, ser
from .nonlinear import (LINK_NONLINEARITIES, MODEL_REGISTRY, MZISine,
                        MackeyGlass, NLModel, SiliconMR, SiliconMRLiteral,
                        register_model)
from .readout import Readout, fit_readout
from .reservoir import generate_channel_states, generate_states, init_state

__all__ = [
    "DFRCAccelerator",
    "DFRCConfig",
    "LINK_NONLINEARITIES",
    "MODEL_REGISTRY",
    "MZISine",
    "MackeyGlass",
    "NLModel",
    "Readout",
    "ReservoirGraph",
    "ReservoirStage",
    "SiliconMR",
    "SiliconMRLiteral",
    "build_stage_masks",
    "chain",
    "fit_readout",
    "generate_channel_states",
    "generate_states",
    "graph_states",
    "init_state",
    "make_mask",
    "masked_input",
    "memory_capacity_score",
    "mls_sequence",
    "nrmse",
    "power",
    "register_model",
    "sample_and_hold",
    "ser",
    "tasks",
    "timing",
]
