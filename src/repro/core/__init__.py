"""Paper core: silicon-MR delayed-feedback reservoir computing in JAX."""

from . import power, tasks, timing
from .accelerator import DFRCAccelerator, DFRCConfig
from .masking import make_mask, masked_input, mls_sequence, sample_and_hold
from .metrics import nrmse, ser
from .nonlinear import MZISine, MackeyGlass, NLModel, SiliconMR, SiliconMRLiteral
from .readout import Readout, fit_readout
from .reservoir import generate_channel_states, generate_states, init_state

__all__ = [
    "DFRCAccelerator",
    "DFRCConfig",
    "MZISine",
    "MackeyGlass",
    "NLModel",
    "Readout",
    "SiliconMR",
    "SiliconMRLiteral",
    "fit_readout",
    "generate_channel_states",
    "generate_states",
    "init_state",
    "make_mask",
    "masked_input",
    "mls_sequence",
    "nrmse",
    "power",
    "sample_and_hold",
    "ser",
    "tasks",
    "timing",
]
