"""Training-time model (paper Section V.D, Fig. 7).

Training time = state-collection time + readout-solve time.

* State collection is physical: each of the T_train input samples occupies one
  feedback-loop period τ, so  T_collect = n_train · τ.
    - 'Silicon MR':      τ = N·θ with θ = 50 ps (on-chip waveguide; 45 ns at
      the paper's NARMA10 point N = 900).
    - 'All Optical (MZI)': τ = 7.56 µs (1.7 km fibre spool [20]).
    - 'Electronic (MG)':  τ = 10 ms (analog Mackey-Glass board [19]).
* The readout solve is host-side linear algebra, identical for all three
  accelerators: pseudo-inverse of the T×(N+1) state matrix,
  flops ≈ 2·T·(N+1)² + 11·(N+1)³ (Golub–Van Loan SVD count), at a host rate
  (default 10 GFLOP/s, a 2021-era workstation).

The paper reports 98× (vs electronic) and 93× (vs photonic) average speedups;
those averages depend on unstated solve-time constants, so the benchmark
(benchmarks/fig7_training_time.py) reports our per-task model outputs next to
the paper's claims rather than asserting equality (DESIGN.md §7).
"""

from __future__ import annotations

import dataclasses

THETA_MR_S = 50e-12
TAU_MZI_S = 7.56e-6
TAU_MG_S = 10e-3


@dataclasses.dataclass(frozen=True)
class TimingModel:
    name: str
    tau_s_fn: str  # "mr" (N-dependent) | fixed float encoded below
    tau_fixed_s: float = 0.0
    host_gflops: float = 10.0

    def tau_s(self, n_nodes: int) -> float:
        if self.tau_s_fn == "mr":
            return n_nodes * THETA_MR_S
        return self.tau_fixed_s

    def collection_time_s(self, n_train: int, n_nodes: int) -> float:
        return n_train * self.tau_s(n_nodes)

    def solve_time_s(self, n_train: int, n_nodes: int) -> float:
        n = n_nodes + 1
        flops = 2.0 * n_train * n**2 + 11.0 * n**3
        return flops / (self.host_gflops * 1e9)

    def training_time_s(self, n_train: int, n_nodes: int) -> float:
        return self.collection_time_s(n_train, n_nodes) + self.solve_time_s(n_train, n_nodes)


TIMING_SILICON_MR = TimingModel(name="Silicon MR", tau_s_fn="mr")
TIMING_MZI = TimingModel(name="All Optical (MZI)", tau_s_fn="fixed", tau_fixed_s=TAU_MZI_S)
TIMING_MG = TimingModel(name="Electronic (MG)", tau_s_fn="fixed", tau_fixed_s=TAU_MG_S)
