"""Linear readout training (paper Section III.A.3, Eq. (3)).

Only W_out is trained.  The paper uses the Moore–Penrose pseudo-inverse; we
provide that (``method="pinv"``) plus the ridge-regularised normal-equation
solve (``method="ridge"``, default — identical at λ→0 but numerically robust
in float32 and streamable).

The normal-equation path accumulates the Gram matrix G = XᵀX and moment
c = Xᵀy in a single pass over the state stream, so the full T×N state matrix
never has to be resident — the analogue of the paper's on-chip sample memory,
but memory-bounded.  On TPU that accumulation is the kernels/ridge_gram
Pallas kernel; on host we reduce in float64 (offline training is host-side in
the physical system too).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Readout:
    """Trained readout: y = [states, 1] @ w  (bias folded as last column)."""

    w: jnp.ndarray  # [N + 1, C]

    def __call__(self, states: jnp.ndarray) -> jnp.ndarray:
        x = _with_bias(states)
        y = x @ self.w
        return y[..., 0] if y.shape[-1] == 1 else y


def _with_bias(states: jnp.ndarray) -> jnp.ndarray:
    ones = jnp.ones((*states.shape[:-1], 1), dtype=states.dtype)
    return jnp.concatenate([states, ones], axis=-1)


def _canon_targets(targets) -> np.ndarray:
    t = np.asarray(targets, dtype=np.float64)
    return t[:, None] if t.ndim == 1 else t


def fit_readout(
    states: jnp.ndarray,
    targets: jnp.ndarray,
    *,
    l2: float | tuple = 1e-6,
    method: str = "ridge",
    use_kernel: bool = False,
) -> Readout:
    """Solve for W_out from states [T, N] and targets [T] or [T, C].

    ``method="pinv"`` reproduces the paper's Moore–Penrose approach exactly;
    ``method="ridge"`` solves (G + λ·tr(G)/n·I)w = c.  Passing a tuple of λs
    holds out the last 20 % of the training stream and keeps the best —
    needed when N approaches the number of training samples (N = 900 on
    1000-sample NARMA10 overfits catastrophically at fixed tiny λ).
    ``use_kernel=True`` accumulates G, c with the Pallas streaming kernel
    (interpret mode on CPU) and solves on host.
    """
    t = _canon_targets(targets)
    if states.ndim != 2 or states.shape[0] != t.shape[0]:
        raise ValueError(f"states {states.shape} vs targets {t.shape}")

    if method == "pinv":
        x = np.asarray(_with_bias(states), dtype=np.float64)
        w = np.linalg.pinv(x) @ t
        return Readout(w=jnp.asarray(w, dtype=states.dtype))

    if method != "ridge":
        raise ValueError(f"unknown method {method!r}")

    if use_kernel:
        from repro.kernels.ridge_gram import ops as gram_ops

        g, c = gram_ops.gram_accumulate(_with_bias(states), jnp.asarray(t, states.dtype))
        g = np.asarray(g, dtype=np.float64)
        c = np.asarray(c, dtype=np.float64)
        x = np.asarray(_with_bias(states), dtype=np.float64)
    else:
        x = np.asarray(_with_bias(states), dtype=np.float64)
        g = x.T @ x
        c = x.T @ t

    n = g.shape[0]
    eye = np.eye(n)

    def solve(lam, gm, cm):
        return np.linalg.solve(gm + lam * np.trace(gm) / n * eye, cm)

    if not isinstance(l2, (tuple, list)):
        return Readout(w=jnp.asarray(solve(l2, g, c), dtype=states.dtype))

    # λ selected by generalised cross-validation.  A held-out tail of the
    # training stream does NOT work here: reservoir states are one Markov
    # trajectory, so a near-singular min-norm solution scores well on the
    # tail yet explodes on fresh test inputs (observed: val-MSE flat in λ
    # while test NRMSE spans 0.6 … 20).  GCV penalises the effective
    # degrees of freedom dof(λ) = Σ s²/(s²+λ') instead:
    #     GCV(λ) = T·‖y − ŷ_λ‖² / (T − dof(λ))²
    u, s, _vt = np.linalg.svd(x, full_matrices=False)
    uty = u.T @ t                                    # [F, C]
    t_norm2 = float(np.sum(t * t))
    big_t = x.shape[0]
    best, best_gcv = None, np.inf
    for lam in l2:
        lamp = lam * np.trace(g) / n
        shrink = (s * s) / (s * s + lamp)            # [F]
        dof = float(np.sum(shrink))
        # ‖y − ŷ‖² = ‖y‖² − 2·Σ shrink·(uᵀy)² + Σ shrink²·(uᵀy)²
        uy2 = np.sum(uty * uty, axis=1)
        rss = t_norm2 - float(np.sum((2.0 * shrink - shrink**2) * uy2))
        gcv = big_t * max(rss, 0.0) / max(big_t - dof, 1.0) ** 2
        if gcv < best_gcv:
            best, best_gcv = lam, gcv
    return Readout(w=jnp.asarray(solve(best, g, c), dtype=states.dtype))
