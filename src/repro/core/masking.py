"""Input pre-processing for DFRC: sample-and-hold + binary masking.

The paper (Section III.A / V.A) masks the sampled-and-held input j(t) with a
periodic binary mask m(t) built from a maximum-length sequence (MLS), per
Appeltant et al., "Constructing optimized binary masks for reservoir computing
with delay systems", Sci. Rep. 4, 3629 (2014) [paper ref 25].  The mask plays
the role of the fixed random input weights W_in: node i of every period sees
input u[k, i] = j[k] * m[i].

MLS are generated with a *Galois*-form LFSR over GF(2) using
primitive-polynomial taps (``mls_sequence``), giving a pseudo-random ±1
sequence of period 2**m - 1 with ideal autocorrelation.  For N virtual nodes
we take the first N entries of the smallest MLS with period >= N (Appeltant
et al. do the same truncation).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# Primitive polynomial taps (1-indexed exponents of the feedback polynomial)
# for register lengths 2..16, from the standard Fibonacci-form tables (Xilinx
# XAPP052 / Golomb).  mls_sequence applies them as the XOR mask of a *Galois*
# LFSR: that realises the reciprocal polynomial x^m·p(1/x), which is primitive
# iff p is, so the register still cycles through all 2**m − 1 nonzero states —
# the emitted m-sequence is the time-reverse of the Fibonacci one, and every
# m-sequence property (period, balance, ideal autocorrelation) is preserved.
_PRIMITIVE_TAPS: dict[int, tuple[int, ...]] = {
    2: (2, 1),
    3: (3, 2),
    4: (4, 3),
    5: (5, 3),
    6: (6, 5),
    7: (7, 6),
    8: (8, 6, 5, 4),
    9: (9, 5),
    10: (10, 7),
    11: (11, 9),
    12: (12, 11, 10, 4),
    13: (13, 12, 11, 8),
    14: (14, 13, 12, 2),
    15: (15, 14),
    16: (16, 15, 13, 4),
}


def mls_sequence(m: int, *, init_state: int = 1) -> np.ndarray:
    """Return one full period (2**m - 1) of a maximum-length ±1 sequence.

    Galois-form LFSR: on emitting a 1, the polynomial mask (primitive taps)
    is XORed into the shifted state — cycles through all 2**m − 1 nonzero
    states for a primitive polynomial regardless of the seed.
    """
    if m not in _PRIMITIVE_TAPS:
        raise ValueError(f"no primitive taps tabulated for m={m}")
    if not 0 < init_state < 2**m:
        raise ValueError("init_state must be a nonzero m-bit value")
    mask = 0
    for t in _PRIMITIVE_TAPS[m]:
        mask |= 1 << (t - 1)
    state = init_state
    out = np.empty(2**m - 1, dtype=np.int8)
    for i in range(out.shape[0]):
        lsb = state & 1
        out[i] = 1 if lsb else -1
        state >>= 1
        if lsb:
            state ^= mask
    return out


def make_mask(
    n_nodes: int,
    *,
    levels: tuple[float, float] = (0.0, 1.0),
    seed: int = 1,
    dtype=jnp.float32,
) -> jnp.ndarray:
    """Binary MLS mask of length ``n_nodes`` with values ``levels``.

    ``levels = (lo, hi)`` maps the MLS -1 -> lo and +1 -> hi.  The default
    keeps the masked optical signal non-negative (an optical intensity cannot
    go below zero); a photonic implementation realises the two levels with
    two drive amplitudes of the input MR modulator.  Electronic devices may
    use bipolar levels, e.g. ``(-1.0, 1.0)`` for 'Electronic (MG)'.  ``seed``
    rotates the MLS, selecting a different (but still MLS-autocorrelation)
    mask.
    """
    if n_nodes < 1:
        raise ValueError("n_nodes must be >= 1")
    m = 2
    while 2**m - 1 < n_nodes:
        m += 1
    seq = mls_sequence(m, init_state=(seed % (2**m - 1)) + 1)
    seq = np.roll(seq, seed // (2**m - 1))[:n_nodes]
    lo, hi = levels
    vals = np.where(seq > 0, hi, lo).astype(np.float32)
    return jnp.asarray(vals, dtype=dtype)


def sample_and_hold(series: jnp.ndarray) -> jnp.ndarray:
    """Identity for discrete-time tasks: each sample j[k] is held for one τ.

    Kept as an explicit (documented) stage so the pipeline mirrors the paper's
    Fig. 2(a); continuous-time front-ends would resample here.
    """
    return jnp.asarray(series)


def masked_input(j: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """u[..., k, i] = j[..., k] * m[i]  (paper Eq. (2)).

    ``j`` has shape [..., K] (K samples); result [..., K, N].
    """
    return j[..., :, None] * mask[None, :]
