"""High-level DFRC accelerator API (paper Fig. 4: input/reservoir/output layers).

Ties together masking (input layer), DFR state generation (reservoir layer)
and readout training (output layer) behind a scikit-style fit/predict object,
with the physical-side power/timing models attached.

Typical use (examples/quickstart.py):

    cfg = DFRCConfig(model=SiliconMR(), n_nodes=900)
    acc = DFRCAccelerator(cfg)
    acc.fit(ds.inputs_train, ds.targets_train)
    err = nrmse(ds.targets_test, acc.predict(ds.inputs_test))
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from .masking import make_mask, sample_and_hold
from .metrics import nrmse, ser
from .nonlinear import NLModel, SiliconMR
from .readout import Readout, fit_readout
from .reservoir import generate_states
from .tasks import quantize_symbols


@dataclasses.dataclass(frozen=True)
class DFRCConfig:
    model: NLModel = dataclasses.field(default_factory=SiliconMR)
    n_nodes: int = 900
    mask_levels: tuple[float, float] = (0.0, 1.0)
    mask_seed: int = 1
    input_gain: float = 1.0
    normalize_input: bool = True   # affine-map train inputs to [0, 1]
    washout: int = 50              # periods dropped before readout training
    ridge_l2: float | tuple = 1e-6
    # Digitiser noise (paper Fig. 4: PD -> digitizer -> sample memory): RMS
    # relative to the state std, injected into the *training* states.  This
    # is the physical regulariser — without it the near-singular directions
    # of the state matrix pick up exploding readout weights (readout.py).
    # 0.003 ~ an 8-bit effective ADC.
    state_noise_rel: float = 0.003
    noise_seed: int = 0
    readout_method: str = "ridge"  # "ridge" | "pinv" (paper's Moore-Penrose)
    state_method: str = "fast"     # "fast" | "ref" | "kernel"
    quantize: bool = False         # snap predictions to 4-PAM symbols


class DFRCAccelerator:
    """One physical DFRC accelerator instance."""

    def __init__(self, config: DFRCConfig):
        self.config = config
        self.mask = make_mask(
            config.n_nodes, levels=config.mask_levels, seed=config.mask_seed
        )
        self.readout: Readout | None = None
        self._in_shift = 0.0
        self._in_scale = 1.0
        self._s_carry = None  # reservoir state at the end of the last series

    # -- input layer ----------------------------------------------------------
    def _drive(self, inputs) -> jnp.ndarray:
        j = sample_and_hold(jnp.asarray(inputs, dtype=jnp.float32))
        j = (j - self._in_shift) * self._in_scale * self.config.input_gain
        return j

    # -- reservoir layer --------------------------------------------------------
    def states(self, inputs, *, carry: bool = True) -> jnp.ndarray:
        """DFR states [K, N] for an input series [K].

        ``carry=True`` continues from wherever the reservoir last stopped
        (the physical loop never resets between train and test phases).
        """
        j = self._drive(inputs)
        s0 = self._s_carry if carry else None
        states = generate_states(
            self.config.model, j, self.mask, s0=s0, method=self.config.state_method
        )
        if carry:
            self._s_carry = states[-1]
        return states

    # -- output layer -----------------------------------------------------------
    def fit(self, inputs, targets) -> "DFRCAccelerator":
        cfg = self.config
        if cfg.normalize_input:
            arr = np.asarray(inputs, dtype=np.float64)
            self._in_shift = float(arr.min())
            self._in_scale = float(1.0 / (arr.max() - arr.min() + 1e-12))
        self._s_carry = None
        st = self.states(inputs)
        w = cfg.washout
        st_train = np.asarray(st[w:])
        if cfg.state_noise_rel:
            rng = np.random.default_rng(cfg.noise_seed)
            sigma = cfg.state_noise_rel * float(st_train.std())
            st_train = st_train + rng.normal(0.0, sigma, st_train.shape)
        self.readout = fit_readout(
            jnp.asarray(st_train, jnp.float32), np.asarray(targets)[w:],
            l2=cfg.ridge_l2, method=cfg.readout_method,
        )
        return self

    def predict(self, inputs) -> np.ndarray:
        if self.readout is None:
            raise RuntimeError("fit() before predict()")
        st = self.states(inputs)
        y = np.asarray(self.readout(st))
        return quantize_symbols(y) if self.config.quantize else y

    # -- evaluation -------------------------------------------------------------
    def evaluate_nrmse(self, inputs, targets) -> float:
        return nrmse(targets, self.predict(inputs))

    def evaluate_ser(self, inputs, targets) -> float:
        return ser(np.asarray(targets), quantize_symbols(self.predict(inputs)))
