"""ReservoirMixer: the paper's DFRC dynamics as an LM sequence mixer.

The paper's accelerator processes a scalar time series through one MR node
+ delay loop.  As a framework feature we lift it into the LM stack:

  x [B, S, d]  --fixed random w_in-->  R scalar drive series  (R "wavelengths")
               --SiliconMR DFR-->      R×N virtual-node states per step
               --trained readout-->    y [B, S, d]

R parallel reservoirs model WDM multiplexing — R wavelength channels sharing
one physical MR+waveguide (each λ sees independent dynamics; the natural
chip-scale scaling axis, DESIGN.md §2).  Following the paper's training
protocol the *reservoir itself is fixed*: w_in is a non-trainable random
projection (stop-gradiented buffer) and only the readout is learned.  The
mixer is causal and O(S·N·R) — linear in sequence length, which is what
makes the ``reservoir_lm`` config runnable at ``long_500k``.

Decode carries (s_prev [B,R,N], s_last [B,R]) — O(N·R) per token.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .masking import make_mask
from .nonlinear import SiliconMR


def reservoir_defs(cfg) -> dict:
    d, n, r = cfg.d_model, cfg.reservoir_nodes, _n_channels(cfg)

    def w_in_init(k, shape):
        return jax.random.normal(k, shape, jnp.float32) / math.sqrt(shape[0])

    return {
        "w_in": ((d, r), ("embed", None), w_in_init),         # fixed (not trained)
        "readout": ((r * n, d), (None, "embed"), "zeros"),    # the trained W_out
        "readout_bias": ((d,), ("embed",), "zeros"),
    }


def _n_channels(cfg) -> int:
    return max(1, cfg.d_model // cfg.reservoir_nodes)


def _model(cfg) -> SiliconMR:
    return SiliconMR(
        theta_ps=50.0,
        tau_ph_ps=50.0 / cfg.reservoir_alpha_ratio,
        gamma=cfg.reservoir_gamma,
    )


def apply_reservoir(cfg, p, x, *, cache=None):
    """x [B,S,d] -> (y [B,S,d], new_cache).  cache=(s_prev [B,R,N], s_last [B,R])."""
    dt = x.dtype
    n, r = cfg.reservoir_nodes, _n_channels(cfg)
    b, s, _ = x.shape
    mdl = _model(cfg)
    mask = make_mask(n, seed=1).astype(jnp.float32)

    # Fixed random drive; squash to the optical intensity range [0, 1].
    w_in = jax.lax.stop_gradient(p["w_in"])
    j = jax.nn.sigmoid((x.astype(jnp.float32) @ w_in))        # [B,S,R]

    if cache is None:
        s_prev = jnp.zeros((b, r, n), jnp.float32)
        s_last = jnp.zeros((b, r), jnp.float32)
    else:
        s_prev, s_last = cache

    def period(carry, j_t):
        sp, sl = carry  # [B,R,N], [B,R]
        u_t = j_t[..., None] * mask                           # [B,R,N]
        s_new = mdl.period_update(u_t, sp, sl)
        return (s_new, s_new[..., -1]), s_new

    (s_prev, s_last), states = jax.lax.scan(period, (s_prev, s_last), jnp.moveaxis(j, 1, 0))
    states = jnp.moveaxis(states, 0, 1).reshape(b, s, r * n)  # [B,S,R·N]

    y = (states.astype(dt) @ p["readout"].astype(dt)) + p["readout_bias"].astype(dt)
    return y, (s_prev, s_last)
