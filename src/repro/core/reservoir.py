"""Delayed-feedback reservoir (DFR) state generation.

Produces the N virtual-node states for every input period (paper Fig. 2(b),
Eq. (1-2)).  Three interchangeable execution paths:

* ``method="ref"``    — nested ``lax.scan`` over periods × nodes: the node
  chain is evaluated strictly sequentially, exactly as the physical device
  evolves in time.  This is the oracle every other path is tested against.
* ``method="fast"``   — ``lax.scan`` over periods, O(log N) associative-scan
  parallelism inside each period (see nonlinear.py docstring).  Pure jnp; the
  default on CPU and the building block the LM-side ReservoirMixer uses.
* ``method="kernel"`` — the Pallas TPU kernel (kernels/dfr_scan), which fuses
  masking + candidate computation + the in-period scan, tiled in VMEM.

All paths take the *unmasked* sample series ``j`` [..., K] plus the mask [N]
and return states [..., K, N].
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .masking import masked_input
from .nonlinear import NLModel


def init_state(model: NLModel, batch_shape: tuple[int, ...], n_nodes: int, dtype=jnp.float32):
    """Zero initial reservoir state (dark waveguide / discharged node)."""
    del model
    return jnp.zeros((*batch_shape, n_nodes), dtype=dtype)


def _canon(j: jnp.ndarray) -> tuple[jnp.ndarray, bool]:
    """Canonicalise j to [B, K]; report whether a batch dim was added."""
    j = jnp.asarray(j)
    if j.ndim == 1:
        return j[None, :], True
    if j.ndim == 2:
        return j, False
    raise ValueError(f"j must be [K] or [B, K], got shape {j.shape}")


@partial(jax.jit, static_argnames=("model",))
def _states_ref(model: NLModel, u: jnp.ndarray, s0: jnp.ndarray) -> jnp.ndarray:
    """u: [B, K, N], s0: [B, N] -> [B, K, N].  Sequential oracle."""

    def period(carry, u_k):
        s_prev, s_last = carry  # [B, N], [B]

        def node(s_prev_node, xs):
            u_i, s_tau_i = xs  # [B], [B]
            s_i = model.node_update(u_i, s_tau_i, s_prev_node)
            return s_i, s_i

        xs = (jnp.moveaxis(u_k, -1, 0), jnp.moveaxis(s_prev, -1, 0))  # [N, B]
        s_last_new, s_nodes = jax.lax.scan(node, s_last, xs)
        s_new = jnp.moveaxis(s_nodes, 0, -1)  # [B, N]
        return (s_new, s_last_new), s_new

    (_, _), states = jax.lax.scan(period, (s0, s0[..., -1]), jnp.moveaxis(u, 1, 0))
    return jnp.moveaxis(states, 0, 1)


@partial(jax.jit, static_argnames=("model",))
def _states_fast(model: NLModel, u: jnp.ndarray, s0: jnp.ndarray) -> jnp.ndarray:
    """u: [B, K, N], s0: [B, N] -> [B, K, N].  Parallel-in-period."""

    def period(carry, u_k):
        s_prev, s_last = carry
        s_new = model.period_update(u_k, s_prev, s_last)
        return (s_new, s_new[..., -1]), s_new

    (_, _), states = jax.lax.scan(period, (s0, s0[..., -1]), jnp.moveaxis(u, 1, 0))
    return jnp.moveaxis(states, 0, 1)


# Swept-parameter variants (DESIGN.md §14): identical scans, but the model's
# operating point arrives as a TRACED ``p`` pytree (leaves scalar or [B] —
# one device grid point per batch lane) through the model's ``*_p`` method
# contract.  Parameters are operands, so a design-space sweep over them
# never retraces; the model itself stays the hashable jit static.

@partial(jax.jit, static_argnames=("model",))
def _states_ref_p(model, p, u: jnp.ndarray, s0: jnp.ndarray) -> jnp.ndarray:
    """Sequential oracle at traced per-lane device parameters ``p``."""

    def period(carry, u_k):
        s_prev, s_last = carry

        def node(s_prev_node, xs):
            u_i, s_tau_i = xs
            s_i = model.node_update_p(p, u_i, s_tau_i, s_prev_node)
            return s_i, s_i

        xs = (jnp.moveaxis(u_k, -1, 0), jnp.moveaxis(s_prev, -1, 0))
        s_last_new, s_nodes = jax.lax.scan(node, s_last, xs)
        s_new = jnp.moveaxis(s_nodes, 0, -1)
        return (s_new, s_last_new), s_new

    (_, _), states = jax.lax.scan(period, (s0, s0[..., -1]), jnp.moveaxis(u, 1, 0))
    return jnp.moveaxis(states, 0, 1)


@partial(jax.jit, static_argnames=("model",))
def _states_fast_p(model, p, u: jnp.ndarray, s0: jnp.ndarray) -> jnp.ndarray:
    """Period-scan path at traced per-lane device parameters ``p``."""

    def period(carry, u_k):
        s_prev, s_last = carry
        s_new = model.period_update_p(p, u_k, s_prev, s_last)
        return (s_new, s_new[..., -1]), s_new

    (_, _), states = jax.lax.scan(period, (s0, s0[..., -1]), jnp.moveaxis(u, 1, 0))
    return jnp.moveaxis(states, 0, 1)


def generate_states(
    model: NLModel,
    j: jnp.ndarray,
    mask: jnp.ndarray,
    *,
    s0: jnp.ndarray | None = None,
    method: str = "fast",
    block_s: int | None = None,
    return_final: bool = False,
    state_dtype=None,
    dev_params=None,
):
    """DFR states for sample series ``j`` [..., K] -> [..., K, N].

    ``method``: "fast" (default), "ref" (sequential oracle) or "kernel"
    (Pallas; interpret-mode on CPU).  ``block_s`` sizes the kernel's sublane
    tile (None = smallest of {1, 2, 4, 8} covering the batch — see
    kernels/dfr_scan/ops.py); ignored by the jnp paths.

    ``dev_params`` threads a *traced* device operating-point pytree (e.g.
    ``devices.cmt.CMTSweepParams``; leaves scalar or [B], one grid point per
    batch lane) into the model's ``node_update_p``/``period_update_p``
    contract — how ``devices/sweep.py`` runs a whole (detuning × loss ×
    power) map as one program.  jnp paths only; the Pallas kernel keeps the
    static-model contract (per-lane parameter tiles are a ROADMAP follow-on).

    ``return_final=True`` additionally returns the final reservoir state
    [..., N] — feed it back as ``s0`` to resume the scan (train -> test
    continuation; chunked streaming over K).  On the kernel path this is the
    kernel's explicit VMEM-carry output rather than a slice of the state
    tensor, so a chunked caller never has to keep the full [..., K, N] block
    alive just to continue from its last period.

    ``state_dtype`` downcasts only the emitted state tensor (e.g. bf16 chunks
    for the streaming paths, halving chunk HBM traffic — DESIGN.md §9); the
    final-state carry and all in-scan compute stay in the input dtype, so
    chunked resume is unaffected by the chunk dtype.
    """
    jb, squeeze = _canon(j)
    n_nodes = int(mask.shape[-1])
    if s0 is None:
        s0b = init_state(model, (jb.shape[0],), n_nodes, dtype=jb.dtype)
    else:
        s0b = jnp.asarray(s0)
        if s0b.ndim == 1:
            s0b = jnp.broadcast_to(s0b[None], (jb.shape[0], n_nodes))

    if method == "kernel":
        if dev_params is not None:
            raise NotImplementedError(
                "dev_params (traced per-lane device parameters) are not "
                "supported on the Pallas kernel path; sweep with "
                "method='fast' or 'ref' (ROADMAP: swept-params kernel tiles)")
        from repro.kernels.dfr_scan import ops as dfr_ops

        out = dfr_ops.dfr_scan(model, jb, mask, s0b, block_s=block_s,
                               return_final=return_final,
                               out_dtype=state_dtype)
        states, s_final = out if return_final else (out, None)
    else:
        u = masked_input(jb, mask)
        if method == "ref":
            states = (_states_ref(model, u, s0b) if dev_params is None
                      else _states_ref_p(model, dev_params, u, s0b))
        elif method == "fast":
            states = (_states_fast(model, u, s0b) if dev_params is None
                      else _states_fast_p(model, dev_params, u, s0b))
        else:
            raise ValueError(f"unknown method {method!r}")
        s_final = states[:, -1, :] if return_final else None
        if state_dtype is not None:
            states = states.astype(state_dtype)
    if squeeze:
        return (states[0], s_final[0]) if return_final else states[0]
    return (states, s_final) if return_final else states


def generate_channel_states(
    model: NLModel,
    j: jnp.ndarray,      # [R, K] — one series per wavelength channel
    masks: jnp.ndarray,  # [R, N] — one MLS mask per channel
    *,
    s0: jnp.ndarray | None = None,
    method: str = "fast",
    block_s: int | None = None,
    return_final: bool = False,
    state_dtype=None,
):
    """WDM ensemble states: per-channel masks over per-channel inputs.

    ``j`` [R, K] with ``masks`` [R, N] -> states [R, K, N]; the software
    analogue of R wavelength channels sharing one physical ring + delay
    loop (DESIGN.md §2/§9).  Same knob semantics as ``generate_states``:
    ``s0`` [R, N] resumes each channel's scan, ``return_final=True`` adds
    the [R, N] carry (the kernel's VMEM-flush output — a chunked caller
    never keeps the full [R, K, N] block alive), ``state_dtype`` downcasts
    only the emitted state tensor.

    ``method="kernel"`` rides the Pallas scan's per-lane mask path: each
    channel is a batch lane with its own [N] mask tile resident in VMEM, so
    all R channels run as ONE kernel launch.  The jnp paths vmap over
    channels.
    """
    j = jnp.asarray(j, jnp.float32)
    masks = jnp.asarray(masks, j.dtype)
    if j.ndim != 2 or masks.ndim != 2 or j.shape[0] != masks.shape[0]:
        raise ValueError(f"channels mismatch: j {j.shape} vs masks {masks.shape}")
    if s0 is None:
        s0 = jnp.zeros((j.shape[0], masks.shape[1]), j.dtype)
    s0 = jnp.asarray(s0, j.dtype)

    if method == "kernel":
        from repro.kernels.dfr_scan import ops as dfr_ops

        return dfr_ops.dfr_scan(model, j, masks, s0, block_s=block_s,
                                return_final=return_final,
                                out_dtype=state_dtype)

    def one(jr, mr, s0r):
        return generate_states(model, jr, mr, s0=s0r, method=method,
                               return_final=True, state_dtype=state_dtype)

    states, s_final = jax.vmap(one)(j, masks, s0)
    return (states, s_final) if return_final else states
