"""Logical-axis -> mesh-axis sharding rules (DESIGN.md §5).

Strategies (per-arch, chosen by divisibility — recorded in each config):

  fsdp_tp     hybrid ZeRO-3 × tensor parallel: "embed"-class dims shard over
              the data axis (params gathered on use), "heads"/"mlp"/"vocab"
              dims over the model axis (Megatron TP).  Any rule whose mesh
              axis does not divide the dim falls back to replication
              (e.g. 8 kv heads on a 16-way model axis).
  fsdp        as fsdp_tp, plus: when TP found nothing to shard on the model
              axis, the largest eligible dim also shards over "model"
              (full ZeRO-3 over data×model) — used by starcoder2 (24 H) and
              xlstm (4 H), whose head counts don't divide 16.
  fsdp_tp_ep  fsdp_tp with the "expert" axis on "model" (expert parallelism);
              same table — listed separately for config clarity.

Batch shards over ("pod", "data") everywhere; long_500k (batch 1) shards the
KV-cache sequence axis over "data" instead (sequence parallelism for
decode).
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import get_abstract_mesh
from repro.models import param_logical_axes

# Candidate mesh axes per logical axis, in preference order.
_TABLE = {
    "vocab": ("model",),
    "embed": ("data",),
    "heads": ("model",),
    "kv": ("model",),
    "mlp": ("model",),
    "expert": ("model",),
    "ctx": ("data",),
    "hd": (),
    "layers": (),
    "nodes": (),
    None: (),
}

# Logical axes eligible for the pure-FSDP fallback shard over "model".
_FSDP_FALLBACK = ("embed", "vocab", "mlp", "ctx")


def _axis_size(mesh, name) -> int:
    return mesh.shape[name] if name in mesh.shape else 0


def spec_for(axes: tuple, shape: tuple, mesh, strategy: str) -> P:
    """PartitionSpec for one param leaf given its logical axes and shape."""
    used: set[str] = set()
    entries: list = []
    for dim, logical in zip(shape, axes):
        chosen = None
        for cand in _TABLE.get(logical, ()):
            size = _axis_size(mesh, cand)
            if size and cand not in used and dim % size == 0:
                chosen = cand
                used.add(cand)
                break
        entries.append(chosen)

    if strategy in ("fsdp", "zero3") and "model" not in used:
        # Full ZeRO-3: fold "model" into the largest eligible dim.
        best = None
        for i, (dim, logical) in enumerate(zip(shape, axes)):
            if logical in _FSDP_FALLBACK and dim % _axis_size(mesh, "model") == 0:
                if best is None or dim > shape[best]:
                    best = i
        if best is not None:
            prev = entries[best]
            entries[best] = (
                (prev, "model") if isinstance(prev, str) else "model"
            )
    return P(*entries)


def param_pspecs(cfg, mesh):
    """PartitionSpec pytree matching init_params(cfg, ...) structure."""
    axes_tree = param_logical_axes(cfg)
    strategy = cfg.strategy

    def leaf_spec(axes, shape):
        return spec_for(axes, shape, mesh, strategy)

    # axes_tree leaves are tuples; we need shapes -> use eval_shape of init.
    from repro.models import init_params

    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))

    def walk(ax, sh):
        if isinstance(ax, tuple) and not isinstance(sh, tuple):
            # leaf: ax is the axes tuple, sh a ShapeDtypeStruct
            return leaf_spec(ax, sh.shape)
        if isinstance(ax, dict):
            return {k: walk(ax[k], sh[k]) for k in ax}
        if isinstance(ax, tuple):
            return tuple(walk(a, s) for a, s in zip(ax, sh))
        raise TypeError(type(ax))

    return walk(axes_tree, shapes)


def batch_axes(mesh, *, strategy: str = "fsdp_tp", batch: int | None = None) -> tuple:
    """Mesh axes the batch dim shards over.

    zero3 spreads the batch over every axis that divides it (the model axis
    carries data parallelism instead of TP — per-token activation
    all-reduces disappear in exchange for per-microbatch param gathers).
    """
    cands = ("pod", "data", "model") if strategy == "zero3" else ("pod", "data")
    axes: list[str] = []
    size = 1
    for a in cands:
        if a not in mesh.shape:
            continue
        if batch is not None and batch % (size * mesh.shape[a]):
            continue
        axes.append(a)
        size *= mesh.shape[a]
    return tuple(axes)


def batch_pspec(mesh, rank: int = 2, *, strategy: str = "fsdp_tp", batch: int | None = None) -> P:
    return P(batch_axes(mesh, strategy=strategy, batch=batch), *([None] * (rank - 1)))


def data_pspecs(cfg, mesh, specs: dict) -> dict:
    """Shardings for a train/prefill input-spec dict (tokens/labels/context)."""
    out = {}
    for k, v in specs.items():
        if k == "cache":
            out[k] = cache_pspecs(cfg, mesh, v)
        else:
            out[k] = batch_pspec(mesh, rank=len(v.shape),
                                 strategy=cfg.strategy, batch=v.shape[0])
    return out


def cache_pspecs(cfg, mesh, cache_shapes):
    """Sharding specs mirroring init_cache structure.

    Batch shards over ("pod","data") when it divides; otherwise (long_500k,
    batch 1) the attention-cache *sequence* axis shards over "data" and
    recurrent-state inner dims shard over "model" where divisible.
    """
    b_axes = batch_axes(mesh)
    b_size = 1
    for a in b_axes:
        b_size *= mesh.shape[a]
    kinds = [blk.mixer for blk in cfg.unit]

    # cache_shapes: {"pos": ..., "units": tuple per position}
    batch = None
    for leaf in jax.tree.leaves(cache_shapes["units"]):
        batch = leaf.shape[1]
        break
    shard_batch = batch is not None and batch % b_size == 0

    def b_ax():
        return b_axes if shard_batch else None

    model = _axis_size(mesh, "model")
    data = _axis_size(mesh, "data")

    def seq_ax(s):
        return "data" if (not shard_batch and data and s % data == 0) else None

    def inner_ax(d):
        return "model" if (model and d % model == 0) else None

    units_specs = []
    for kind, unit_cache in zip(kinds, cache_shapes["units"]):
        if kind in ("attn", "cross_attn"):
            k_sh = unit_cache[0].shape  # [U, B, S, KV, hd]
            kv_ax = "model" if (model and k_sh[3] % model == 0) else None
            # Sequence axis takes whatever is left: "model" when kv heads
            # don't divide it (kv replication would hold the full cache per
            # device — 38 GiB at granite decode_32k), and "data" too when
            # the batch can't shard (long_500k, batch 1).
            s_axes = []
            if not shard_batch:
                s_axes.append("data")
            if kv_ax is None and model:
                s_axes.append("model")
            s_div = 1
            for a in s_axes:
                s_div *= mesh.shape[a]
            s_entry = tuple(s_axes) if (s_axes and k_sh[2] % s_div == 0) else None
            spec = P(None, b_ax(), s_entry, kv_ax, None)
            units_specs.append((spec, spec))
        elif kind == "mamba":
            conv_sh, h_sh = unit_cache[0].shape, unit_cache[1].shape
            units_specs.append(
                (
                    P(None, b_ax(), None, inner_ax(conv_sh[3])),
                    P(None, b_ax(), inner_ax(h_sh[2]), None),
                )
            )
        elif kind == "mlstm":
            conv_sh, c_sh, n_sh, m_sh = (u.shape for u in unit_cache)
            units_specs.append(
                (
                    P(None, b_ax(), None, inner_ax(conv_sh[3])),
                    P(None, b_ax(), None, inner_ax(c_sh[3]), None),
                    P(None, b_ax(), None, inner_ax(n_sh[3])),
                    P(None, b_ax(), None),
                )
            )
        elif kind == "slstm":
            units_specs.append(
                (
                    P(None, b_ax(), inner_ax(unit_cache[0].shape[2])),
                    P(None, b_ax(), inner_ax(unit_cache[1].shape[2])),
                    P(None, b_ax(), None),
                    P(None, b_ax(), inner_ax(unit_cache[3].shape[2])),
                )
            )
        elif kind == "reservoir":
            units_specs.append(
                (P(None, b_ax(), None, None), P(None, b_ax(), None))
            )
        else:
            raise ValueError(kind)
    return {"pos": P(), "units": tuple(units_specs)}


def named(mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def maybe_shard(x, *spec_entries):
    """with_sharding_constraint that degrades to a no-op when no mesh is
    active or the named axes aren't in the mesh (smoke tests, single device).

    Entries may be axis names, tuples of axis names, or None; names missing
    from the active mesh are dropped from the constraint.
    """
    mesh = get_abstract_mesh()
    if mesh is None:
        return x
    names = set(mesh.axis_names)

    def keep(e):
        if e is None:
            return None
        if isinstance(e, tuple):
            kept = tuple(a for a in e if a in names)
            return kept if kept else None
        return e if e in names else None

    entries = [keep(e) for e in spec_entries]
    entries += [None] * (x.ndim - len(entries))
    return jax.lax.with_sharding_constraint(x, P(*entries))
