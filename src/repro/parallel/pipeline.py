"""GPipe-style pipeline parallelism over shard_map + collective_permute.

An alternative distribution strategy for depth-dominated models: layers are
split into S contiguous stages laid out along a mesh axis; M microbatches
stream through, each device running its stage function and handing
activations to the next stage with ``jax.lax.ppermute``.

Schedule: the classic GPipe loop of T = M + S − 1 ticks.  At tick t, stage s
processes microbatch (t − s) when 0 ≤ t − s < M.  Bubble fraction
(S − 1)/T; utilisation is driven by M/S as usual.  All stages execute the
same program (SPMD), with ``jnp.where`` masking the warm-up/drain ticks.

Used by tests (tests/test_pipeline.py validates vs the unpipelined
reference) and available as strategy="pp" building block; the default
dry-run strategies are FSDP×TP (DESIGN.md §5).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def pipeline_apply(
    stage_fn,           # (stage_params, x [mb, ...]) -> [mb, ...]
    stacked_params,     # pytree, leaves [S, ...] — one slice per stage
    x,                  # [M, mb, ...] microbatched input
    *,
    mesh,
    axis: str = "stage",
):
    """Run x through S pipeline stages with a GPipe schedule.

    Returns [M, mb, ...] outputs (equal to folding stage_fn over stages for
    each microbatch).
    """
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]

    def per_stage(params_slice, x_all):
        # params_slice: this stage's params (leaves [1, ...] -> squeeze);
        # x_all: [M, mb, ...] full input (only stage 0 actually consumes it).
        params_local = jax.tree.map(lambda a: a[0], params_slice)
        stage_id = jax.lax.axis_index(axis)

        mb_shape = x_all.shape[1:]
        buf = jnp.zeros(mb_shape, x_all.dtype)          # current activation
        outputs = jnp.zeros_like(x_all)                 # stage S-1 collects

        def tick(t, carry):
            buf, outputs = carry
            micro_idx = t - stage_id
            active = (micro_idx >= 0) & (micro_idx < n_micro)
            # Stage 0 ingests microbatch t; others use the permuted buffer.
            feed = jnp.where(
                stage_id == 0,
                x_all[jnp.clip(t, 0, n_micro - 1)],
                buf,
            )
            y = stage_fn(params_local, feed)
            y = jnp.where(active, y, jnp.zeros_like(y))
            # Last stage writes its finished microbatch to the output slot.
            write_idx = jnp.clip(micro_idx, 0, n_micro - 1)
            is_last = stage_id == n_stages - 1
            outputs = jax.lax.cond(
                active & is_last,
                lambda o: o.at[write_idx].set(y),
                lambda o: o,
                outputs,
            )
            # Hand activations forward (ring; the wrap-around link is unused
            # because stage 0 always feeds from x_all).
            buf = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)]
            )
            return buf, outputs

        _, outputs = jax.lax.fori_loop(0, n_micro + n_stages - 1, tick, (buf, outputs))
        # Only stage S-1 holds real outputs; broadcast them to all stages.
        outputs = jax.lax.psum(
            jnp.where(stage_id == n_stages - 1, outputs, jnp.zeros_like(outputs)), axis
        )
        return outputs

    spec_params = jax.tree.map(lambda _: P(axis), stacked_params)
    fn = shard_map(
        per_stage,
        mesh=mesh,
        in_specs=(spec_params, P()),
        out_specs=P(),
        check_rep=False,
    )
    return fn(stacked_params, x)


def make_stage_mesh(n_stages: int):
    devs = jax.devices()[:n_stages]
    import numpy as np

    return jax.sharding.Mesh(np.array(devs), ("stage",))
