"""Distribution: sharding rules, pipeline parallelism, mesh helpers."""

from . import pipeline, sharding

__all__ = ["pipeline", "sharding"]
