"""jamba-v0.1-52b — AI21 Jamba [arXiv:2403.19887; hf].

Hybrid Mamba+attention MoE: 32 layers as 4 Jamba blocks of 8 (attention at
in-block index 4 — the 1:7 attn:mamba ratio), MoE (16 experts, top-2,
expert d_ff 14336) every second layer, d_model 4096, 32 heads (GQA kv=8),
vocab 65536.  Mamba: d_state 16, d_conv 4, expand 2.
"""

from repro.models import BlockSpec, ModelConfig

_UNIT = tuple(
    BlockSpec(
        mixer=("attn" if i == 4 else "mamba"),
        mlp=("moe" if i % 2 == 1 else "dense"),
    )
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    max_seq_len=262144,
    unit=_UNIT,
    n_experts=16,
    top_k=2,
    moe_d_ff=14336,
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    strategy="fsdp_tp_ep",
    microbatches=8,
)
