"""qwen3-moe-30b-a3b — Qwen3 MoE 30B (3B active) [hf:Qwen/Qwen3-30B-A3B; hf].

MoE: 48L, d_model 2048, 32 heads (GQA kv=4, head_dim 128), qk_norm,
128 experts top-8, expert d_ff 768, vocab 151936.  Expert parallelism over
the model axis (8 experts / device at TP=16).
"""

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=0,
    vocab_size=151936,
    max_seq_len=40960,
    qk_norm=True,
    rope_theta=1_000_000.0,
    n_experts=128,
    top_k=8,
    moe_d_ff=768,
    strategy="fsdp_tp_ep",
    microbatches=8,
)
