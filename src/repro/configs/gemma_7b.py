"""gemma-7b — Google Gemma [arXiv:2403.08295; hf].

Dense: 28L, d_model 3072, 16 MHA heads (kv=16), head_dim 256, d_ff 24576,
GeGLU MLP, vocab 256000, attention logit softcap.
"""

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    max_seq_len=8192,
    mlp_act="gelu",
    attn_logit_softcap=50.0,
    strategy="fsdp_tp",
    microbatches=8,
)
