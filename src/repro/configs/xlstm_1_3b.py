"""xlstm-1.3b — xLSTM [arXiv:2405.04517; unverified].

Recurrent xLSTM[7:1]: 48 blocks = 6 units of (7× mLSTM + 1× sLSTM),
d_model 2048, 4 heads, no separate FFN (d_ff = 0; blocks carry their own
projections: mLSTM pre-up-projects ×2, sLSTM post-up-projects ×4/3),
vocab 50304.  4 heads do not divide the model axis -> pure-FSDP strategy.
"""

from repro.models import BlockSpec, ModelConfig

_UNIT = tuple(BlockSpec("mlstm", "none") for _ in range(7)) + (BlockSpec("slstm", "none"),)

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    max_seq_len=524288,
    unit=_UNIT,
    mlstm_expand=2,
    strategy="fsdp",
    microbatches=4,
)
