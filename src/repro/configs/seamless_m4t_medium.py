"""seamless-m4t-medium — Meta SeamlessM4T medium [arXiv:2308.11596; hf].

Audio enc-dec: 12 encoder layers (bidirectional) over stub audio-frame
embeddings + 12 decoder layers, each with self-attention and cross-attention
(expressed as a 2-block unit, so n_layers = 24 block entries = 12 logical
decoder layers).  d_model 1024, 16 MHA heads, d_ff 4096, vocab 256206.
The speech frontend (conformer feature extractor) is a STUB per the
assignment: input_specs provides precomputed frame embeddings [B, 1024, d].
"""

from repro.models import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=24,  # 12 logical decoder layers × (self-attn + cross-attn)
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    max_seq_len=4096,
    unit=(
        BlockSpec("attn", "none"),
        BlockSpec("cross_attn", "dense"),
    ),
    n_encoder_layers=12,
    n_context_tokens=1024,
    strategy="fsdp_tp",
    microbatches=4,
)
