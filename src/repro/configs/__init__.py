"""Architecture / task config registry.

``get_config(arch)`` -> full ModelConfig exactly as assigned;
``smoke_config(arch)`` -> reduced same-family config for CPU smoke tests;
``input_specs(cfg, shape)`` -> ShapeDtypeStruct stand-ins for every model
input of an assignment shape (no device allocation — dry-run safe);
``dfrc_tasks()`` -> the paper's own accelerator configs per benchmark task.

Shapes (assignment):
  train_4k     seq 4096,   global_batch 256   (training)
  prefill_32k  seq 32768,  global_batch 32    (inference prefill)
  decode_32k   seq 32768,  global_batch 128   (one-token decode, full cache)
  long_500k    seq 524288, global_batch 1     (long-context decode)

``long_500k`` needs sub-quadratic sequence mixing -> only jamba / xlstm /
reservoir_lm run it (pure full-attention archs skip it; DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
import importlib

import jax
import jax.numpy as jnp

ARCHS = {
    "granite-8b": "granite_8b",
    "starcoder2-3b": "starcoder2_3b",
    "qwen3-32b": "qwen3_32b",
    "gemma-7b": "gemma_7b",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "xlstm-1.3b": "xlstm_1_3b",
    "reservoir_lm": "reservoir_lm",
}

SHAPES = {
    "train_4k": {"seq": 4096, "batch": 256, "kind": "train"},
    "prefill_32k": {"seq": 32768, "batch": 32, "kind": "prefill"},
    "decode_32k": {"seq": 32768, "batch": 128, "kind": "decode"},
    "long_500k": {"seq": 524288, "batch": 1, "kind": "decode"},
}

# Families whose sequence mixing is sub-quadratic end-to-end.
SUBQUADRATIC = {"hybrid", "ssm", "reservoir"}


def get_config(arch: str):
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    return mod.CONFIG


def list_archs(include_extras: bool = False) -> list[str]:
    names = list(ARCHS)
    return names if include_extras else [n for n in names if n != "reservoir_lm"]


def runnable_cells(arch: str) -> list[str]:
    """The assignment shapes this arch runs (long_500k only if sub-quadratic)."""
    cfg = get_config(arch)
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.family in SUBQUADRATIC:
        cells.append("long_500k")
    return cells


def smoke_config(arch: str):
    """Reduced same-family config: same unit pattern / block kinds, tiny dims."""
    cfg = get_config(arch)
    n_kv = 4 if cfg.n_kv_heads == cfg.n_heads else 2
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=len(cfg.unit),
        d_model=64,
        n_heads=4,
        n_kv_heads=n_kv,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        max_seq_len=128,
        n_experts=min(8, cfg.n_experts) if cfg.n_experts else 0,
        top_k=min(2, cfg.top_k) if cfg.top_k else 0,
        moe_d_ff=32 if cfg.n_experts else 0,
        # Dropless at smoke scale: with S ~ 10 tokens per group the assigned
        # capacity factor would drop tokens in forward but not in per-token
        # decode, breaking the decode-vs-forward consistency check.
        capacity_factor=8.0,
        n_encoder_layers=2 if cfg.n_encoder_layers else 0,
        n_context_tokens=8 if cfg.n_context_tokens else 0,
        d_context=0,
        reservoir_nodes=16,
        dtype="float32",
        remat="none",
        microbatches=1,
    )


# --------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no device allocation)
# --------------------------------------------------------------------------


def _context_spec(cfg, batch: int):
    if not cfg.n_context_tokens:
        return None
    return jax.ShapeDtypeStruct(
        (batch, cfg.n_context_tokens, cfg.d_context or cfg.d_model), jnp.float32
    )


def input_specs(cfg, shape: str) -> dict:
    """Stand-ins for every input of ``shape``.  Keys match the step fns:

      train:   {tokens, labels, context?}
      prefill: {tokens, context?}
      decode:  {tokens, cache}   (cache stands in at fill level seq_len)
    """
    info = SHAPES[shape]
    b, s = info["batch"], info["seq"]
    tok = jnp.int32
    if info["kind"] == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s), tok),
            "labels": jax.ShapeDtypeStruct((b, s), tok),
        }
        ctx = _context_spec(cfg, b)
        if ctx is not None:
            specs["context"] = ctx
        return specs
    if info["kind"] == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), tok)}
        ctx = _context_spec(cfg, b)
        if ctx is not None:
            specs["context"] = ctx
        return specs
    if info["kind"] == "decode":
        from repro.models import init_cache

        cache = jax.eval_shape(
            lambda: init_cache(cfg, b, s, context_len=cfg.n_context_tokens)
        )
        return {
            "tokens": jax.ShapeDtypeStruct((b, 1), tok),
            "cache": cache,
        }
    raise ValueError(shape)


# --------------------------------------------------------------------------
# The paper's own DFRC accelerator configs (per benchmark task)
# --------------------------------------------------------------------------


def dfrc_tasks():
    """Operating points per task — N per the paper's sensitivity analysis;
    device hyperparameters tuned on the training split (EXPERIMENTS.md)."""
    from repro.core import DFRCConfig, MZISine, MackeyGlass, SiliconMR

    def mk(model, n_nodes, **kw):
        lams = (1e-10, 1e-8, 1e-6, 1e-4, 1e-2)
        return DFRCConfig(model=model, n_nodes=n_nodes, washout=60, ridge_l2=lams, **kw)

    return {
        "narma10": {
            "Silicon MR": mk(SiliconMR(), 900),
            "All Optical (MZI)": mk(MZISine(), 400),
            "Electronic (MG)": mk(MackeyGlass(), 900, mask_levels=(-1.0, 1.0)),
        },
        "santa_fe": {
            "Silicon MR": mk(SiliconMR(), 40),
            "All Optical (MZI)": mk(MZISine(), 400),
            "Electronic (MG)": mk(MackeyGlass(), 400, mask_levels=(-1.0, 1.0)),
        },
        "channel_eq": {
            "Silicon MR": mk(SiliconMR(), 30, quantize=True),
            "All Optical (MZI)": mk(MZISine(), 400, quantize=True),
            "Electronic (MG)": mk(MackeyGlass(), 400, mask_levels=(-1.0, 1.0), quantize=True),
        },
    }
