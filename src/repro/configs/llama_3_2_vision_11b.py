"""llama-3.2-vision-11b — Meta Llama 3.2 Vision [hf:meta-llama/Llama-3.2-11B-Vision; unverified].

VLM: 40 text layers, d_model 4096, 32 heads (GQA kv=8), d_ff 14336, vocab
128256; gated cross-attention to image-patch embeddings every 5th layer
(unit = 4×self-attn + 1×cross-attn, 8 units).  Vision frontend is a STUB
per the assignment: input_specs provides precomputed patch embeddings
[B, 1600, d_model].
"""

from repro.models import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    max_seq_len=32768,
    rope_theta=500_000.0,
    unit=(
        BlockSpec("attn", "dense"),
        BlockSpec("attn", "dense"),
        BlockSpec("attn", "dense"),
        BlockSpec("attn", "dense"),
        BlockSpec("cross_attn", "dense"),
    ),
    n_context_tokens=1600,
    strategy="fsdp_tp",
    microbatches=8,
)
