"""qwen3-moe-235b-a22b — Qwen3 MoE 235B (22B active) [hf:Qwen/Qwen3-30B-A3B scaling; hf].

MoE: 94L, d_model 4096, 64 heads (GQA kv=4, head_dim 128), qk_norm,
128 experts top-8, expert d_ff 1536, vocab 151936.
"""

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=0,
    vocab_size=151936,
    max_seq_len=40960,
    qk_norm=True,
    rope_theta=1_000_000.0,
    n_experts=128,
    top_k=8,
    moe_d_ff=1536,
    strategy="fsdp_tp_ep",
    microbatches=16,
)
