"""granite-8b — IBM Granite 8B code model [arXiv:2405.04324; hf].

Dense llama-style: 36L, d_model 4096, 32 heads (GQA kv=8), d_ff 14336,
vocab 49152.  Default hybrid FSDP×TP sharding.
"""

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=49152,
    max_seq_len=32768,
    rope_theta=10_000_000.0,
    strategy="fsdp_tp",
    microbatches=8,
)
