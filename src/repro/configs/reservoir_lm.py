"""reservoir_lm — the paper's DFRC technique as a first-class LM feature.

A ~100M-param LM whose sequence mixer is the silicon-MR delayed-feedback
reservoir (core/layer.py): fixed photonic dynamics (3 WDM channels × 256
virtual nodes per layer), trained linear readout + gated MLP.  O(S) in
sequence length, so it also runs the long_500k shape.  Used by
examples/train_reservoir_lm.py as the end-to-end driver.
"""

from repro.models import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="reservoir_lm",
    family="reservoir",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=32000,
    max_seq_len=524288,
    unit=(BlockSpec("reservoir", "dense"),),
    reservoir_nodes=256,
    reservoir_gamma=0.9,
    strategy="fsdp",
    microbatches=4,
)
