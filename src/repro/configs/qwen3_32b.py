"""qwen3-32b — Qwen3 dense [hf:Qwen/Qwen3-8B family; hf].

Dense: 64L, d_model 5120, 64 heads (GQA kv=8, head_dim 128), d_ff 25600,
vocab 151936, per-head q/k RMSNorm (qk_norm).
"""

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=25600,
    vocab_size=151936,
    max_seq_len=40960,
    qk_norm=True,
    rope_theta=1_000_000.0,
    strategy="fsdp_tp",
    microbatches=8,
)
