"""starcoder2-3b — BigCode StarCoder2 [arXiv:2402.19173; hf].

Dense: 30L, d_model 3072, 24 heads (GQA kv=2), d_ff 12288, vocab 49152.
24 heads / 2 kv heads do not divide the 16-way model axis -> pure-FSDP
strategy (DESIGN.md §5).
"""

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_ff=12288,
    vocab_size=49152,
    max_seq_len=32768,
    rope_theta=1_000_000.0,
    strategy="fsdp",
    microbatches=8,
)
