"""Error-feedback int8 gradient compression for cross-pod reduction.

On a multi-pod mesh the inter-pod links are the slowest hop, so the pod-level
gradient all-reduce is the natural place to compress (DESIGN.md §5).  Blocked
int8 quantisation (per-block absmax scale) cuts the all-reduced bytes 4×
vs f32 / 2× vs bf16; the quantisation residual is fed back into the next
step's gradient (error feedback), which keeps SGD/Adam convergence —
EF-SGD/EF21-style.

``compressed_psum`` runs inside ``shard_map`` over the pod axis:

    q, scales, err = quantize(g + err_state)
    q_sum = lax.psum(q.astype(int32), "pod")      # 1 byte/elem on the wire
    g_hat = dequantize(q_sum, psum(scales)) / n_pods

Tested for closed-loop convergence in tests/test_compression.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to_block(x):
    flat = x.reshape(-1)
    pad = -flat.shape[0] % BLOCK
    return jnp.pad(flat, (0, pad)), pad


def quantize(g: jnp.ndarray):
    """g (any shape, f32) -> (int8 codes, per-block scales f32, residual)."""
    flat, _pad = _pad_to_block(g.astype(jnp.float32))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    deq = (q.astype(jnp.float32) * scale).reshape(-1)[: g.size].reshape(g.shape)
    residual = g.astype(jnp.float32) - deq
    return q, scale, residual


def dequantize(q: jnp.ndarray, scale: jnp.ndarray, shape) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def compressed_psum(g: jnp.ndarray, err: jnp.ndarray, axis_name: str):
    """Error-feedback int8 psum over ``axis_name``.

    Returns (mean-reduced gradient f32, new error state).  Call per-leaf
    inside shard_map; the int8 codes are what crosses the link.
    """
    q, scale, new_err = quantize(g.astype(jnp.float32) + err)
    # Sum int8 codes in int32 (values ≤ 127·n_pods fit easily), then apply the
    # per-shard scale before combining: each pod's codes carry its own scale,
    # so sum q_i·s_i via psum of the dequantised-but-still-int-grid values.
    contrib = q.astype(jnp.float32) * scale
    total = jax.lax.psum(contrib, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    g_hat = dequantize_from_grid(total, g.shape) / n
    return g_hat, new_err


def dequantize_from_grid(grid: jnp.ndarray, shape) -> jnp.ndarray:
    flat = grid.reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def tree_compressed_psum(grads, err_state, axis_name: str):
    """Apply compressed_psum over a gradient pytree with an error pytree."""
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree.leaves(err_state)
    outs = [compressed_psum(g, e, axis_name) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs])
    new_e = jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs])
    return new_g, new_e


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
