"""Optimisation: AdamW (+schedules, clipping) and gradient compression."""

from . import compression
from .adamw import (AdamWConfig, apply_updates, global_norm, init_opt_state,
                    schedule_lr)

__all__ = [
    "AdamWConfig",
    "apply_updates",
    "compression",
    "global_norm",
    "init_opt_state",
    "schedule_lr",
]
