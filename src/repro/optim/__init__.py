"""Optimisation: AdamW (+schedules, clipping) and gradient compression."""

from .adamw import AdamWConfig, apply_updates, global_norm, init_opt_state, schedule_lr
from . import compression

__all__ = [
    "AdamWConfig",
    "apply_updates",
    "compression",
    "global_norm",
    "init_opt_state",
    "schedule_lr",
]
