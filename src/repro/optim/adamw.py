"""AdamW with global-norm clipping and schedules — self-contained (no optax
dependency), pytree-generic, f32 moments regardless of param dtype.

The optimizer state mirrors the parameter pytree, so parameter sharding
specs apply verbatim to both moments (ZeRO: moments live wherever their
shard lives).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    schedule: str = "cosine"  # "cosine" | "constant"


def init_opt_state(params) -> dict:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def schedule_lr(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1.0) / max(1, cfg.warmup_steps))
    if cfg.schedule == "constant":
        return cfg.lr * warm
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0
    )
    cos = 0.5 * (1.0 + jnp.cos(math.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def _decay_mask(path: str) -> bool:
    """No weight decay on norms / biases / gates / 1-d params."""
    needle = path.lower()
    return not any(s in needle for s in ("norm", "bias", "gate", "scale", "a_log", "d_skip"))


def apply_updates(cfg: AdamWConfig, params, opt_state, grads, step):
    """One AdamW step.  Returns (new_params, new_opt_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = schedule_lr(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - b1**t
    bc2 = 1.0 - b2**t

    flat_p, treedef = jax.tree_util.tree_flatten_with_path(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])

    new_p, new_m, new_v = [], [], []
    for (path, p), g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        g32 = g.astype(jnp.float32) * scale
        m = b1 * m + (1.0 - b1) * g32
        v = b2 * v + (1.0 - b2) * g32 * g32
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        pathstr = jax.tree_util.keystr(path)
        if cfg.weight_decay and _decay_mask(pathstr):
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - lr * upd).astype(p.dtype))
        new_m.append(m)
        new_v.append(v)

    unflatten = jax.tree_util.tree_unflatten
    params = unflatten(treedef, new_p)
    opt_state = {
        "m": unflatten(jax.tree_util.tree_structure(opt_state["m"]), new_m),
        "v": unflatten(jax.tree_util.tree_structure(opt_state["v"]), new_v),
    }
    metrics = {"grad_norm": gnorm, "lr": lr}
    return params, opt_state, metrics
